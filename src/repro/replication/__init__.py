"""Replicated shards: WAL shipping, heartbeat failover, chaos survival.

DESIGN.md §12.  Each range partition of the key space is served by a
:class:`~.replica.ReplicaGroup` — a primary plus R−1 replicas kept in
sync by shipping group-commit WAL records (``repro.wal`` on-disk format,
one private segment directory per node).  The
:class:`~.frontend.ReplicatedFrontend` runs the open-loop serving
protocol over the ensemble with heartbeat-driven failover: a dead
primary is detected on the sim clock, the most-caught-up replica is
promoted (WAL tail replayed), a fresh replica is rebuilt from snapshot
+ catch-up, and ops for the affected range degrade to bounded
retry-with-backoff while every other range keeps serving.  The chaos
harness (:class:`repro.wal.faults.FaultSchedule`) injects crashes,
stalls, latency spikes, and physical log corruption against stable slot
addresses — the whole run stays deterministic given the schedule seed.
"""
from .frontend import ReplicatedFrontend, run_replicated
from .replica import ReplicaGroup, ReplicaNode, ReplicationConfig

__all__ = [
    "ReplicaGroup", "ReplicaNode", "ReplicatedFrontend",
    "ReplicationConfig", "run_replicated",
]
