"""Deterministic workload-mix generator (YCSB-style, paper-tier portable).

A :class:`WorkloadSpec` names an operation mix (per-kind probabilities), a
key distribution (uniform, zipfian, or a moving zipfian hotspot over a
bounded key space), a range selectivity, and sizes; :class:`Workload` expands it into a reproducible
stream of :class:`~repro.core.engine_api.OpBatch` — the same stream for
every engine, which is what makes cross-tier comparisons and conformance
tests meaningful.

Portability constraints (see ``engine_api`` module docstring): generated
keys live in ``[1, key_space]`` with ``key_space + range span < 2^31`` so
the uint32 device tier and the uint64 cost-model tiers see identical keys,
and values are an increasing non-negative counter below 2^31 (int32-safe,
never a tombstone sentinel) so freshest-copy-wins is observable.

Zipfian draws use the continuous bounded power-law inverse CDF
(rank = ((u*(N^{1-θ}-1))+1)^{1/(1-θ)}, the standard smooth approximation of
YCSB's ZipfianGenerator) and scatter ranks over the key space with a
splitmix64 mix so hot keys are not clustered at one end of the key space —
hot *ranks*, arbitrary *keys*, as in YCSB's hashed key order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine_api import OpBatch, OpKind
from repro.core.splitmix import splitmix64 as _splitmix64

#: named operation mixes (probabilities per op kind).
MIXES: dict = {
    # the paper's own regime: ingestion-dominated with occasional reads.
    "insert-heavy":    {OpKind.INSERT: 0.95, OpKind.QUERY: 0.05},
    "point-read-heavy": {OpKind.INSERT: 0.05, OpKind.QUERY: 0.95},
    "range-heavy":     {OpKind.INSERT: 0.05, OpKind.RANGE: 0.95},
    # YCSB-style blends (A: update-heavy, B: read-mostly, E: short scans);
    # updates are inserts on existing keys (blind writes), as in YCSB.
    "ycsb-a":          {OpKind.INSERT: 0.50, OpKind.QUERY: 0.50},
    "ycsb-b":          {OpKind.INSERT: 0.05, OpKind.QUERY: 0.95},
    "ycsb-e":          {OpKind.INSERT: 0.05, OpKind.RANGE: 0.95},
    # tombstone churn: exercises delta-record deletion on every tier.
    "delete-churn":    {OpKind.INSERT: 0.45, OpKind.DELETE: 0.25,
                        OpKind.QUERY: 0.25, OpKind.RANGE: 0.05},
    # moving hotspot: insert-dominated zipfian mass inside a narrow window
    # that sweeps across the key space over the stream — the adversary for
    # any static range partitioning (forces hot-shard rebalancing).
    "hotspot-shift":   {OpKind.INSERT: 0.80, OpKind.QUERY: 0.15,
                        OpKind.RANGE: 0.05},
}

#: mixes that default to a skewed key distribution (YCSB's default).
_ZIPF_BY_DEFAULT = ("ycsb-a", "ycsb-b", "ycsb-e")

#: mixes that default to the moving-hotspot distribution.
_HOTSPOT_BY_DEFAULT = ("hotspot-shift",)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mix: dict                      # OpKind -> probability, sums to 1
    dist: str = "uniform"          # "uniform" | "zipfian" | "hotspot"
    theta: float = 0.8             # zipfian skew (0 = uniform, <1)
    key_space: int = 1 << 24       # keys drawn from [1, key_space]
    #: "hotspot" dist: fraction of draws inside the moving hot window and
    #: the window's width as a fraction of the key space.  The window is
    #: ``[base, base + width)`` (wrapping modulo key_space) with draws
    #: zipfian toward ``base``; ``base`` sweeps the key space linearly
    #: with stream progress (batch 0 starts at key 1; the last batch's
    #: base sits one batch short of key_space).
    hotspot_frac: float = 0.9
    hotspot_width: float = 0.05
    range_selectivity: float = 1e-3
    preload: int = 4096            # distinct keys loaded before the mix runs
    n_ops: int = 8192
    batch_size: int = 256
    seed: int = 0
    #: emit each batch's ops grouped by kind (INSERT, DELETE, QUERY, RANGE).
    #: The stream stays mixed *across* batches and sequential semantics are
    #: untouched; within a batch, grouping turns ~batch_size/2 tiny
    #: same-kind runs into <= 4 large ones, which is what lets the device
    #: tier serve a batch in <= 4 fused shape-bucketed calls instead of
    #: recompiling per run length.  Set False for interleaving stress tests.
    group_kinds: bool = True

    def __post_init__(self):
        total = sum(self.mix.values())
        assert abs(total - 1.0) < 1e-9, f"mix must sum to 1, got {total}"
        span = self.range_span
        assert self.key_space + span < (1 << 31), \
            "key_space + range span must stay below 2^31 (uint32 device tier)"
        assert 0.0 <= self.theta < 1.0
        assert self.dist in ("uniform", "zipfian", "hotspot"), self.dist
        assert 0.0 <= self.hotspot_frac <= 1.0
        assert 0.0 < self.hotspot_width <= 1.0

    @property
    def range_span(self) -> int:
        return max(1, int(self.key_space * self.range_selectivity))


def make_workload(mix_name: str, **overrides) -> "Workload":
    """Build a workload from a named mix; keyword overrides win."""
    mix = MIXES[mix_name]
    if mix_name in _ZIPF_BY_DEFAULT:
        overrides.setdefault("dist", "zipfian")
    if mix_name in _HOTSPOT_BY_DEFAULT:
        overrides.setdefault("dist", "hotspot")
    return Workload(WorkloadSpec(name=mix_name, mix=mix, **overrides))


class Workload:
    """Expands a :class:`WorkloadSpec` into deterministic OpBatch streams."""

    def __init__(self, spec: WorkloadSpec):
        self.spec = spec

    # ---------------------------------------------------------------- key draw
    def _zipf_ranks(self, rng: np.random.Generator, n: int,
                    space: int) -> np.ndarray:
        """Zipfian ranks in [0, space) via the bounded power-law inverse CDF."""
        u = rng.random(n)
        g = 1.0 - self.spec.theta
        ranks = ((u * (float(space) ** g - 1.0)) + 1.0) ** (1.0 / g)
        return np.minimum(ranks.astype(np.uint64), np.uint64(space)) - 1

    def _draw_keys(self, rng: np.random.Generator, n: int,
                   progress: float = 0.0) -> np.ndarray:
        space = self.spec.key_space
        if self.spec.dist == "zipfian" and self.spec.theta > 0.0:
            ranks = self._zipf_ranks(rng, n, space)
            # scatter hot ranks over the key space (YCSB hashed key order).
            return (_splitmix64(ranks) % np.uint64(space)) + np.uint64(1)
        if self.spec.dist == "hotspot":
            # moving hot window [base, base + width): base sweeps the key
            # space with progress, in-window draws are zipfian toward base,
            # the rest of the mass is uniform background.  All draws
            # consume the rng in a fixed order, so streams are
            # reproducible per seed.
            width = max(1, int(space * self.spec.hotspot_width))
            base = int(progress * (space - 1))          # 0-based sweep
            hot = rng.random(n) < self.spec.hotspot_frac
            offs = self._zipf_ranks(rng, n, width)      # clustered near 0
            cold = rng.integers(0, space, n, dtype=np.uint64)
            keys0 = np.where(
                hot, (np.uint64(base) + offs) % np.uint64(space), cold)
            return keys0 + np.uint64(1)
        return rng.integers(1, space + 1, n, dtype=np.uint64)

    # ---------------------------------------------------------------- preload
    def preload_batch(self) -> OpBatch:
        """Distinct-key initial load (YCSB load phase), deterministic."""
        spec = self.spec
        keys = (_splitmix64(np.arange(spec.preload, dtype=np.uint64))
                % np.uint64(spec.key_space)) + np.uint64(1)
        keys = np.unique(keys)[: spec.preload]       # drop rare collisions
        vals = np.arange(1, len(keys) + 1, dtype=np.int64)
        return OpBatch.inserts(keys, vals)

    # ----------------------------------------------------------------- stream
    def batches(self):
        """Yield the mixed-op stream, ``batch_size`` ops per OpBatch."""
        spec = self.spec
        rng = np.random.default_rng(spec.seed)
        kinds_pool = np.array([int(k) for k in spec.mix], np.int8)
        probs = np.array([spec.mix[OpKind(int(k))] for k in kinds_pool])
        val_counter = spec.preload + 1
        emitted = 0
        while emitted < spec.n_ops:
            b = min(spec.batch_size, spec.n_ops - emitted)
            kinds = rng.choice(kinds_pool, b, p=probs).astype(np.int8)
            if spec.group_kinds:
                kinds = kinds[np.argsort(kinds, kind="stable")]
            keys = self._draw_keys(rng, b, progress=emitted / spec.n_ops)
            vals = np.zeros(b, np.int64)
            his = np.zeros(b, np.uint64)
            ins = kinds == int(OpKind.INSERT)
            n_ins = int(ins.sum())
            # increasing int32-safe values: freshest-wins is observable and
            # both value widths (int64 host / int32 device) agree.
            vals[ins] = (np.arange(val_counter, val_counter + n_ins)
                         % ((1 << 31) - 1))
            val_counter += n_ins
            rng_mask = kinds == int(OpKind.RANGE)
            his[rng_mask] = keys[rng_mask] + np.uint64(spec.range_span)
            yield OpBatch(kinds, keys, vals, his)
            emitted += b
