"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
shape + finiteness assertions; decode/prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T
from repro.optim import adamw
from repro.train.train_step import make_train_step

ARCHS = registry.list_archs()


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch, key):
    cfg = registry.get_config(arch).reduced()
    params = T.init_params(key, cfg)
    B, S = 2, 32
    if cfg.encoder_only:
        embeds = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        logits, aux = jax.jit(lambda p, e: T.forward(p, cfg, embeds=e))(params, embeds)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        logits, aux = jax.jit(lambda p, t: T.forward(p, cfg, tokens=t))(params, toks)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.array(logits, np.float32)).all(), f"{arch}: NaN"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, key):
    cfg = registry.get_config(arch).reduced()
    params = T.init_params(key, cfg)
    opt = adamw.init(params)
    step = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
    B, S = 2, 16
    if cfg.encoder_only:
        batch = {"embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
                 "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    else:
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed somewhere (NB: encoder archs take embeds, so
    # their embed table only sees weight decay, which bf16 can round away).
    changed = any(
        not np.array_equal(np.array(a, np.float32), np.array(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert changed, arch


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not registry.get_config(a).encoder_only])
def test_decode_matches_forward(arch, key):
    """Teacher-forced decode must reproduce forward logits (fp32 configs).

    capacity_factor raised so MoE drops can't occur (full-sequence vs
    token-by-token routing legitimately diverges once tokens drop)."""
    cfg = dataclasses.replace(registry.get_config(arch).reduced(),
                              dtype="float32", remat="none",
                              capacity_factor=8.0)
    params = T.init_params(key, cfg)
    B, S = 1, 12
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab)
    logits, _ = T.forward(params, cfg, tokens=toks)
    cache = T.init_cache(cfg, B, 32)
    for i in range(S):
        lg, cache = T.decode_step(params, cfg, toks[:, i], cache, jnp.int32(i))
    np.testing.assert_allclose(np.array(lg), np.array(logits[:, -1]),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not registry.get_config(a).encoder_only])
def test_prefill_cache_matches_stepwise(arch, key):
    """Prefill-built cache must continue decoding identically to a cache
    built token-by-token (the engine's prefill->decode handoff).

    capacity_factor is raised so MoE capacity drops cannot occur: with
    drops, full-sequence routing and token-by-token routing legitimately
    differ (batch-dependent truncation) and parity is not defined.
    """
    cfg = dataclasses.replace(registry.get_config(arch).reduced(),
                              dtype="float32", remat="none",
                              capacity_factor=8.0)
    params = T.init_params(key, cfg)
    B, S, MAX = 1, 8, 24
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab)
    _, _, cache_pf = T.forward(params, cfg, tokens=toks, build_cache_len=MAX)
    cache_st = T.init_cache(cfg, B, MAX)
    for i in range(S):
        lg_st, cache_st = T.decode_step(params, cfg, toks[:, i], cache_st, jnp.int32(i))
    nxt = jnp.argmax(lg_st, -1).astype(jnp.int32)
    lg_a, _ = T.decode_step(params, cfg, nxt, cache_pf, jnp.int32(S))
    lg_b, _ = T.decode_step(params, cfg, nxt, cache_st, jnp.int32(S))
    np.testing.assert_allclose(np.array(lg_a), np.array(lg_b), atol=2e-3, rtol=2e-3)


def test_swa_ring_cache_long_context(key):
    """SWA ring cache: decode far past the window stays consistent with a
    full-length cache (mixtral-style)."""
    cfg = dataclasses.replace(
        registry.get_config("mixtral-8x22b").reduced(),
        dtype="float32", remat="none", swa_window=8)
    params = T.init_params(key, cfg)
    B = 1
    LONG = 40
    toks = jax.random.randint(key, (B, LONG), 1, cfg.vocab)
    # ring cache (init_cache caps SWA cache at window+128 but >=256 slots;
    # use small max_seq so ring < full)
    ring = T.init_cache(cfg, B, 1 << 20)   # kv_len = min(1M, window+128)
    full = T.init_cache(cfg, B, LONG + 8)  # full-length cache
    kv_len_ring = ring["seg0"]["kv"]["k"].shape[2] if "kv" in ring["seg0"] else ring["seg0"]["k"].shape[2]
    assert kv_len_ring < 1 << 20
    for i in range(LONG):
        lr, ring = T.decode_step(params, cfg, toks[:, i], ring, jnp.int32(i))
        lf, full = T.decode_step(params, cfg, toks[:, i], full, jnp.int32(i))
    np.testing.assert_allclose(np.array(lr), np.array(lf), atol=2e-3, rtol=2e-3)


def test_int8_kv_cache_decode_accuracy(key):
    """int8 KV cache (Perf It.7): <5% logit error, argmax-stable decode."""
    # fresh executable cache: XLA CPU's jit dylib cache intermittently fails
    # to re-materialize a dus fusion symbol after many prior compilations
    # ("Failed to materialize symbols", jaxlib 0.8.2) — environment flake.
    jax.clear_caches()
    cfg = dataclasses.replace(registry.get_config("qwen3-8b").reduced(),
                              dtype="float32", remat="none")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 16), 1, cfg.vocab)
    c16, c8 = T.init_cache(cfg, 1, 32), T.init_cache(cfg8, 1, 32)
    assert c8["seg0"]["k"].dtype == jnp.int8
    agree = 0
    for i in range(16):
        l16, c16 = T.decode_step(params, cfg, toks[:, i], c16, jnp.int32(i))
        l8, c8 = T.decode_step(params, cfg8, toks[:, i], c8, jnp.int32(i))
        agree += int(jnp.argmax(l16[0]) == jnp.argmax(l8[0]))
    rel = float(jnp.abs(l16 - l8).max() / (jnp.abs(l16).max() + 1e-9))
    assert rel < 0.05, rel
    assert agree >= 14, agree


def test_mrope_reduces_to_rope_for_text(key):
    """Qwen2-VL M-RoPE with identical (t,h,w) ids == standard RoPE."""
    jax.clear_caches()   # see test_int8_kv_cache_decode_accuracy note
    from repro.models.layers import mrope_angles, rope_angles
    pos = jnp.arange(16)[None]
    c1, s1 = rope_angles(pos, 64, 10000.0)
    pos3 = jnp.broadcast_to(pos, (3, 1, 16))
    c2, s2 = mrope_angles(pos3, 64, 10000.0, (8, 12, 12))
    # sections permute the frequency order; sorted spectra must match
    np.testing.assert_allclose(np.sort(np.array(c1), -1), np.sort(np.array(c2), -1),
                               rtol=1e-6)


def test_param_counts_match_published():
    expected = {"deepseek-moe-16b": 16.4e9, "mixtral-8x22b": 141e9,
                "xlstm-1.3b": 1.3e9, "starcoder2-3b": 3.1e9,
                "minicpm3-4b": 4.1e9, "qwen3-8b": 8.2e9, "gemma-2b": 2.5e9,
                "hubert-xlarge": 1.0e9, "hymba-1.5b": 1.6e9,
                "qwen2-vl-2b": 1.5e9}
    for arch, want in expected.items():
        cfg = registry.get_config(arch)
        shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))
        assert abs(n - want) / want < 0.12, (arch, n, want)
