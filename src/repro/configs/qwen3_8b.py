"""Qwen3-8B [hf:Qwen/Qwen3-8B; hf].

36L, d_model 4096, 32 heads GQA kv 8, head_dim 128, qk-norm, d_ff 12288.
Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936, head_dim=128,
    segments=(("dense", 36),),
    qk_norm=True, mlp_kind="swiglu", rope_base=1000000.0,
)
