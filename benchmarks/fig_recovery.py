"""Durability scenario: time-to-recover vs data volume, WAL ingest overhead.

The paper's deamortized NB-tree bounds the *foreground* insertion delay;
an insertion-intensive deployment also has to bound what happens after a
crash.  This scenario measures the durability subsystem (DESIGN.md §9) on
the paper's SSD testbed constants:

* **Recovery rows** — ingest a durable insert-heavy stream of increasing
  volume through the group-commit WAL, then treat the surviving directory
  as a crash image and time ``repro.wal.recovery.recover``.  Two modes per
  volume: ``ckpt`` (periodic snapshots truncate the WAL, so replay is a
  bounded tail regardless of volume) and ``wal-only`` (no periodic
  snapshots: replay grows linearly with everything ever acked).  Every row
  differentially checks the recovered engine against the live one
  (``recovered_equal`` — zero lost acked writes, zero resurrected unacked
  ones).
* **Overhead rows** — the same offered load served with durability on vs
  off.  The fsync-per-commit cost is charged on the simulated clock
  (`seek + bytes/write_bw` on the engine's own device constants), so the
  overhead is deterministic and attributable: ``wal_s`` of charged service
  vs the baseline.

Expected shape: checkpointed recovery replays a bounded tail (< the
checkpoint cadence) at every volume while WAL-only replay scales with
volume; WAL-on ingest pays a real but modest charged-service premium at
group-commit granularity.

Standalone CLI (CI fault-smoke; ``BENCH_recovery.json`` at the repo root
is the seed trajectory record)::

    PYTHONPATH=src python -m benchmarks.fig_recovery --quick \
        --out runs/fig_recovery.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

from repro.core.cost_model import SSD
from repro.core.engine_api import make_engine
from repro.ingest import (DurabilityConfig, FrontendConfig, IngestFrontend,
                          PoissonArrivals, make_trace)
from repro.workloads import make_workload
from repro.workloads.driver import SCHEMA_VERSION

KEY_SPACE = 1 << 20
ENGINE_KW = dict(f=3, sigma=512, device=SSD)
FRONTEND = FrontendConfig(max_queue=4096, commit_ops=64, linger_s=2e-4)
CKPT_EVERY = 32            # commits between periodic snapshots ("ckpt" mode)

#: acked ops ingested before the simulated crash (recovery rows).
VOLUMES = (4_000, 8_000, 16_000)
#: offered load for the WAL-on/off overhead comparison, ops/second.
RATES = (50_000, 200_000)

#: one source of truth for the smoke-sized sweep (--quick here and in
#: benchmarks/run.py must produce comparable artifacts).
QUICK_KWARGS = dict(volumes=(1_500, 3_000), rates=(50_000,))


def _engine():
    return make_engine("nbtree", **ENGINE_KW)


def _trace(n_ops, seed, mix="insert-heavy", rate=100_000.0):
    wl = make_workload(mix, key_space=KEY_SPACE, n_ops=n_ops, preload=4096,
                       batch_size=256, seed=seed)
    return make_trace(wl, PoissonArrivals(rate))


def _row(**kw):
    base = dict(fig="recovery", kind="", index="", volume=0, rate=0.0,
                recover_ms=0.0, snapshot_lsn=0, snapshot_pairs=0,
                replayed_commits=0, replayed_ops=0, acked_commits=0,
                last_lsn=0, live_pairs=0, recovered_equal=True,
                service_s=0.0, wal_service_s=0.0, ckpt_service_s=0.0,
                overhead_pct=0.0, n_done=0)
    base.update(kw)
    return base


def run(volumes=VOLUMES, rates=RATES, seed: int = 0):
    from repro.wal import recover

    rows = []

    # ---- time-to-recover vs data volume (ckpt vs wal-only) ----------------
    for n_ops in volumes:
        for mode, every in (("ckpt", CKPT_EVERY), ("wal-only", 0)):
            trace = _trace(n_ops, seed)
            eng = _engine()
            with tempfile.TemporaryDirectory() as d:
                fe = IngestFrontend(
                    eng, FRONTEND,
                    durability=DurabilityConfig(
                        d, checkpoint_every_commits=every))
                rep = fe.run(trace)
                rr = recover(d, _engine)
                lk, lv = eng.dump_live()
                rk, rv = rr.engine.dump_live()
                equal = (np.array_equal(lk, rk) and np.array_equal(lv, rv)
                         and rr.last_lsn == fe.last_acked_lsn)
            dur = rep["durability"]
            rows.append(_row(
                kind="recover", index=f"nbtree/{mode}", volume=n_ops,
                recover_ms=rr.recover_wall_s * 1e3,
                snapshot_lsn=rr.snapshot_lsn,
                snapshot_pairs=rr.snapshot_pairs,
                replayed_commits=rr.replayed_commits,
                replayed_ops=rr.replayed_ops,
                acked_commits=dur["acked_commits"],
                last_lsn=dur["last_acked_lsn"],
                live_pairs=int(len(lk)), recovered_equal=bool(equal),
                wal_service_s=dur["wal"]["service_s_total"],
                ckpt_service_s=dur["checkpoints"]["service_s_total"],
                n_done=rep["n_done"]))

    # ---- ingest throughput, WAL on vs off ---------------------------------
    for rate in rates:
        base_eng = _engine()
        rep_off = IngestFrontend(base_eng, FRONTEND).run(
            _trace(6_000, seed, rate=rate))
        with tempfile.TemporaryDirectory() as d:
            fe = IngestFrontend(
                _engine(), FRONTEND,
                durability=DurabilityConfig(
                    d, checkpoint_every_commits=CKPT_EVERY))
            rep_on = fe.run(_trace(6_000, seed, rate=rate))
        off_s = rep_off["server"]["service_s"]
        on_s = rep_on["server"]["service_s"]
        dur = rep_on["durability"]
        rows.append(_row(kind="overhead", index="nbtree/wal-off", rate=rate,
                         service_s=off_s, n_done=rep_off["n_done"]))
        rows.append(_row(kind="overhead", index="nbtree/wal-on", rate=rate,
                         service_s=on_s,
                         wal_service_s=dur["wal"]["service_s_total"],
                         ckpt_service_s=dur["checkpoints"]["service_s_total"],
                         acked_commits=dur["acked_commits"],
                         last_lsn=dur["last_acked_lsn"],
                         overhead_pct=100.0 * (on_s - off_s) / off_s,
                         n_done=rep_on["n_done"]))
    return rows


def check(rows) -> list[str]:
    out = []
    rec = [r for r in rows if r["kind"] == "recover"]
    ck = {r["volume"]: r for r in rec if r["index"] == "nbtree/ckpt"}
    wo = {r["volume"]: r for r in rec if r["index"] == "nbtree/wal-only"}

    # the durability contract: recovery == acked prefix, at every volume.
    bad = [r["index"] for r in rec if not r["recovered_equal"]]
    tag = "matches paper" if not bad else "MISMATCH"
    out.append(f"recovery: recovered state equals the acked prefix exactly "
               f"(zero lost / zero resurrected) in {len(rec)}/{len(rec)} "
               f"crash images  [{tag}]")

    # checkpoints bound replay: the ckpt-mode tail never exceeds the
    # cadence, while wal-only replay is the full acked history.
    bounded = all(r["replayed_commits"] <= CKPT_EVERY for r in ck.values())
    full = all(wo[v]["replayed_commits"] == wo[v]["acked_commits"]
               for v in wo)
    tag = "matches paper" if bounded and full else "MISMATCH"
    worst = max((r["replayed_commits"] for r in ck.values()), default=0)
    out.append(f"recovery: periodic snapshots bound replay to <= "
               f"{CKPT_EVERY} commits at every volume (worst {worst}); "
               f"wal-only replays the full history  [{tag}]")

    # wal-only replay work grows with volume (the reason checkpoints exist).
    vols = sorted(wo)
    grows = all(wo[a]["replayed_ops"] < wo[b]["replayed_ops"]
                for a, b in zip(vols, vols[1:]))
    tag = "matches paper" if grows else "MISMATCH"
    out.append(f"recovery: wal-only replay work grows with data volume "
               f"({[wo[v]['replayed_ops'] for v in vols]} ops)  [{tag}]")

    # durability costs something, at group-commit (not per-op) granularity:
    # positive charged overhead, but bounded.
    over = [r for r in rows if r["index"] == "nbtree/wal-on"]
    ok = all(0.0 < r["overhead_pct"] for r in over)
    tag = "matches paper" if ok else "MISMATCH"
    pcts = [round(r["overhead_pct"], 1) for r in over]
    out.append(f"recovery: WAL-on charged service overhead is positive at "
               f"group-commit granularity ({pcts} % per offered rate)  "
               f"[{tag}]")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI fault-smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/fig_recovery.json")
    args = ap.parse_args(argv)
    kwargs = dict(QUICK_KWARGS) if args.quick else {}
    rows = run(seed=args.seed, **kwargs)
    checks = check(rows)
    for r in rows:
        print(r)
    for c in checks:
        print(" ->", c)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "seed": args.seed,
                   "quick": bool(args.quick), "rows": rows,
                   "checks": checks}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
