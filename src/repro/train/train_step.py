"""Training step factory: loss, grad, (optionally compressed) reduce, AdamW.

``make_train_step(cfg, ...)`` returns a function with signature
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with in/out shardings from distributed/sharding.py.

Microbatching (gradient accumulation) wraps loss+grad in a ``lax.scan`` over
microbatch slices — per-device activation memory scales with the microbatch,
not the per-device batch.  Cross-pod gradient compression (optim/compression)
swaps the fp32 DCN all-reduce for error-feedback int8.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import shard_map
from ..models import transformer as T
from ..optim import adamw, compression

AUX_LOSS_WEIGHT = 0.01


def make_loss_fn(cfg):
    def loss_fn(params, batch):
        if cfg.encoder_only:
            logits, aux = T.forward(params, cfg, embeds=batch["embeds"])
            loss = T.cross_entropy(logits, batch["labels"])
        else:
            logits, aux = T.forward(params, cfg, tokens=batch["tokens"])
            loss = T.cross_entropy(logits[:, :-1], batch["tokens"][:, 1:])
        return loss + AUX_LOSS_WEIGHT * aux, (loss, aux)
    return loss_fn


def _grads_microbatched(loss_fn, params, batch, num_microbatches: int):
    if num_microbatches <= 1:
        (tot, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return grads, loss, aux

    def slice_mb(i, t):
        mb = t.shape[0] // num_microbatches
        return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

    def body(carry, i):
        g_acc, l_acc, a_acc = carry
        mb = jax.tree.map(functools.partial(slice_mb, i), batch)
        (_, (loss, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
        return (g_acc, l_acc + loss, a_acc + aux), None

    zeros = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params)
    (g, l, a), _ = jax.lax.scan(
        body, (zeros, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(num_microbatches))
    inv = 1.0 / num_microbatches
    return jax.tree.map(lambda t: t * inv, g), l * inv, a * inv


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *,
                    num_microbatches: int = 1,
                    grad_compression: bool = False,
                    mesh=None):
    """Build the jittable train step.

    With ``grad_compression`` the step expects ``opt_state['error']`` (from
    ``compression.init_error``) and the mesh must have a "pod" axis; the
    cross-pod reduction then rides int8 (see optim/compression.py).
    """
    loss_fn = make_loss_fn(cfg)

    if not grad_compression:
        def step(params, opt_state, batch):
            grads, loss, aux = _grads_microbatched(loss_fn, params, batch,
                                                   num_microbatches)
            params, opt_state, metrics = adamw.update(grads, opt_state, params, opt_cfg)
            metrics.update(loss=loss, aux_loss=aux)
            return params, opt_state, metrics
        return step

    assert mesh is not None and "pod" in mesh.shape, "compression needs a pod axis"
    n_pods = mesh.shape["pod"]

    def step(params, opt_state, batch):
        error = opt_state["error"]

        def per_pod(params, error, batch):
            error = jax.tree.map(lambda t: t[0], error)   # drop local pod dim
            batch = jax.tree.map(lambda t: t[0], batch)
            grads, loss, aux = _grads_microbatched(loss_fn, params, batch,
                                                   num_microbatches)
            grads, new_error = compression.quantized_psum_mean(grads, error, "pod")
            loss = jax.lax.pmean(loss, "pod")
            aux = jax.lax.pmean(aux, "pod")
            new_error = jax.tree.map(lambda t: t[None], new_error)
            return grads, new_error, loss, aux

        # explicit leading pod dim so the manual axis (dim 0) never shares a
        # dimension with auto data-sharding (dim 1) — jaxlib 0.8.2's SPMD
        # partitioner CHECK-fails on jointly manual+auto dims.
        batch_p = jax.tree.map(
            lambda t: jax.lax.with_sharding_constraint(
                t.reshape((n_pods, t.shape[0] // n_pods) + t.shape[1:]),
                P("pod", "data")), batch)
        batch_specs = jax.tree.map(lambda _: P("pod"), batch_p)
        grads, new_error, loss, aux = shard_map(
            per_pod, mesh=mesh,
            in_specs=(P(), P(), batch_specs),
            out_specs=(P(), P("pod"), P(), P()),
            axis_names={"pod"}, check_vma=False,
        )(params, error, batch_p)

        inner = {k: opt_state[k] for k in ("m", "v", "count")}
        params, inner, metrics = adamw.update(grads, inner, params, opt_cfg)
        inner["error"] = new_error
        metrics.update(loss=loss, aux_loss=aux)
        return params, inner, metrics

    return step
