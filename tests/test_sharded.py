"""Sharded storage layer tests (DESIGN.md §6).

Covers the routing tables (range + hash partitioners), the debt-weighted
maintenance scheduler, order-preserving batch split/merge against an
unsharded engine, hot-shard splitting under a moving hotspot (aggregated
stats must stay monotone across rebalances), and the 4-shard conformance
replay: the delete-churn op-stream through a 4-shard wrapper of every tier
against the sorted-dict oracle.
"""
import numpy as np
import pytest

from repro.core.engine_api import (FIVE_TIERS, OpBatch, OpKind, make_engine)
from repro.shard import DebtScheduler, HashPartitioner, RangePartitioner
from repro.workloads import make_workload

#: small-footprint per-shard configs so the device tier stays CI-sized.
CONFIGS = {
    "nbtree": dict(f=3, sigma=128),
    "lsm": dict(mem_pairs=128),
    "btree": {},
    "bepsilon": dict(node_bytes=1 << 14, cached_levels=1),
    "jax-nbtree": dict(f=4, sigma=128, max_nodes=64),
}


# ------------------------------------------------------------- partitioners
def test_range_partitioner_routing():
    p = RangePartitioner([100, 200])
    assert p.n_shards == 3
    assert p.shard_of([0, 99, 100, 150, 199, 200, 5000]).tolist() \
        == [0, 0, 1, 1, 1, 2, 2]
    assert list(p.shards_for_range(0, 50)) == [0]
    assert list(p.shards_for_range(50, 100)) == [0, 1]
    assert list(p.shards_for_range(150, 10**6)) == [1, 2]
    assert list(p.shards_for_range(10, 5)) == []          # lo > hi: empty
    assert p.interval(0) == (0, 99)
    assert p.interval(1) == (100, 199)
    assert p.interval(2)[0] == 200


def test_range_partitioner_from_sample_and_split():
    keys = np.arange(1, 1001, dtype=np.uint64)
    p = RangePartitioner.from_sample(keys, 4)
    assert p.n_shards == 4
    sid = p.shard_of(keys)
    counts = np.bincount(sid, minlength=4)
    assert counts.min() > 150          # quantile pivots balance the sample
    p.split(1, int(p.interval(1)[0]) + 10)
    assert p.n_shards == 5
    assert np.all(np.diff(p.pivots.astype(np.int64)) > 0)
    # degenerate samples collapse to fewer shards, never to invalid pivots
    assert RangePartitioner.from_sample([7, 7, 7], 4).n_shards == 1
    assert RangePartitioner.from_sample([], 8).n_shards == 1


def test_hash_partitioner_covers_and_fans_out():
    p = HashPartitioner(4)
    sid = p.shard_of(np.arange(1, 4097, dtype=np.uint64))
    assert set(sid.tolist()) == {0, 1, 2, 3}
    assert np.bincount(sid, minlength=4).min() > 4096 // 8   # roughly even
    assert list(p.shards_for_range(5, 10)) == [0, 1, 2, 3]
    assert list(p.shards_for_range(10, 5)) == []


# ---------------------------------------------------------------- scheduler
def test_scheduler_debt_weighted_allocation():
    s = DebtScheduler()
    assert s.allocate([3, 1, 0], 4) == [3, 1, 0]
    assert s.allocate([0, 0, 0], 5) == [0, 0, 0]     # no debt, no spend
    assert s.allocate([2, 5], 3) == [0, 3]           # heaviest first
    assert sum(s.allocate([1, 1], 10)) == 2          # never exceeds debt


def test_scheduler_round_robin_tiebreak():
    s = DebtScheduler()
    first = s.allocate([1, 1, 1, 1], 2)
    second = s.allocate([1, 1, 1, 1], 2)
    assert first == [1, 1, 0, 0]
    assert second == [0, 0, 1, 1]     # pointer advanced: no shard starves


def test_scheduler_no_starvation_under_persistent_hot_shard():
    """Starvation regression: a persistently hot shard must not let any
    other shard's pending debt grow without bound across rounds.

    Shard 0 accrues 4 debt units per round forever (a hot ingest
    partition); the three cold shards accrue 1 each.  With a per-round
    budget that covers total accrual (8 >= 7), heaviest-first allocation
    plus the round-robin tiebreak must keep every cold shard's debt
    bounded by a small constant — the cold debts may climb until they tie
    the hot shard's steady level, but never diverge.
    """
    s = DebtScheduler()
    debts = [0, 0, 0, 0]
    peak_cold = [0, 0, 0]
    served_rounds = [0, 0, 0]
    for rnd in range(400):
        debts[0] += 4
        for i in (1, 2, 3):
            debts[i] += 1
        alloc = s.allocate(debts, 8)
        assert sum(alloc) <= 8
        debts = [max(0, d - a) for d, a in zip(debts, alloc)]
        for i in (1, 2, 3):
            peak_cold[i - 1] = max(peak_cold[i - 1], debts[i])
            if alloc[i] > 0:
                served_rounds[i - 1] += 1
    assert max(peak_cold) <= 12, \
        f"cold-shard debt grew without bound: peaks {peak_cold}"
    # every cold shard keeps receiving budget, not just the hot one
    assert min(served_rounds) > 50, served_rounds


# ------------------------------------------------- order-preserving merge
def test_sharded_matches_unsharded_interleaved():
    """Ungrouped batches: ranges spanning shards interleaved with writes."""
    rng = np.random.default_rng(7)
    sh = make_engine("sharded:nbtree", shards=4, **CONFIGS["nbtree"])
    ref = make_engine("nbtree", **CONFIGS["nbtree"])
    keys = rng.permutation(np.arange(1, 801, dtype=np.uint64))
    pre = OpBatch.inserts(keys, np.arange(1, 801, dtype=np.int64))
    sh.apply(pre)
    ref.apply(pre)
    for step in range(8):
        n = 48
        kinds = rng.integers(0, 4, n).astype(np.int8)   # fully interleaved
        ks = rng.integers(1, 1000, n, dtype=np.uint64)
        vals = np.where(kinds == int(OpKind.INSERT),
                        np.arange(n, dtype=np.int64) + 1000 * step, 0)
        his = np.where(kinds == int(OpKind.RANGE),
                       ks + np.uint64(120), 0).astype(np.uint64)
        b = OpBatch(kinds, ks, vals, his)
        r1, r2 = sh.apply(b), ref.apply(b)
        assert r1.found.tolist() == r2.found.tolist(), step
        assert r1.values.tolist() == r2.values.tolist(), step
        for i in np.nonzero(kinds == int(OpKind.RANGE))[0]:
            assert r1.range_hits[i][0].tolist() \
                == r2.range_hits[i][0].tolist(), (step, i)
            assert r1.range_hits[i][1].tolist() \
                == r2.range_hits[i][1].tolist(), (step, i)
        sh.maintain(2)
        ref.maintain(2)
    sh.drain()
    ref.drain()
    assert sh.count_live() == ref.count_live()


def test_sharded_hash_partition_conformance():
    sh = make_engine("sharded:nbtree", shards=4, partition="hash",
                     **CONFIGS["nbtree"])
    keys = np.arange(1, 513, dtype=np.uint64)
    sh.apply(OpBatch.inserts(keys, np.arange(512, dtype=np.int64)))
    res = sh.apply(OpBatch.ranges([100], [200]))
    rk, rv = res.range_hits[0]
    assert rk.tolist() == list(range(100, 201))     # merged sorted fan-out
    assert rv.tolist() == list(range(99, 200))
    st = sh.stats()
    assert st.shards == 4 and st.total_pairs == 512


# --------------------------------------------------- sharded odds and ends
def test_sharded_empty_and_prebootstrap_batches():
    sh = make_engine("sharded:nbtree", **CONFIGS["nbtree"])
    res = sh.apply(OpBatch.empty())
    assert len(res.kinds) == 0
    # a query-only first batch bootstraps from its keys and answers empty.
    res = sh.apply(OpBatch.queries([5, 10]))
    assert not res.found.any()
    res = sh.apply(OpBatch.ranges([1], [100]))
    assert res.range_hits[0][0].tolist() == []


def test_sharded_registry_names():
    with pytest.raises(KeyError):
        make_engine("sharded:no-such-base")
    eng = make_engine("sharded:lsm", shards=2, mem_pairs=64)
    assert eng.name == "sharded:lsm"
    eng.apply(OpBatch.inserts(np.arange(1, 65, dtype=np.uint64),
                              np.arange(64, dtype=np.int64)))
    s = eng.stats()
    assert s.shards == 2 and len(s.shard_debt) == 2
    assert s.n_inserts == 64 and s.total_pairs == 64


# ------------------------------------------------------ hot-shard rebalance
@pytest.mark.parametrize("base", ["nbtree", "lsm"])
def test_hot_shard_split_keeps_stats_monotone(base):
    """Moving hotspot forces rebalances; aggregate I/O must stay monotone
    and the visible state must stay exact across every split."""
    wl = make_workload("hotspot-shift", key_space=1 << 14, n_ops=768,
                       batch_size=128, preload=256, seed=5)
    sh = make_engine(f"sharded:{base}", shards=2, min_split_pairs=96,
                     skew_factor=1.5, **CONFIGS[base])
    model = {}
    pre = wl.preload_batch()
    sh.apply(pre)
    model.update(zip(pre.keys.tolist(), pre.vals.tolist()))
    last_io, last_seeks = sh.io_time_s(), sh.stats().io_seeks
    last_probes = 0
    for b in wl.batches():
        res = sh.apply(b)
        for i in range(len(b)):
            kind = OpKind(int(b.kinds[i]))
            k = int(b.keys[i])
            if kind is OpKind.INSERT:
                model[k] = int(b.vals[i])
            elif kind is OpKind.DELETE:
                model.pop(k, None)
            elif kind is OpKind.QUERY:
                want = model.get(k)
                assert bool(res.found[i]) == (want is not None)
                if want is not None:
                    assert int(res.values[i]) == want
        sh.maintain(4)
        st = sh.stats()
        assert st.io_time_s >= last_io        # monotone across rebalances
        assert st.io_seeks >= last_seeks
        assert st.bloom_probes >= last_probes  # retired shards fold in too
        last_io, last_seeks = st.io_time_s, st.io_seeks
        last_probes = st.bloom_probes
    assert sh.n_splits > 0, "hotspot stream must force at least one split"
    assert sh.stats().bloom_probes > 0        # both bases consult filters
    sh.drain()
    st = sh.stats()
    assert st.shards == 2 + sh.n_splits
    assert st.total_pairs == len(model)
    assert st.pending_debt == 0 and len(st.shard_debt) == st.shards


# ------------------------------------------------- 4-shard conformance suite
def _stream():
    wl = make_workload("delete-churn", key_space=4096, n_ops=320,
                       batch_size=64, preload=192, range_selectivity=0.01,
                       seed=11)
    pre = wl.preload_batch()
    batches = list(wl.batches())
    model = dict(zip(pre.keys.tolist(), pre.vals.tolist()))
    expected = []
    for b in batches:
        exp = []
        for i in range(len(b)):
            kind = OpKind(int(b.kinds[i]))
            k = int(b.keys[i])
            if kind is OpKind.INSERT:
                model[k] = int(b.vals[i])
                exp.append(None)
            elif kind is OpKind.DELETE:
                model.pop(k, None)
                exp.append(None)
            elif kind is OpKind.QUERY:
                exp.append(model.get(k))
            else:
                hi = int(b.his[i])
                ks = sorted(x for x in model if k <= x <= hi)
                exp.append((ks, [model[x] for x in ks]))
        expected.append(exp)
    return pre, batches, expected, len(model)


@pytest.fixture(scope="module")
def churn_stream():
    return _stream()


@pytest.mark.parametrize("name", FIVE_TIERS)
def test_sharded_conformance(name, churn_stream):
    pre, batches, expected, n_live = churn_stream
    eng = make_engine(f"sharded:{name}", shards=4, min_split_pairs=64,
                      skew_factor=2.0, **CONFIGS[name])
    eng.apply(pre)
    eng.drain()
    last_io = eng.io_time_s()

    for bi, (b, exp) in enumerate(zip(batches, expected)):
        res = eng.apply(b)
        assert not res.range_truncated.any(), (name, bi)
        for i in range(len(b)):
            kind = OpKind(int(b.kinds[i]))
            if kind is OpKind.QUERY:
                want = exp[i]
                assert bool(res.found[i]) == (want is not None), (name, bi, i)
                if want is not None:
                    assert int(res.values[i]) == want, (name, bi, i)
            elif kind is OpKind.RANGE:
                rk, rv = res.range_hits[i]
                assert rk.tolist() == exp[i][0], (name, bi, i)
                assert rv.tolist() == exp[i][1], (name, bi, i)
        eng.maintain(2)
        io = eng.io_time_s()            # summed cost must never decrease
        assert io >= last_io, (name, bi)
        last_io = io

    eng.drain()
    s = eng.stats()
    assert s.io_time_s >= last_io, name
    assert s.total_pairs == n_live, (name, s.total_pairs, n_live)
    assert s.pending_debt == 0, name
    assert s.physical_pairs >= s.total_pairs, name
    assert s.shards >= 4 and len(s.shard_debt) == s.shards, name
    assert s.n_inserts + s.n_deletes + s.n_queries + s.n_ranges \
        == len(pre) + sum(len(b) for b in batches), name
