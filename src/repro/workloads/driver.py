"""Workload driver: stream any mix through any registered engine.

``run_workload(engine, workload)`` applies the preload then the mixed
stream batch by batch, calling ``engine.maintain(budget)`` between batches
(the serving-loop deamortization knob), and records per-op latencies into
per-kind :class:`LatencyHistogram`s.  The report carries p50/p99/p100/mean
per kind, the histogram buckets, and the engine's final ``stats()``
snapshot — everything ``benchmarks/fig_mixed.py`` and the CI smoke job
need, in JSON-ready form.

CLI (used by the CI benchmark-smoke job)::

    PYTHONPATH=src python -m repro.workloads.driver \
        --engines all --mix ycsb-a --ops 512 --batch 64 --out runs/mixed.json

``--shards N`` (N > 1) wraps every requested engine in the sharded layer
(``sharded:<name>``, DESIGN.md §6) with ``--partition`` choosing range or
hash placement.  ``--arrival poisson --rate R [--duration T]`` switches
from closed-loop (service time only) to *open-loop* serving through the
ingest frontend (``repro.ingest``, DESIGN.md §7): timestamped arrivals,
bounded queue + admission control, group commit, end-to-end latency =
queueing + service.  ``--list-engines`` / ``--list-mixes`` enumerate the
registries.  Emitted JSON carries ``schema_version`` (top level and per
report) so bench trajectory files are comparable across PRs.

**Multiple streams** (DESIGN.md §10): repeat ``--mix`` to drive one
stream *per tenant*, each namespace-encoded into its own key interval
(``repro.tenancy``) and reported with its own per-stream latency
histograms.  Closed-loop, the streams interleave round-robin batch by
batch; with ``--arrival`` they serve open-loop through the multi-tenant
frontend (weighted-fair admission; ``--weights`` sets DRR shares,
``--unfair`` swaps back the shared FIFO baseline)::

    PYTHONPATH=src python -m repro.workloads.driver --engines nbtree \
        --mix insert-heavy --mix point-read-heavy --weights 2 1 \
        --arrival poisson --rate 4000 --out runs/two_tenants.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.core.engine_api import (FIVE_TIERS, OpKind, StorageEngine,
                                   available_engines, make_engine)
from repro.obs.metrics import BUCKET_EDGES_S, LogBucketHistogram, ObsConfig

from .generator import MIXES, Workload, make_workload

#: bump when the emitted JSON layout changes (stamped into every report so
#: trajectory files from different PRs are comparable — or visibly not).
#: v3: EngineStats bloom_* counters; open-loop (``--arrival``) reports.
#: v4: EngineStats maintain-unit wall-clock fields (units, total,
#: p50/p99/p100 per unit) — real device-tier maintenance service cost.
#: v5: EngineStats.applied_lsn; open-loop reports gain a ``durability``
#: section (WAL/checkpoint counters + charged fsync service) when the
#: frontend runs with a DurabilityConfig (DESIGN.md §9).
#: v6: multi-stream reports (repeated ``--mix``): closed-loop ``streams``
#: sections with per-stream per-kind histograms + namespace intervals;
#: open-loop multi-tenant reports (``tenants``/``admission``/``fair``
#: sections from the tenancy frontend, DESIGN.md §10).
#: v7: closed-loop per-kind histograms switch to the shared bounded
#: log-bucket implementation (``repro.obs.metrics``): same bucket edges
#: and JSON shape, count/mean/p100 still exact, but p50/p99 are now
#: bucket-interpolated (within one bucket of the exact sample quantile)
#: instead of exact-sample percentiles; open-loop reports gain an ``obs``
#: section (windowed timeline + stall attribution + trace block) when
#: driven with ``--trace``/``--metrics-window`` (DESIGN.md §11).
#: v8: replicated open-loop reports (``--replicas``): top-level SLO report
#: plus a ``replication`` section — ReplicationConfig, acked commit/row
#: counts, failover event list (detection/promotion/RTO timestamps),
#: per-group availability timelines, and the chaos schedule when
#: ``--chaos`` is set (DESIGN.md §12).
SCHEMA_VERSION = 8


class LatencyHistogram:
    """Bounded log-bucket latency histogram (per-kind driver reports).

    A thin façade over the shared :class:`repro.obs.metrics.
    LogBucketHistogram`: 4 buckets/decade across 1 ns .. ~1000 s,
    out-of-range samples clamped into the edge buckets (zero-cost ops —
    e.g. buffered sim-tier inserts — land in the first bucket) so
    ``sum(bucket_counts) == count`` always holds.  Memory is O(buckets),
    not O(samples): count, mean, and p100 stay exact, while p50/p99 are
    interpolated within the owning bucket (within one bucket width of the
    exact sample quantile — property-tested in ``tests/test_obs.py``).
    """

    EDGES = BUCKET_EDGES_S                  # seconds

    def __init__(self):
        self._h = LogBucketHistogram()

    @property
    def count(self) -> int:
        return self._h.count

    def add(self, latencies_s) -> None:
        self._h.add_many(np.asarray(latencies_s, np.float64))

    def percentile(self, q: float) -> float:
        """Quantile at ``q`` in [0, 100]; exact at q=0 and q=100."""
        return self._h.quantile(q / 100.0)

    def to_dict(self) -> dict:
        s = self._h.summary()
        del s["p999_s"]         # per-kind blocks predate the p99.9 field
        return s


def run_workload(engine: StorageEngine, workload: Workload, *,
                 maintain_budget: int = 1) -> dict:
    """Drive ``workload`` through ``engine``; returns the JSON-ready report."""
    spec = workload.spec
    hists = {k: LatencyHistogram() for k in OpKind}

    pre = workload.preload_batch()
    engine.apply(pre)
    engine.drain()
    io_after_preload = engine.io_time_s()

    max_debt = 0
    for batch in workload.batches():
        res = engine.apply(batch)
        for k in OpKind:
            hists[k].add(res.latencies(k))
        max_debt = max(max_debt, engine.maintain(maintain_budget))
    debt_before_drain = engine.maintain(0)
    engine.drain()

    stats = engine.stats()
    return {
        "schema_version": SCHEMA_VERSION,
        "engine": engine.name,
        "workload": dataclasses.asdict(spec) | {
            "mix": {OpKind(k).name.lower(): p for k, p in spec.mix.items()}},
        "maintain_budget": maintain_budget,
        "preload_pairs": len(pre),
        "io_time_preload_s": io_after_preload,
        "max_pending_debt": int(max_debt),
        "pending_debt_before_drain": int(debt_before_drain),
        "per_kind": {OpKind(k).name.lower(): h.to_dict()
                     for k, h in hists.items() if h.count},
        "stats": dataclasses.asdict(stats),
    }


def run_open_workload(engine: StorageEngine, workload: Workload, *,
                      arrival: str, rate: float,
                      duration_s: float | None = None,
                      maintain_budget: int = 1,
                      frontend_config=None,
                      obs: ObsConfig | None = None,
                      chaos_spec: str | None = None) -> dict:
    """Open-loop counterpart of :func:`run_workload` (DESIGN.md §7).

    Timestamps ``workload``'s op stream with the named arrival process and
    serves it through the ingest frontend; the report mirrors the
    closed-loop shape with the SLO section under ``"open_loop"``.
    ``maintain_budget`` (the per-commit deamortization knob) shapes the
    default frontend config; an explicit ``frontend_config`` wins
    wholesale.  ``obs`` (DESIGN.md §11) adds a windowed-metrics timeline,
    stall attribution, and a structured span trace under ``report["obs"]``.
    ``chaos_spec`` (DESIGN.md §12) schedules faults against the frontend
    itself — the DSL's default target ``"wal"``.
    """
    from repro.ingest import (FrontendConfig, make_arrivals, make_trace,
                              run_open_loop)
    from repro.wal import FaultSchedule

    if frontend_config is None:
        frontend_config = FrontendConfig(maintain_budget=maintain_budget)
    process = make_arrivals(arrival, rate)
    trace = make_trace(workload, process, duration_s=duration_s)
    chaos = FaultSchedule.parse(chaos_spec) if chaos_spec else None
    report = run_open_loop(engine, trace, config=frontend_config, obs=obs,
                           chaos=chaos)
    report["schema_version"] = SCHEMA_VERSION
    report["workload"] = dataclasses.asdict(workload.spec) | {
        "mix": {OpKind(k).name.lower(): p
                for k, p in workload.spec.mix.items()}}
    return report


def run_multi_workload(engine: StorageEngine, workloads: list, *,
                       maintain_budget: int = 1, namespace=None) -> dict:
    """Closed-loop multi-stream drive: one namespace per workload.

    Stream *i*'s keys are encoded into tenant *i*'s interval
    (``repro.tenancy.NamespaceMap``) and the streams interleave
    round-robin batch by batch — deterministic contention on one shared
    engine — with latencies recorded into per-stream per-kind histograms.
    """
    from repro.core.engine_api import OpBatch
    from repro.tenancy import NamespaceMap

    ns = namespace or NamespaceMap()
    pre = [ns.encode_batch(i, wl.preload_batch())
           for i, wl in enumerate(workloads)]
    pre = [b for b in pre if len(b)]
    n_pre = sum(len(b) for b in pre)
    if pre:
        engine.apply(OpBatch.concat(pre))
        engine.drain()

    hists = [{k: LatencyHistogram() for k in OpKind} for _ in workloads]
    iters = [wl.batches() for wl in workloads]
    alive = list(range(len(workloads)))
    max_debt = 0
    while alive:
        for i in list(alive):
            batch = next(iters[i], None)
            if batch is None:
                alive.remove(i)
                continue
            res = engine.apply(ns.encode_batch(i, batch))
            for k in OpKind:
                hists[i][k].add(res.latencies(k))
            max_debt = max(max_debt, engine.maintain(maintain_budget))
    debt_before_drain = engine.maintain(0)
    engine.drain()

    stats = engine.stats()
    streams = []
    for i, wl in enumerate(workloads):
        lo, hi = ns.tenant_interval(i)
        streams.append({
            "stream": i,
            "workload": dataclasses.asdict(wl.spec) | {
                "mix": {OpKind(k).name.lower(): p
                        for k, p in wl.spec.mix.items()}},
            "interval": [int(lo), int(hi)],
            "live_pairs": int(engine.count_live_range(lo, hi)),
            "per_kind": {OpKind(k).name.lower(): h.to_dict()
                         for k, h in hists[i].items() if h.count},
        })
    return {
        "schema_version": SCHEMA_VERSION,
        "engine": engine.name,
        "namespace": ns.describe(),
        "maintain_budget": maintain_budget,
        "preload_pairs": n_pre,
        "max_pending_debt": int(max_debt),
        "pending_debt_before_drain": int(debt_before_drain),
        "streams": streams,
        "stats": dataclasses.asdict(stats),
    }


def run_open_multi_workload(engine: StorageEngine, workloads: list, *,
                            arrival: str, rate: float,
                            duration_s: float | None = None,
                            maintain_budget: int = 1, weights=None,
                            fair: bool = True,
                            obs: ObsConfig | None = None) -> dict:
    """Open-loop multi-stream drive through the multi-tenant frontend.

    One tenant per workload; every tenant gets its own instance of the
    named arrival process at ``rate`` (its trace seeded by its workload
    seed, so streams stay independent).  ``weights`` sets the DRR shares
    (default: equal); ``fair=False`` is the shared-FIFO baseline.
    """
    from repro.ingest import FrontendConfig, make_arrivals, make_trace
    from repro.tenancy import TenantConfig, run_multi_tenant

    tenants = [TenantConfig(i, name=wl.spec.name,
                            weight=(float(weights[i]) if weights else 1.0))
               for i, wl in enumerate(workloads)]
    traces = {i: make_trace(wl, make_arrivals(arrival, rate),
                            duration_s=duration_s)
              for i, wl in enumerate(workloads)}
    cfg = FrontendConfig(maintain_budget=maintain_budget)
    report = run_multi_tenant(engine, tenants, traces, config=cfg, fair=fair,
                              obs=obs)
    report["schema_version"] = SCHEMA_VERSION
    report["workloads"] = [
        dataclasses.asdict(wl.spec) | {
            "mix": {OpKind(k).name.lower(): p
                    for k, p in wl.spec.mix.items()}}
        for wl in workloads]
    return report


def run_replicated_workload(engine_name: str, workload: Workload, *,
                            arrival: str, rate: float,
                            duration_s: float | None = None,
                            groups: int = 4, replicas: int = 2,
                            ack_mode: str = "quorum",
                            chaos_spec: str | None = None,
                            maintain_budget: int = 1,
                            obs: ObsConfig | None = None,
                            directory: str | None = None,
                            base_kw: dict | None = None) -> dict:
    """Replicated open loop (DESIGN.md §12): R WAL-shipped copies per range.

    Serves the open-loop trace through :class:`repro.replication.
    ReplicatedFrontend` — ``groups`` range partitions, each a primary plus
    ``replicas - 1`` replicas acking at ``ack_mode`` ("quorum" or
    "primary").  ``chaos_spec`` is the ``--chaos`` DSL
    (``kind@t[:target[:arg[:dur]]]`` joined with ``;``, see
    :meth:`repro.wal.FaultSchedule.parse`); the report gains a
    ``"replication"`` section with failover events and per-group
    availability timelines.  WAL segment directories live under
    ``directory`` (a temp dir when None).
    """
    import tempfile

    from repro.ingest import FrontendConfig, make_arrivals, make_trace
    from repro.replication import ReplicationConfig, run_replicated
    from repro.wal import FaultSchedule

    def factory():
        return make_engine(engine_name, **(base_kw or {}))

    process = make_arrivals(arrival, rate)
    trace = make_trace(workload, process, duration_s=duration_s)
    chaos = FaultSchedule.parse(chaos_spec) if chaos_spec else None
    rep = ReplicationConfig(replicas=replicas, ack_mode=ack_mode)
    cfg = FrontendConfig(maintain_budget=maintain_budget)
    if directory is None:
        with tempfile.TemporaryDirectory(prefix="repro_repl_") as d:
            report = run_replicated(factory, trace, d, groups=groups,
                                    replication=rep, config=cfg,
                                    chaos=chaos, obs=obs)
    else:
        report = run_replicated(factory, trace, directory, groups=groups,
                                replication=rep, config=cfg,
                                chaos=chaos, obs=obs)
    report["schema_version"] = SCHEMA_VERSION
    report["workload"] = dataclasses.asdict(workload.spec) | {
        "mix": {OpKind(k).name.lower(): p
                for k, p in workload.spec.mix.items()}}
    return report


# ---------------------------------------------------------------- CLI harness
_SMALL_CONFIGS = {
    # tiny-footprint constructor kwargs for smoke runs (CI, demos).
    "nbtree": dict(f=3, sigma=1024),
    "nbtree-basic": dict(f=3, sigma=1024),
    "nbtree-nobloom": dict(f=3, sigma=1024),
    "lsm": dict(mem_pairs=1024),
    "blsm": dict(mem_pairs=1024),
    "btree": {},
    "bepsilon": dict(node_bytes=1 << 16, cached_levels=1),
    "jax-nbtree": dict(f=4, sigma=512, max_nodes=256),
}


def _resolve_engine_names(engines, parser: argparse.ArgumentParser) -> tuple:
    """'all' -> the five paper tiers; anything unknown is a clean CLI error."""
    if engines == ["all"]:
        return FIVE_TIERS
    known = set(available_engines())
    bad = [n for n in engines if n not in known]
    if bad:
        parser.error(f"unknown engine(s): {', '.join(sorted(bad))}; "
                     f"registered: {', '.join(available_engines())} "
                     "(--list-engines to enumerate)")
    return tuple(engines)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engines", nargs="+", default=["all"],
                    help="engine names, or 'all' for the five paper tiers "
                         f"({', '.join(FIVE_TIERS)}); see --list-engines")
    ap.add_argument("--list-engines", action="store_true",
                    help="print the registered engine names and exit")
    ap.add_argument("--list-mixes", action="store_true",
                    help="print the named workload mixes and exit")
    ap.add_argument("--mix", action="append", choices=sorted(MIXES),
                    help="workload mix; repeat for one stream per tenant "
                         "(multi-stream mode, DESIGN.md §10). Default: ycsb-a")
    ap.add_argument("--weights", nargs="+", type=float, default=None,
                    help="multi-stream fair-share weights, one per --mix")
    ap.add_argument("--unfair", action="store_true",
                    help="multi-stream open loop: shared-FIFO baseline "
                         "instead of weighted-fair admission")
    ap.add_argument("--ops", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--preload", type=int, default=2048)
    ap.add_argument("--key-space", type=int, default=1 << 20)
    ap.add_argument("--dist", choices=("uniform", "zipfian", "hotspot"),
                    default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload stream seed (same seed -> same op stream)")
    ap.add_argument("--maintain-budget", type=int, default=1)
    ap.add_argument("--shards", type=int, default=1,
                    help="N > 1 wraps each engine as sharded:<name> with N "
                         "range-partitioned shards (DESIGN.md §6)")
    ap.add_argument("--partition", choices=("range", "hash"), default="range")
    ap.add_argument("--arrival", choices=("poisson", "mmpp", "diurnal"),
                    default=None,
                    help="open-loop mode: serve through the ingest frontend "
                         "with this arrival process (DESIGN.md §7)")
    ap.add_argument("--replicas", type=int, default=0, metavar="R",
                    help="replicated open loop (DESIGN.md §12): R WAL-"
                         "shipped copies per range partition (--shards sets "
                         "the group count); needs --arrival")
    ap.add_argument("--ack", choices=("quorum", "primary"), default="quorum",
                    help="replicated ack mode: wait for a majority of "
                         "copies (quorum, default) or the primary's fsync "
                         "only (faster, loses acked tail on failover)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="fault-injection schedule for the open loop: "
                         "';'-joined kind@t[:target[:arg[:dur]]] events, "
                         "kinds crash|fsync_stall|latency_spike|"
                         "torn_segment|bit_flip; with --replicas, targets "
                         "like g0/primary, g1/r0, g2 (group-wide); without, "
                         "the default target 'wal' hits the single-engine "
                         "frontend; e.g. 'crash@0.05:"
                         "g0/primary;latency_spike@0.1:g1:8:0.05'")
    ap.add_argument("--rate", type=float, default=10_000.0,
                    help="open-loop offered rate, ops/second (poisson/"
                         "diurnal mean; mmpp burst rate)")
    ap.add_argument("--duration", type=float, default=None,
                    help="open-loop trace window in seconds (default: the "
                         "full --ops stream)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="open-loop mode: save a Chrome trace_event JSON "
                         "of frontend spans here (load in Perfetto / "
                         "chrome://tracing; DESIGN.md §11)")
    ap.add_argument("--metrics-window", type=float, default=None,
                    metavar="SECONDS",
                    help="open-loop mode: windowed-metrics timeline width "
                         "in sim seconds (enables the report's 'obs' "
                         "section; implied 1.0 when --trace is set)")
    ap.add_argument("--out", default="runs/driver_report.json",
                    help="write the JSON report here")
    args = ap.parse_args(argv)

    if args.list_engines:
        for name in available_engines():
            print(name)
        print("sharded:<base>  (any of the above via --shards N)")
        return
    if args.list_mixes:
        for name in sorted(MIXES):
            kinds = {OpKind(k).name.lower(): p for k, p in MIXES[name].items()}
            print(f"{name}: {kinds}")
        return

    names = _resolve_engine_names(args.engines, ap)
    mixes = args.mix or ["ycsb-a"]
    if args.weights is not None and len(args.weights) != len(mixes):
        ap.error("--weights needs exactly one value per --mix")
    if args.chaos and not args.arrival:
        ap.error("--chaos needs open-loop mode (--arrival; replicated "
                 "targets additionally need --replicas R)")
    if args.replicas:
        if not args.arrival:
            ap.error("--replicas needs open-loop mode (--arrival)")
        if len(mixes) > 1:
            ap.error("--replicas runs a single stream (one --mix)")
    obs = None
    if args.trace or args.metrics_window is not None:
        if not args.arrival:
            ap.error("--trace/--metrics-window need open-loop mode "
                     "(--arrival)")
        if len(names) > 1 and args.trace:
            ap.error("--trace needs a single --engines value (one trace "
                     "file per run)")
        obs = ObsConfig(window_s=args.metrics_window or 1.0,
                        trace_path=args.trace)
    overrides = dict(n_ops=args.ops, batch_size=args.batch,
                     preload=args.preload, key_space=args.key_space,
                     seed=args.seed)
    if args.dist:
        overrides["dist"] = args.dist

    reports = []
    for name in names:
        base_kw = _SMALL_CONFIGS.get(name, {})
        if args.shards > 1:
            engine = make_engine(f"sharded:{name}", shards=args.shards,
                                 partition=args.partition, **base_kw)
        else:
            engine = make_engine(name, **base_kw)
        if len(mixes) > 1:
            # one stream per mix, each in its own namespace; decorrelate
            # stream seeds the same way the scenario catalog does.
            workloads = [make_workload(m, **overrides
                                       | {"seed": args.seed * 1000 + i})
                         for i, m in enumerate(mixes)]
            if args.arrival:
                report = run_open_multi_workload(
                    engine, workloads, arrival=args.arrival, rate=args.rate,
                    duration_s=args.duration,
                    maintain_budget=args.maintain_budget,
                    weights=args.weights, fair=not args.unfair, obs=obs)
                reports.append(report)
                ol = report["open_loop"]
                print(f"{engine.name:>14} ({report['stats']['clock']}) "
                      f"{len(mixes)} streams +{args.arrival}@{args.rate:g}/s "
                      f"fair={ol['fair']}: shed={ol['n_shed']} "
                      f"util={ol['server']['utilization']:.2f}")
                for tid, t in sorted(ol["tenants"].items()):
                    sub = t["open_loop"]
                    ins = sub["per_kind_e2e"].get("insert", {})
                    print(f"    stream {tid} ({t['name']}, w={t['weight']:g})"
                          f": done={sub['n_done']} shed={sub['n_shed']} "
                          f"insert p99.9={ins.get('p999_s', 0)*1e3:.3f}ms "
                          f"live={t['live_pairs']}")
            else:
                report = run_multi_workload(
                    engine, workloads, maintain_budget=args.maintain_budget)
                reports.append(report)
                print(f"{engine.name:>14} ({report['stats']['clock']}) "
                      f"{len(mixes)} streams closed-loop: "
                      f"pairs={report['stats']['total_pairs']}")
                for s in report["streams"]:
                    line = " ".join(
                        f"{kind}[p50={h['p50_s']*1e3:.3f}ms "
                        f"p99={h['p99_s']*1e3:.3f}ms]"
                        for kind, h in s["per_kind"].items())
                    print(f"    stream {s['stream']} "
                          f"({s['workload']['name']}): {line} "
                          f"live={s['live_pairs']}")
            continue
        workload = make_workload(mixes[0], **overrides)
        if args.replicas:
            report = run_replicated_workload(
                name, workload, arrival=args.arrival, rate=args.rate,
                duration_s=args.duration, groups=max(1, args.shards),
                replicas=args.replicas, ack_mode=args.ack,
                chaos_spec=args.chaos,
                maintain_budget=args.maintain_budget, obs=obs,
                base_kw=base_kw)
            reports.append(report)
            rep = report["replication"]
            ins = report["per_kind_e2e"].get("insert", {})
            down = sum(a["downtime_s"] for a in rep["availability"])
            print(f"{name:>14} R={args.replicas}/{args.ack} "
                  f"x{rep['n_groups']} groups {mixes[0]}+{args.arrival}"
                  f"@{args.rate:g}/s: done={report['n_done']} "
                  f"shed={report['n_shed']} acked={rep['acked_commits']} "
                  f"failovers={len(rep['failovers'])} "
                  f"downtime={down*1e3:.1f}ms "
                  f"insert p99.9={ins.get('p999_s', 0)*1e3:.3f}ms")
            continue
        if args.arrival:
            report = run_open_workload(engine, workload,
                                       arrival=args.arrival, rate=args.rate,
                                       duration_s=args.duration,
                                       maintain_budget=args.maintain_budget,
                                       obs=obs, chaos_spec=args.chaos)
            reports.append(report)
            ol = report["open_loop"]
            ins = ol["per_kind_e2e"].get("insert", {})
            print(f"{engine.name:>14} ({report['stats']['clock']}) "
                  f"{mixes[0]}+{args.arrival}@{args.rate:g}/s: "
                  f"util={ol['server']['utilization']:.2f} "
                  f"shed={ol['n_shed']} "
                  f"e2e insert p50={ins.get('p50_s', 0)*1e3:.3f}ms "
                  f"p99.9={ins.get('p999_s', 0)*1e3:.3f}ms "
                  f"debt_max={ol['stalls']['debt_max']}")
            if obs is not None and "obs" in ol:
                ob = ol["obs"]
                print(f"    obs: {ob['n_windows']} windows "
                      f"stall_free={ob['stall_free_pct']:.1f}% "
                      f"fluctuation={ob['fluctuation_score']:.3f} "
                      f"trace_events={ob['trace']['events']}"
                      + (f" -> {args.trace}" if args.trace else ""))
            continue
        report = run_workload(engine, workload,
                              maintain_budget=args.maintain_budget)
        reports.append(report)
        pk = report["per_kind"]
        line = " ".join(
            f"{kind}[p50={h['p50_s']*1e3:.3f}ms p99={h['p99_s']*1e3:.3f}ms "
            f"p100={h['p100_s']*1e3:.3f}ms]" for kind, h in pk.items())
        print(f"{engine.name:>14} ({report['stats']['clock']}) {mixes[0]}: "
              f"{line} pairs={report['stats']['total_pairs']} "
              f"shards={report['stats']['shards']}")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION,
                       "mix": mixes[0] if len(mixes) == 1 else list(mixes),
                       "seed": args.seed, "shards": args.shards,
                       "arrival": args.arrival,
                       "reports": reports}, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
