"""Device-tier NB-tree: the paper's index as a composable JAX module.

Architecture (DESIGN.md §2-3) — the split every production serving engine
uses (vLLM block manager, LevelDB manifest): a *host control plane* runs the
paper's s-tree algorithm (flush / SNodeSplit / single-recursive-call /
bounded maintenance quota = deamortization), while the *device data plane*
keeps all key/value runs, pivot tables and Bloom bit-arrays as flat padded
arrays in (simulated) HBM and executes the hot operations with the Pallas
kernels:

  * ``insert_batch``  — sorted-batch merge into the root run (merge kernel),
  * ``query_batch``   — one fused jitted descent: Bloom probe + lockstep
                        binary search per level, first (= freshest) hit wins,
  * ``maintain``      — up to ``max_units`` child-merge/split work units per
                        call: the serving-loop analogue of the paper's
                        1/sigma-per-insert deamortization (no allocator or
                        compaction stall can exceed the per-step budget).

Range queries (DESIGN.md §4): ``range_query_batch(lo, hi, max_results)``
serves inclusive scans ``[lo, hi]`` with the same host/device split as point
lookups.  The *host control plane* routes each query over its pivot
structure, collecting — in pre-order, so ancestors (fresher data) come
first — the ids of every node whose key interval intersects the range; the
*device data plane* then runs one fused jitted pass that (a) lower/upper
bound binary-searches every candidate run in lockstep, (b) gathers the
matching spans into a fixed-capacity candidate tile, (c) resolves per-key
freshness by a single stable sort over the level-major candidates (the
range generalization of the point lookup's first-hit-wins rule: for
duplicate keys, the copy from the shallower level — or leftmost in-run
position — survives), (d) filters ``TOMBSTONE32`` delta-deletes, and (e)
returns sorted, KEY_MAX-padded results with a live count and a truncation
flag.  Bloom filters are not consulted: they cannot answer range
predicates.  The standalone ``ops.range_scan`` Pallas kernel implements the
same search+gather step for single-run scans (LSM-style baselines,
microbenchmarks).

Static-shape adaptations vs. the paper (recorded in DESIGN.md §2): runs are
fixed-capacity rows of a node table (RUN_CAP >= f*(sigma+1) + sigma, the
paper's Sec. 5.1 sibling bound plus one incoming flush); device rows are
always compacted on rewrite, the lazy-removal watermark living in the host
control plane only (rewriting an HBM row is a stream copy, the thing the
paper's lazy removal avoids on *disk* seeks).

Device keys are uint32 (TPU lane width), values int32 payload references;
``TOMBSTONE32`` realizes delta-record deletions (paper Sec. 3.2.2).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.ref import bloom_hash_ref

KEY_MAX32 = np.uint32(0xFFFFFFFF)
TOMBSTONE32 = np.int32(-(2**31))
TILE = 1024


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


class _HostNode:
    """Control-plane view of an s-node (structure only, no key data)."""

    __slots__ = ("nid", "skeys", "children", "count", "parent")

    def __init__(self, nid: int, parent=None):
        self.nid = nid
        self.skeys: list[int] = []
        self.children: list[_HostNode] = []
        self.count = 0           # live pairs in the device run row
        self.parent: _HostNode | None = parent

    @property
    def is_leaf(self):
        return not self.children


# --------------------------------------------------------------------- jit fns
@functools.partial(jax.jit, donate_argnums=(0,))
def _write_row(table, row, data):
    return table.at[row].set(data)


@functools.partial(jax.jit, static_argnames=("cap",))
def _window(row_keys, row_vals, start, length, cap: int):
    """Fixed-size (cap,) slice [start, start+length) padded with KEY_MAX."""
    idx = start + jnp.arange(cap, dtype=jnp.int32)
    k = jnp.take(row_keys, idx, mode="clip")
    v = jnp.take(row_vals, idx, mode="clip")
    mask = jnp.arange(cap, dtype=jnp.int32) < length
    return jnp.where(mask, k, jnp.uint32(KEY_MAX32)), jnp.where(mask, v, 0)


@jax.jit
def _prepare_batch(keys, vals):
    """Sort an incoming batch descending-recency-stable (newest copy first)."""
    # stable argsort keeps earlier (older) duplicates first; we want the
    # newest first, so sort the *reversed* batch.
    keys, vals = keys[::-1], vals[::-1]
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


@functools.partial(jax.jit, static_argnames=("nbits", "h"))
def _build_bloom(keys, nbits: int, h: int):
    return ops.bloom_build(keys, nbits, h)


@functools.partial(jax.jit, static_argnames=("cap",))
def _compact_tombstones(keys, vals, cap: int):
    """Leaf-level delta resolution (Sec. 3.2.2): dedup then drop deletes.

    The merge kernel keeps duplicate keys (newest copy leftmost — that is
    what makes leftmost-match point lookups see the freshest record), so a
    leaf run accumulates stale copies.  Compaction must retire the stale
    duplicates *together with* the tombstone records: dropping only the
    tombstone would resurrect the older copy it deleted.
    """
    first = jnp.concatenate(
        [jnp.ones(1, bool), keys[1:] != keys[:-1]])   # leftmost = freshest
    dead = ~first | (vals == TOMBSTONE32)
    keys = jnp.where(dead, jnp.uint32(KEY_MAX32), keys)
    order = jnp.argsort(keys, stable=True)
    keys, vals = keys[order], vals[order]
    live = jnp.sum((keys != KEY_MAX32).astype(jnp.int32))
    return keys[:cap], vals[:cap], live


@functools.partial(
    jax.jit, static_argnames=("f", "levels", "run_cap", "nbits", "h", "steps")
)
def _query_batch_impl(pivots, nchild, children, run_keys, run_vals, run_count,
                      bloom, q, *, f, levels, run_cap, nbits, h, steps):
    B = q.shape[0]
    node = jnp.zeros(B, jnp.int32)
    found = jnp.zeros(B, bool)
    out = jnp.full(B, -1, jnp.int32)
    # Bloom-effectiveness tallies (paper Sec. 5.2), reduced on device so the
    # fused call stays one round trip: probes issued, negatives that skipped
    # a run search, and positives whose search then missed (false positives).
    n_probe = jnp.int32(0)
    n_neg = jnp.int32(0)
    n_fp = jnp.int32(0)
    # the descent parks on its leaf for any iterations left after reaching
    # it; `prev` masks those repeats out of the tallies (one logical probe
    # per distinct node on each query's root-to-leaf path).
    prev = jnp.full(B, -1, jnp.int32)

    pos = bloom_hash_ref(q, h, nbits)  # (h, B), shared across levels

    for _ in range(levels + 1):
        cnt = run_count[node]
        # ---- Bloom probe (skip the run search on negative) ----------------
        w = bloom[node[None, :], pos // 32]              # (h, B)
        bit = (w >> (pos % 32).astype(jnp.uint32)) & jnp.uint32(1)
        positive = jnp.all(bit == 1, axis=0)
        probe = ~found & (cnt > 0) & (node != prev)      # filter consulted
        do = positive & probe
        # ---- lockstep binary search over the node's run -------------------
        lo = jnp.zeros(B, jnp.int32)
        hi = cnt
        for _s in range(steps):
            mid = (lo + hi) >> 1
            key = run_keys[node, jnp.clip(mid, 0, run_cap - 1)]
            right = (lo < hi) & (key < q)
            lo = jnp.where(right, mid + 1, lo)
            hi = jnp.where(right, hi, mid)
        hitk = run_keys[node, jnp.clip(lo, 0, run_cap - 1)]
        hit = do & (lo < cnt) & (hitk == q)
        out = jnp.where(hit & ~found, run_vals[node, jnp.clip(lo, 0, run_cap - 1)], out)
        found = found | hit
        n_probe += jnp.sum(probe.astype(jnp.int32))
        n_neg += jnp.sum((probe & ~positive).astype(jnp.int32))
        n_fp += jnp.sum((do & ~hit).astype(jnp.int32))
        # ---- descend via pivots (cross-s-node linkage) ---------------------
        pv = pivots[node]                                # (B, f-1)
        ci = jnp.sum((q[:, None] >= pv).astype(jnp.int32), axis=1)
        child = children[node, jnp.clip(ci, 0, f - 1)]
        prev = node
        node = jnp.where(nchild[node] > 0, child, node)
    present = found & (out != TOMBSTONE32)
    return present, out, n_probe, n_neg, n_fp


@functools.partial(
    jax.jit, static_argnames=("cap", "max_results", "run_cap", "steps"))
def _range_query_batch_impl(run_keys, run_vals, run_count, nodes, lo, hi, *,
                            cap, max_results, run_cap, steps):
    B, M = nodes.shape
    valid_node = nodes >= 0                      # (B, M), -1 = padding
    nid = jnp.maximum(nodes, 0)
    cnt = jnp.where(valid_node, run_count[nid], 0)
    lo_b, hi_b = lo[:, None], hi[:, None]

    # ---- lockstep lower/upper bound over every candidate run --------------
    def bound(q, closed):
        l = jnp.zeros((B, M), jnp.int32)
        h = cnt                                  # excludes KEY_MAX padding
        for _ in range(steps):
            mid = (l + h) >> 1
            key = run_keys[nid, jnp.clip(mid, 0, run_cap - 1)]
            go = (l < h) & ((key <= q) if closed else (key < q))
            l = jnp.where(go, mid + 1, l)
            h = jnp.where(go, h, mid)
        return l

    start = bound(lo_b, False)
    end = bound(hi_b, True)
    n_match = jnp.maximum(end - start, 0)        # per-node matches (pre-cap)

    # ---- masked gather of each matching span ------------------------------
    idx = start[..., None] + jnp.arange(cap, dtype=jnp.int32)   # (B, M, cap)
    valid = idx < end[..., None]
    safe = jnp.clip(idx, 0, run_cap - 1)
    gk = run_keys[nid[..., None], safe]
    gv = run_vals[nid[..., None], safe]
    ck = jnp.where(valid, gk, jnp.uint32(KEY_MAX32)).reshape(B, M * cap)
    cv = jnp.where(valid, gv, 0).reshape(B, M * cap)

    # ---- freshness resolution ---------------------------------------------
    # Candidates are level-major with m ordered pre-order (ancestors first)
    # and in-run position order within m (newer duplicate copies first, the
    # merge kernel's tie-break), so a *stable* sort by key puts the freshest
    # copy of every key first — the range generalization of first-hit-wins.
    order = jnp.argsort(ck, axis=1, stable=True)
    sk = jnp.take_along_axis(ck, order, axis=1)
    sv = jnp.take_along_axis(cv, order, axis=1)
    fresh = jnp.concatenate(
        [jnp.ones((B, 1), bool), sk[:, 1:] != sk[:, :-1]], axis=1)
    live = fresh & (sk != KEY_MAX32) & (sv != TOMBSTONE32)
    sk = jnp.where(live, sk, jnp.uint32(KEY_MAX32))
    sv = jnp.where(live, sv, 0)
    order2 = jnp.argsort(sk, axis=1, stable=True)
    sk = jnp.take_along_axis(sk, order2, axis=1)
    sv = jnp.take_along_axis(sv, order2, axis=1)
    total = jnp.sum(live.astype(jnp.int32), axis=1)
    truncated = (total > max_results) | jnp.any(n_match > cap, axis=1)
    return (sk[:, :max_results], sv[:, :max_results],
            jnp.minimum(total, max_results), truncated)


class NBTreeIndex:
    """Composable device-backed NB-tree index (see module docstring)."""

    def __init__(self, f: int = 4, sigma: int = 4096, *, bits_per_key: int = 10,
                 num_hashes: int = 3, max_nodes: int = 256, max_levels: int = 12):
        assert f >= 2 and sigma >= 2 * f
        self.f, self.sigma = f, sigma
        self.h = num_hashes
        self.sigma_pad = _round_up(sigma, TILE)
        self.run_cap = _round_up(f * (sigma + 1) + sigma, TILE)
        self.nbits = _round_up(self.run_cap * bits_per_key, 32 * 128)
        self.max_levels = max_levels
        self._steps = math.ceil(math.log2(self.run_cap + 1)) + 1

        self.max_nodes = max_nodes
        nw = self.nbits // 32
        self.pivots = jnp.full((max_nodes, f - 1), KEY_MAX32, jnp.uint32)
        self.children = jnp.zeros((max_nodes, f), jnp.int32)
        self.nchild = jnp.zeros((max_nodes,), jnp.int32)
        self.run_keys = jnp.full((max_nodes, self.run_cap), KEY_MAX32, jnp.uint32)
        self.run_vals = jnp.zeros((max_nodes, self.run_cap), jnp.int32)
        self.run_count = jnp.zeros((max_nodes,), jnp.int32)
        self.bloom = jnp.zeros((max_nodes, nw), jnp.uint32)

        self.root = _HostNode(0)
        self._next_id = 1
        self._pending: list[_HostNode] = []   # oversized nodes awaiting work
        self.n_items = 0
        # Bloom effectiveness (paper Sec. 5.2); see query_batch.
        self.bloom_probes = 0
        self.bloom_negative_skips = 0
        self.bloom_false_positives = 0

    # ------------------------------------------------------------------ public
    def insert_batch(self, keys, vals) -> None:
        """Merge a batch into the root run (device merge kernel).

        Oversized batches are split into sigma-sized chunks with
        backpressure maintenance between them — the bounded-latency
        contract holds per chunk (a caller that submits a giant batch has
        asked for the work; it is never deferred into later steps).
        """
        keys = jnp.asarray(keys, jnp.uint32)
        vals = jnp.asarray(vals, jnp.int32)
        n = int(keys.shape[0])
        if self.root.count + n > self.run_cap or n > self.sigma:
            for i in range(0, n, self.sigma):
                while self.root.count + self.sigma > self.run_cap:
                    if self.maintain(4) == 0 and self.root.count + self.sigma > self.run_cap:
                        break  # tree fully maintained; capacity guaranteed
                self._insert_chunk(keys[i:i + self.sigma], vals[i:i + self.sigma])
            return
        self._insert_chunk(keys, vals)

    def _insert_chunk(self, keys, vals) -> None:
        bk, bv = _prepare_batch(keys, vals)
        merged_k, merged_v = ops.merge_sorted(
            bk, bv, self.run_keys[0, : self.run_cap], self.run_vals[0])
        self.run_keys = _write_row(self.run_keys, 0, merged_k[: self.run_cap])
        self.run_vals = _write_row(self.run_vals, 0, merged_v[: self.run_cap])
        self.root.count += int(keys.shape[0])
        assert self.root.count <= self.run_cap, "root run overflow: call maintain()"
        self.run_count = self.run_count.at[0].set(self.root.count)
        self.bloom = _write_row(
            self.bloom, 0, _build_bloom(self.run_keys[0], self.nbits, self.h))
        self.n_items += int(keys.shape[0])
        if self.root.count > self.sigma and self.root not in self._pending:
            self._pending.append(self.root)

    def delete_batch(self, keys) -> None:
        keys = jnp.asarray(keys, jnp.uint32)
        self.insert_batch(keys, jnp.full(keys.shape, TOMBSTONE32, jnp.int32))

    def query_batch(self, keys):
        """(present: bool (B,), vals: int32 (B,)) — one fused device call.

        Bloom-effectiveness tallies for the batch (probes / negative skips /
        false positives, reduced on device) accumulate into
        ``bloom_probes`` / ``bloom_negative_skips`` /
        ``bloom_false_positives`` — the paper Sec. 5.2 attribution counters
        surfaced through ``EngineStats``.
        """
        q = jnp.asarray(keys, jnp.uint32)
        present, out, n_probe, n_neg, n_fp = _query_batch_impl(
            self.pivots, self.nchild, self.children, self.run_keys,
            self.run_vals, self.run_count, self.bloom, q,
            f=self.f, levels=self.max_levels, run_cap=self.run_cap,
            nbits=self.nbits, h=self.h, steps=self._steps)
        self.bloom_probes += int(n_probe)
        self.bloom_negative_skips += int(n_neg)
        self.bloom_false_positives += int(n_fp)
        return present, out

    def range_query_batch(self, lo, hi, max_results: int = 256):
        """Batched inclusive range scan [lo_b, hi_b] — one fused device call.

        Returns ``(keys uint32 (B, max_results), vals int32 (B, max_results),
        count int32 (B,), truncated bool (B,))``: per query the up-to-
        ``max_results`` freshest live pairs in the range, sorted by key and
        KEY_MAX-padded; ``count`` is the number of valid slots; ``truncated``
        flags queries whose full result did not fit (re-issue with a larger
        ``max_results`` for exact results).  ``lo > hi`` is an empty range.

        The host control plane routes each query to the nodes whose key
        interval intersects it (pre-order, ancestors first — see module
        docstring); the device pass searches, gathers, freshness-resolves
        and tombstone-filters in one jitted call.  Recompiles per distinct
        (B, routed-node-count-bucket, max_results) combination; the node
        bucket is padded to a power of two to bound recompiles.
        """
        lo = np.asarray(lo, np.uint32)
        hi = np.asarray(hi, np.uint32)
        assert lo.shape == hi.shape and lo.ndim == 1
        B = lo.shape[0]
        routes = [self._route_range(int(l), int(h)) for l, h in zip(lo, hi)]
        M = max(1, *(len(r) for r in routes)) if routes else 1
        M = 1 << (M - 1).bit_length()
        nodes = np.full((B, M), -1, np.int32)
        for b, r in enumerate(routes):
            nodes[b, : len(r)] = r
        return _range_query_batch_impl(
            self.run_keys, self.run_vals, self.run_count,
            jnp.asarray(nodes), jnp.asarray(lo), jnp.asarray(hi),
            cap=int(max_results), max_results=int(max_results),
            run_cap=self.run_cap, steps=self._steps)

    def _route_range(self, lo: int, hi: int) -> list[int]:
        """Pre-order ids of nodes whose key interval intersects [lo, hi]."""
        if lo > hi:
            return []
        out: list[int] = []

        def rec(node, nlo, nhi):
            out.append(node.nid)
            if node.is_leaf:
                return
            bounds = [nlo, *node.skeys, nhi]
            for i, c in enumerate(node.children):
                clo, chi = bounds[i], bounds[i + 1]
                if (chi is None or lo < chi) and (clo is None or hi >= clo):
                    rec(c, clo, chi)

        rec(self.root, None, None)
        return out

    def maintain(self, max_units: int = 1) -> int:
        """Run up to ``max_units`` flush/split units; returns pending count.

        This is the deamortization knob: a serving loop calls
        ``maintain(k)`` once per step, so index upkeep can never stall a
        step for longer than k units — the paper's bounded worst-case
        insertion transplanted to the engine level.
        """
        units = 0
        while self._pending and units < max_units:
            node = self._pending.pop(0)
            if node.count <= self.sigma:
                continue
            units += self._handle_full(node)
        return len(self._pending)

    def drain(self) -> None:
        while self.maintain(64):
            pass

    # -------------------------------------------------------- paper operations
    def _handle_full(self, node: _HostNode) -> int:
        """One HandleFullSNode step (Sec. 5.1).  Returns work units done."""
        if node.is_leaf:
            if node is self.root:
                self._split_root_leaf()
            else:
                self._split_upward(node)
            return 1
        self._flush(node)
        sizes = [c.count for c in node.children]
        big = int(np.argmax(sizes))
        if sizes[big] > self.sigma:
            # single recursive call — queued as a separate work unit.
            self._pending.insert(0, node.children[big])
        if node.count > self.sigma:
            # node absorbed multiple batches; it still owes another flush.
            self._pending.append(node)
        return 1

    def _alloc(self, parent) -> _HostNode:
        if self._next_id >= self.max_nodes:
            self._grow_tables()
        n = _HostNode(self._next_id, parent)
        self._next_id += 1
        return n

    def _grow_tables(self) -> None:
        new_max = self.max_nodes * 2
        pad = lambda t, fill: jnp.concatenate(
            [t, jnp.full((self.max_nodes,) + t.shape[1:], fill, t.dtype)])
        self.pivots = pad(self.pivots, KEY_MAX32)
        self.children = pad(self.children, 0)
        self.nchild = pad(self.nchild, 0)
        self.run_keys = pad(self.run_keys, KEY_MAX32)
        self.run_vals = pad(self.run_vals, 0)
        self.run_count = pad(self.run_count, 0)
        self.bloom = pad(self.bloom, 0)
        self.max_nodes = new_max

    def _flush(self, node: _HostNode) -> None:
        """Stream-merge the first sigma live pairs into the children."""
        nid = node.nid
        moved = min(node.count, self.sigma)
        row_k, row_v = self.run_keys[nid], self.run_vals[nid]
        if moved < node.count:
            # Never split a duplicate group across the moved boundary: runs
            # keep duplicate copies newest-first, so flushing the fresh copy
            # while the stale one stays behind would invert the
            # ancestors-are-fresher rule both query paths rely on.  Back the
            # cut up to the group start; if the whole prefix is one key,
            # move the entire group (progress is guaranteed, and the child
            # run has sigma headroom — RUN_CAP >= f*(sigma+1) + sigma).
            k_cut = jnp.uint32(int(row_k[moved]))
            left = int(jnp.searchsorted(row_k, k_cut, side="left"))
            if left > 0:
                moved = min(left, moved)
            else:
                moved = min(int(jnp.searchsorted(row_k, k_cut, side="right")),
                            node.count)
        piv = jnp.asarray([int(k) for k in node.skeys], jnp.uint32)
        cuts = jnp.minimum(jnp.searchsorted(row_k, piv, side="left"), moved)
        cuts = np.asarray(cuts)                          # host ints, f-1 of them
        bounds = [0, *cuts.tolist(), moved]
        for i, child in enumerate(node.children):
            lo, hi = bounds[i], bounds[i + 1]
            if hi <= lo:
                continue
            part_k, part_v = _window(row_k, row_v, jnp.int32(lo),
                                     jnp.int32(hi - lo), self.sigma_pad)
            mk, mv = ops.merge_sorted(part_k, part_v,
                                      self.run_keys[child.nid],
                                      self.run_vals[child.nid])
            new_count = child.count + (hi - lo)
            if child.is_leaf:
                mk, mv, live = _compact_tombstones(mk, mv, self.run_cap)
                new_count = int(live)
            else:
                mk, mv = mk[: self.run_cap], mv[: self.run_cap]
            assert new_count <= self.run_cap, "child run overflow"
            self.run_keys = _write_row(self.run_keys, child.nid, mk)
            self.run_vals = _write_row(self.run_vals, child.nid, mv)
            child.count = new_count
            self.run_count = self.run_count.at[child.nid].set(new_count)
            self.bloom = _write_row(
                self.bloom, child.nid, _build_bloom(mk, self.nbits, self.h))
        # the paper advances a lazy watermark; a device row rewrite is a
        # stream copy, so we compact immediately (DESIGN.md §2).
        rest = node.count - moved
        rk, rv = _window(row_k, row_v, jnp.int32(moved), jnp.int32(rest), self.run_cap)
        self.run_keys = _write_row(self.run_keys, nid, rk)
        self.run_vals = _write_row(self.run_vals, nid, rv)
        node.count = rest
        self.run_count = self.run_count.at[nid].set(rest)
        self.bloom = _write_row(self.bloom, nid, _build_bloom(rk, self.nbits, self.h))

    def _split_root_leaf(self) -> None:
        """First split: the root leaf becomes a root with two leaf children."""
        left, right = self._alloc(self.root), self._alloc(self.root)
        k_m = self._split_run(self.root, left, right)
        self.root.skeys = [k_m]
        self.root.children = [left, right]
        self._sync_structure(self.root)
        # root keeps an empty run (the in-memory buffer of the paper).
        self._clear_run(self.root)

    def _split_upward(self, node: _HostNode) -> None:
        self._split_node(node)
        anc = node.parent
        while anc is not None and len(anc.children) > self.f:
            if anc is self.root:
                self._split_root_internal()
                return
            self._split_node(anc)
            anc = anc.parent

    def _split_node(self, node: _HostNode) -> None:
        parent = node.parent
        left, right = self._alloc(parent), self._alloc(parent)
        k_m = self._split_structure(node, left, right)
        i = parent.children.index(node)
        parent.children[i: i + 1] = [left, right]
        parent.skeys.insert(i, k_m)
        self._sync_structure(parent)

    def _split_root_internal(self) -> None:
        """Root fanout exceeded f: grow the s-tree height by one."""
        old = self.root
        left = self._alloc(None)
        right = self._alloc(None)
        k_m = self._split_structure(old, left, right)
        old.skeys = [k_m]
        old.children = [left, right]
        left.parent = right.parent = old
        self._sync_structure(old)

    def _split_structure(self, node, left, right) -> int:
        """Split node's run (and pivots/children for internal nodes)."""
        if node.is_leaf:
            k_m = self._split_run(node, left, right)
        else:
            mid = len(node.skeys) // 2
            k_m = node.skeys[mid]
            left.skeys, right.skeys = node.skeys[:mid], node.skeys[mid + 1:]
            left.children, right.children = node.children[: mid + 1], node.children[mid + 1:]
            for c in left.children:
                c.parent = left
            for c in right.children:
                c.parent = right
            self._split_run(node, left, right, at_key=k_m)
            self._sync_structure(left)
            self._sync_structure(right)
        # the original node id is retired (host-side free list elided: ids
        # are cheap; production would recycle).
        self._clear_run(node)
        node.count = 0
        return k_m

    def _split_run(self, node, left, right, at_key: int | None = None) -> int:
        nid = node.nid
        row_k, row_v = self.run_keys[nid], self.run_vals[nid]
        if at_key is None:
            mid = node.count // 2
            k_m = int(np.asarray(row_k[mid]))
            cut = int(np.asarray(jnp.searchsorted(row_k, jnp.uint32(k_m), side="left")))
        else:
            k_m = int(at_key)
            cut = int(np.asarray(jnp.searchsorted(row_k, jnp.uint32(k_m), side="left")))
            cut = min(cut, node.count)
        for dst, lo, ln in ((left, 0, cut), (right, cut, node.count - cut)):
            dk, dv = _window(row_k, row_v, jnp.int32(lo), jnp.int32(ln), self.run_cap)
            self.run_keys = _write_row(self.run_keys, dst.nid, dk)
            self.run_vals = _write_row(self.run_vals, dst.nid, dv)
            dst.count = ln
            self.run_count = self.run_count.at[dst.nid].set(ln)
            self.bloom = _write_row(self.bloom, dst.nid, _build_bloom(dk, self.nbits, self.h))
        return k_m

    def _clear_run(self, node) -> None:
        nid = node.nid
        self.run_keys = _write_row(
            self.run_keys, nid, jnp.full(self.run_cap, KEY_MAX32, jnp.uint32))
        self.run_vals = _write_row(self.run_vals, nid, jnp.zeros(self.run_cap, jnp.int32))
        node.count = 0
        self.run_count = self.run_count.at[nid].set(0)
        self.bloom = _write_row(self.bloom, nid, jnp.zeros(self.nbits // 32, jnp.uint32))

    def _sync_structure(self, node: _HostNode) -> None:
        """Mirror a host node's pivots/children into the device tables."""
        nid = node.nid
        pv = np.full(self.f - 1, KEY_MAX32, np.uint32)
        ch = np.zeros(self.f, np.int32)
        for i, k in enumerate(node.skeys[: self.f - 1]):
            pv[i] = np.uint32(k)
        for i, c in enumerate(node.children[: self.f]):
            ch[i] = c.nid
        self.pivots = self.pivots.at[nid].set(jnp.asarray(pv))
        self.children = self.children.at[nid].set(jnp.asarray(ch))
        self.nchild = self.nchild.at[nid].set(len(node.children))

    # ------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        assert not self._pending, "drain() before checking invariants"
        run_keys = np.asarray(self.run_keys)

        def rec(node, lo, hi_excl, depth, depths):
            ks = run_keys[node.nid][: node.count]
            if len(ks):
                assert np.all(ks[:-1] <= ks[1:]), "run not sorted"
                assert lo is None or ks[0] >= lo
                assert hi_excl is None or ks[-1] < hi_excl
            if node.is_leaf:
                depths.add(depth)
                return
            assert len(node.children) == len(node.skeys) + 1 <= self.f
            bounds = [lo, *node.skeys, hi_excl]
            for i, c in enumerate(node.children):
                assert c.parent is node
                rec(c, bounds[i], bounds[i + 1], depth + 1, depths)

        depths: set = set()
        rec(self.root, None, None, 0, depths)
        assert len(depths) <= 1, "leaves at non-uniform depth"

    @property
    def height(self) -> int:
        h, n = 0, self.root
        while not n.is_leaf:
            n, h = n.children[0], h + 1
        return h

    def total_pairs(self) -> int:
        total, stack = 0, [self.root]
        while stack:
            n = stack.pop()
            total += n.count
            stack.extend(n.children)
        return total
