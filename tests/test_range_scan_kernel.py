"""range_scan kernel edge cases + interpret-vs-XLA-reference agreement."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY_MAX = np.uint32(0xFFFFFFFF)


def _sorted_run(rng, n):
    return np.sort(rng.choice(2**31, n, replace=False)).astype(np.uint32)


def _scan(run, vals, lo, hi, maxr=128):
    k, v, c = ops.range_scan(jnp.array(run), jnp.array(vals),
                             jnp.array(lo, np.uint32), jnp.array(hi, np.uint32),
                             max_results=maxr)
    return np.array(k), np.array(v), np.array(c)


@pytest.mark.parametrize("n,q,maxr", [(16, 8, 128), (1000, 64, 128),
                                      (5000, 300, 256), (65536, 40, 512)])
def test_random_agreement_with_ref(rng, n, q, maxr):
    run = _sorted_run(rng, n)
    vals = np.arange(n, dtype=np.int32)
    lo = rng.integers(0, 2**31, q).astype(np.uint32)
    hi = (lo.astype(np.uint64) + rng.integers(0, 2**27, q)).clip(
        0, 2**32 - 2).astype(np.uint32)
    k, v, c = _scan(run, vals, lo, hi, maxr)
    rk, rv, rc = ref.range_scan_ref(jnp.array(run), jnp.array(vals),
                                    jnp.array(lo), jnp.array(hi), maxr)
    assert np.array_equal(k, np.array(rk))
    assert np.array_equal(v, np.array(rv))
    assert np.array_equal(c, np.array(rc))


def test_all_keys_below_lo():
    run = np.arange(1, 101, dtype=np.uint32)
    vals = np.arange(100, dtype=np.int32)
    k, v, c = _scan(run, vals, [1000], [2000])
    assert c[0] == 0
    assert (k[0] == KEY_MAX).all() and (v[0] == 0).all()


def test_all_keys_above_hi():
    run = np.arange(1000, 1100, dtype=np.uint32)
    vals = np.arange(100, dtype=np.int32)
    k, v, c = _scan(run, vals, [1], [999])
    assert c[0] == 0
    assert (k[0] == KEY_MAX).all()


def test_duplicates_at_boundary():
    run = np.array([5, 7, 7, 7, 9, 9], np.uint32)
    vals = np.arange(6, dtype=np.int32)
    k, v, c = _scan(run, vals, [7, 7, 9], [7, 9, 9])
    assert c.tolist() == [3, 5, 2]
    assert k[0, :3].tolist() == [7, 7, 7] and v[0, :3].tolist() == [1, 2, 3]
    assert k[1, :5].tolist() == [7, 7, 7, 9, 9]
    assert k[2, :2].tolist() == [9, 9] and v[2, :2].tolist() == [4, 5]


def test_overflow_truncation_reports_total_count():
    run = np.arange(1, 1001, dtype=np.uint32)
    vals = np.arange(1000, dtype=np.int32)
    k, v, c = _scan(run, vals, [1], [2000], maxr=128)
    assert c[0] == 1000                      # total matches, not the capacity
    assert k[0].tolist() == list(range(1, 129))   # first 128 in key order
    assert v[0].tolist() == list(range(128))


def test_empty_point_and_inverted_ranges():
    run = np.array([10, 20, 30], np.uint32)
    vals = np.array([0, 1, 2], np.int32)
    k, v, c = _scan(run, vals, [20, 21, 25, 0], [20, 29, 15, 2**32 - 2])
    assert c.tolist() == [1, 0, 0, 3]        # point hit, gap, inverted, all
    assert k[0, 0] == 20 and v[0, 0] == 1


def test_padding_keys_never_match():
    """hi = KEY_MAX-1 must return the whole run but no KEY_MAX padding."""
    run = np.array([3, 4, 5], np.uint32)     # kernel pads run to 128 lanes
    vals = np.array([7, 8, 9], np.int32)
    k, v, c = _scan(run, vals, [0], [2**32 - 2])
    assert c[0] == 3
    assert k[0, :3].tolist() == [3, 4, 5] and (k[0, 3:] == KEY_MAX).all()
