"""Unified observability layer (DESIGN.md §11).

The paper's headline claim is *consistency* — worst-case insertion delays
up to three orders of magnitude below LSM compaction stalls — but an
end-of-run percentile cannot show it: a mid-run saw-tooth and a flat
timeline can share the same p99.  Luo & Carey ("On Performance Stability
in LSM-based Storage Systems") argue the honest metrics are *windowed*
timelines and the stall-free window percentage; the fluctuation score
follows "Towards a B+-tree with Fluctuation-Free Performance".  This
package provides those metrics plus a structured span tracer whose output
loads directly in Perfetto, all behind :class:`ObsConfig` so the layer is
strictly zero-cost when disabled.

- :mod:`repro.obs.metrics` — log-bucket histograms (the one shared
  implementation; the driver and device engine both use it), windowed
  metric rollover, fluctuation/stall-free scoring.
- :mod:`repro.obs.trace` — bounded ring-buffer span tracer emitting Chrome
  ``trace_event`` JSON.
- :mod:`repro.obs.stall` — stalled-window detection and attribution to
  the dominant concurrent span category.
"""
from __future__ import annotations

from repro.obs.metrics import (LogBucketHistogram, ObsConfig,
                               WindowedMetrics)
from repro.obs.stall import attribute_stalls, detect_stalls
from repro.obs.trace import SPAN_CATEGORIES, Tracer, validate_chrome_trace

__all__ = [
    "LogBucketHistogram",
    "ObsConfig",
    "WindowedMetrics",
    "Tracer",
    "SPAN_CATEGORIES",
    "detect_stalls",
    "attribute_stalls",
    "validate_chrome_trace",
]
