"""Generic segmented transformer: init / forward / decode for all 10 archs.

The layer stack is cfg.segments = ((kind, count), ...); every group with
count > 1 runs as one ``lax.scan`` over stacked parameters — compact HLO
(512-way SPMD compiles stay tractable) and exact per-block semantics
(heterogeneous stacks never trace dead branches).

Public entry points:
  init_params(key, cfg)                        -> params pytree
  forward(params, cfg, tokens|embeds, ...)     -> logits (B, S, V), aux
  init_cache(cfg, B, max_seq)                  -> decode cache pytree
  decode_step(params, cfg, tok, cache, index)  -> logits (B, V), new cache
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from . import mla as mla_lib
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (_dense_init, apply_norm, attention, attn_params, mlp,
                     mlp_params, norm_params)

ATTN_KINDS = {"dense", "swa", "moe", "moe_swa", "encoder", "hybrid", "hybrid_global"}


# ---------------------------------------------------------------------- init
def _block_params(key, kind, cfg, dtype):
    ks = jax.random.split(key, 6)
    p = {"norm1": norm_params(ks[0], cfg.d_model, cfg.norm_kind, dtype)}
    if kind in ("dense", "swa", "encoder"):
        p["attn"] = attn_params(ks[1], cfg, dtype)
        p["norm2"] = norm_params(ks[2], cfg.d_model, cfg.norm_kind, dtype)
        p["mlp"] = mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    elif kind in ("moe", "moe_swa"):
        p["attn"] = attn_params(ks[1], cfg, dtype)
        p["norm2"] = norm_params(ks[2], cfg.d_model, cfg.norm_kind, dtype)
        p["moe"] = moe_lib.moe_params(ks[3], cfg, dtype)
    elif kind == "mla":
        p["attn"] = mla_lib.mla_params(ks[1], cfg, dtype)
        p["norm2"] = norm_params(ks[2], cfg.d_model, cfg.norm_kind, dtype)
        p["mlp"] = mlp_params(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    elif kind == "mlstm":
        p["cell"] = ssm_lib.mlstm_params(ks[1], cfg, dtype)
    elif kind == "slstm":
        p["cell"] = ssm_lib.slstm_params(ks[1], cfg, dtype)
    elif kind in ("hybrid", "hybrid_global"):
        p["attn"] = attn_params(ks[1], cfg, dtype)
        p["cell"] = ssm_lib.mamba_params(ks[2], cfg, dtype)
        p["attn_norm"] = norm_params(ks[3], cfg.d_model, cfg.norm_kind, dtype)
        p["ssm_norm"] = norm_params(ks[4], cfg.d_model, cfg.norm_kind, dtype)
        p["norm2"] = norm_params(ks[5], cfg.d_model, cfg.norm_kind, dtype)
        p["mlp"] = mlp_params(ks[0], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    else:
        raise ValueError(kind)
    return p


def init_params(key, cfg):
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, len(cfg.segments) + 3)
    params = {
        "embed": _dense_init(keys[0], (cfg.vocab, cfg.d_model), dtype,
                             fan_in=cfg.d_model),
        "final_norm": norm_params(keys[1], cfg.d_model, cfg.norm_kind, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _dense_init(keys[2], (cfg.d_model, cfg.vocab), dtype)
    for i, (kind, count) in enumerate(cfg.segments):
        seg_keys = jax.random.split(keys[3 + i], count)
        stacked = jax.vmap(lambda k: _block_params(k, kind, cfg, dtype))(seg_keys)
        params[f"seg{i}"] = stacked
    return params


def param_count(params) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params)))


# -------------------------------------------------------------------- blocks
def _ring_from_full(k, v, kv_len):
    """Deterministic ring cache from full-sequence KV (B, S, KVH, D).

    Slot s holds the *latest* position p ≡ s (mod kv_len), p < S — a pure
    gather (no duplicate-index scatter), so prefill->decode handoff is exact
    for SWA ring caches.
    """
    S = k.shape[1]
    slots = jnp.arange(kv_len)
    pos = slots + kv_len * ((S - 1 - slots) // kv_len)
    valid = (pos < S) & (pos >= 0) & (slots < S)
    safe = jnp.clip(pos, 0, S - 1)
    rk = jnp.take(k, safe, axis=1)
    rv = jnp.take(v, safe, axis=1)
    B = k.shape[0]
    posb = jnp.broadcast_to(jnp.where(valid, pos, -1), (B, kv_len)).astype(jnp.int32)
    zero = lambda t: jnp.where(valid[None, :, None, None], t, 0)
    return {"k": zero(rk), "v": zero(rv), "pos": posb}


def _pad_cache_to(kv, max_seq):
    """Pad full-sequence KV (B, S, ...) to cache length with pos tracking."""
    B, S = kv["k"].shape[:2]
    pad = max_seq - S
    out = {
        "k": jnp.pad(kv["k"], ((0, 0), (0, pad)) + ((0, 0),) * (kv["k"].ndim - 2)),
        "v": jnp.pad(kv["v"], ((0, 0), (0, pad)) + ((0, 0),) * (kv["v"].ndim - 2)),
        "pos": jnp.pad(jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
                       ((0, 0), (0, pad)), constant_values=-1),
    }
    return out


def _block_fwd(x, p, kind, cfg, positions, mrope_positions, cache_len=None):
    """Full-sequence block application; returns (x, aux_loss, cache|None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = apply_norm(x, p["norm1"], cfg.norm_kind, cfg.norm_eps)
    if kind in ("dense", "swa", "encoder", "moe", "moe_swa"):
        window = cfg.swa_window if kind in ("swa", "moe_swa") else None
        mask_kind = "bidir" if kind == "encoder" else "causal"
        a, kv = attention(h, p["attn"], cfg, positions=positions, kind=mask_kind,
                          window=window, mrope_positions=mrope_positions)
        x = x + a
        h2 = apply_norm(x, p["norm2"], cfg.norm_kind, cfg.norm_eps)
        if kind in ("moe", "moe_swa"):
            x = x + moe_lib.moe_mlp(h2, p["moe"], cfg)
            aux = aux + moe_lib.aux_load_balance_loss(h2, p["moe"], cfg)
        else:
            x = x + mlp(h2, p["mlp"], cfg.mlp_kind)
        if cache_len is not None:
            if window is not None and cache_len > cfg.swa_window + 128:
                ring = min(cache_len, cfg.swa_window + 128)
                cache = _ring_from_full(kv["k"], kv["v"], ring)
            elif window is not None:
                cache = _ring_from_full(kv["k"], kv["v"], cache_len)
            else:
                cache = _pad_cache_to(kv, cache_len)
    elif kind == "mla":
        a, kv = mla_lib.mla_attention(h, p["attn"], cfg, positions=positions)
        x = x + a
        h2 = apply_norm(x, p["norm2"], cfg.norm_kind, cfg.norm_eps)
        x = x + mlp(h2, p["mlp"], cfg.mlp_kind)
        if cache_len is not None:
            S = kv["c_kv"].shape[1]
            pad = cache_len - S
            cache = {"c_kv": jnp.pad(kv["c_kv"], ((0, 0), (0, pad), (0, 0))),
                     "k_rope": jnp.pad(kv["k_rope"], ((0, 0), (0, pad), (0, 0)))}
    elif kind == "mlstm":
        out, st = ssm_lib.mlstm_block(h, p["cell"], cfg)
        x = x + out
        cache = st if cache_len is not None else None
    elif kind == "slstm":
        out, st = ssm_lib.slstm_block(h, p["cell"], cfg)
        x = x + out
        cache = st if cache_len is not None else None
    elif kind in ("hybrid", "hybrid_global"):
        window = cfg.swa_window if kind == "hybrid" else None
        a, kv = attention(h, p["attn"], cfg, positions=positions, window=window)
        s, st = ssm_lib.mamba_block(h, p["cell"], cfg)
        a = apply_norm(a, p["attn_norm"], cfg.norm_kind, cfg.norm_eps)
        s = apply_norm(s, p["ssm_norm"], cfg.norm_kind, cfg.norm_eps)
        x = x + 0.5 * (a + s)
        h2 = apply_norm(x, p["norm2"], cfg.norm_kind, cfg.norm_eps)
        x = x + mlp(h2, p["mlp"], cfg.mlp_kind)
        if cache_len is not None:
            if window is not None:
                ring = min(cache_len, cfg.swa_window + 128)
                ckv = _ring_from_full(kv["k"], kv["v"], ring)
            else:
                ckv = _pad_cache_to(kv, cache_len)
            cache = {"kv": ckv, "ssm": st}
    else:
        raise ValueError(kind)
    x = constrain(x, "batch", "seq", "embed")
    return x, aux, cache


def forward(params, cfg, tokens=None, embeds=None, mrope_positions=None,
            build_cache_len=None, last_logit_only=False):
    """Token ids (B, S) or precomputed frame/patch embeds (B, S, d).

    Returns (logits (B, S, V) model-dtype, aux_loss scalar) — or, with
    ``build_cache_len`` (prefill), (logits, aux, cache) where cache is the
    decode cache pytree filled up to position S-1.  ``last_logit_only``
    slices the final hidden state before the unembed matmul (serving
    prefill must never materialize B x S x V logits).
    """
    if embeds is None:
        x = params["embed"][tokens]
        x = constrain(x, "batch", "seq", "embed")
    else:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    for i, (kind, count) in enumerate(cfg.segments):
        seg = params[f"seg{i}"]
        body = functools.partial(_block_fwd, kind=kind, cfg=cfg,
                                 positions=positions,
                                 mrope_positions=mrope_positions,
                                 cache_len=build_cache_len)
        if cfg.remat == "layer":
            body = jax.checkpoint(body)
        if count == 1:
            lp = jax.tree.map(lambda t: t[0], seg)
            x, aux, c = body(x, lp)
            aux_total = aux_total + aux
            if build_cache_len is not None:
                caches[f"seg{i}"] = jax.tree.map(lambda t: t[None], c)
        else:
            def scan_fn(carry, lp):
                x, acc = carry
                x, aux, c = body(x, lp)
                return (x, acc + aux), c
            (x, aux_total), cs = jax.lax.scan(scan_fn, (x, aux_total), seg)
            if build_cache_len is not None:
                caches[f"seg{i}"] = cs

    x = apply_norm(x, params["final_norm"], cfg.norm_kind, cfg.norm_eps)
    if last_logit_only:
        x = x[:, -1:]
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = x @ unembed
    logits = constrain(logits, "batch", "seq", "vocab")
    if build_cache_len is not None:
        return logits, aux_total, caches
    return logits, aux_total


# -------------------------------------------------------------------- decode
def _init_block_cache(kind, cfg, B, max_seq, dtype):
    hd = cfg.resolved_head_dim
    if kind == "mla":
        m = cfg.mla
        return {"c_kv": jnp.zeros((B, max_seq, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((B, max_seq, m.qk_rope_head_dim), dtype)}
    if kind == "mlstm":
        H, dk = cfg.n_heads, cfg.d_model // cfg.n_heads
        return (jnp.zeros((B, H, dk, dk), jnp.float32),
                jnp.zeros((B, H, dk), jnp.float32),
                jnp.zeros((B, H), jnp.float32))
    if kind == "slstm":
        return tuple(jnp.zeros((B, cfg.d_model), jnp.float32) for _ in range(4))
    kv_len = max_seq
    if kind in ("swa", "moe_swa", "hybrid"):
        # SWA layers keep a *ring* cache of window + slack slots; masking
        # uses stored true positions (layers.attention), so 500k-context
        # decode carries O(window) state, not O(context).
        kv_len = min(max_seq, max(cfg.swa_window + 128, 256))
    kv_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
    kv = {"k": jnp.zeros((B, kv_len, cfg.n_kv_heads, hd), kv_dtype),
          "v": jnp.zeros((B, kv_len, cfg.n_kv_heads, hd), kv_dtype),
          "pos": jnp.full((B, kv_len), -1, jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        kv["k_scale"] = jnp.zeros((B, kv_len, cfg.n_kv_heads), jnp.float32)
        kv["v_scale"] = jnp.zeros((B, kv_len, cfg.n_kv_heads), jnp.float32)
    if kind in ("hybrid", "hybrid_global"):
        di, N, W = cfg.ssm_expand * cfg.d_model, cfg.ssm_state, cfg.conv_width
        return {"kv": kv, "ssm": (jnp.zeros((B, di, N), jnp.float32),
                                  jnp.zeros((B, W - 1, di), dtype))}
    return kv


def init_cache(cfg, B, max_seq):
    dtype = jnp.dtype(cfg.dtype)
    cache = {}
    for i, (kind, count) in enumerate(cfg.segments):
        one = _init_block_cache(kind, cfg, B, max_seq, dtype)
        cache[f"seg{i}"] = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (count,) + t.shape), one)
    return cache


def _block_decode(x, p, c, kind, cfg, index, positions):
    """Single-token block step; returns (x, new_cache_slice)."""
    h = apply_norm(x, p["norm1"], cfg.norm_kind, cfg.norm_eps)
    if kind in ("dense", "swa", "encoder", "moe", "moe_swa"):
        window = cfg.swa_window if kind in ("swa", "moe_swa") else None
        kv_len = c["k"].shape[1]
        slot = index % kv_len            # identity for full-length caches
        a, nc = attention(h, p["attn"], cfg, positions=positions,
                          window=window, cache=c, cache_index=slot,
                          true_index=index)
        x = x + a
        h2 = apply_norm(x, p["norm2"], cfg.norm_kind, cfg.norm_eps)
        if kind in ("moe", "moe_swa"):
            x = x + moe_lib.moe_mlp(h2, p["moe"], cfg)
        else:
            x = x + mlp(h2, p["mlp"], cfg.mlp_kind)
        return x, nc
    if kind == "mla":
        a, nc = mla_lib.mla_attention(h, p["attn"], cfg, positions=positions,
                                      cache=c, cache_index=index)
        x = x + a
        h2 = apply_norm(x, p["norm2"], cfg.norm_kind, cfg.norm_eps)
        x = x + mlp(h2, p["mlp"], cfg.mlp_kind)
        return x, nc
    if kind == "mlstm":
        out, ns = ssm_lib.mlstm_block(h, p["cell"], cfg, state=c)
        return x + out, ns
    if kind == "slstm":
        out, ns = ssm_lib.slstm_block(h, p["cell"], cfg, state=c)
        return x + out, ns
    if kind in ("hybrid", "hybrid_global"):
        window = cfg.swa_window if kind == "hybrid" else None
        kv_len = c["kv"]["k"].shape[1]
        slot = index % kv_len
        a, nkv = attention(h, p["attn"], cfg, positions=positions,
                           window=window, cache=c["kv"], cache_index=slot,
                           true_index=index)
        s, nssm = ssm_lib.mamba_block(h, p["cell"], cfg, state=c["ssm"])
        a = apply_norm(a, p["attn_norm"], cfg.norm_kind, cfg.norm_eps)
        s = apply_norm(s, p["ssm_norm"], cfg.norm_kind, cfg.norm_eps)
        x = x + 0.5 * (a + s)
        h2 = apply_norm(x, p["norm2"], cfg.norm_kind, cfg.norm_eps)
        x = x + mlp(h2, p["mlp"], cfg.mlp_kind)
        return x, {"kv": nkv, "ssm": nssm}
    raise ValueError(kind)


def decode_step(params, cfg, tokens, cache, index):
    """One decode step.  tokens (B,) int32, index scalar int32 position.

    Returns (logits (B, V) fp32, new_cache).
    """
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :]           # (B, 1, d)
    positions = jnp.full((B, 1), index, jnp.int32)

    new_cache = {}
    for i, (kind, count) in enumerate(cfg.segments):
        seg_p, seg_c = params[f"seg{i}"], cache[f"seg{i}"]
        if count == 1:
            lp = jax.tree.map(lambda t: t[0], seg_p)
            lc = jax.tree.map(lambda t: t[0], seg_c)
            x, nc = _block_decode(x, lp, lc, kind, cfg, index, positions)
            new_cache[f"seg{i}"] = jax.tree.map(lambda t: t[None], nc)
        else:
            def scan_fn(x, pc):
                lp, lc = pc
                x, nc = _block_decode(x, lp, lc, kind, cfg, index, positions)
                return x, nc
            x, nc = jax.lax.scan(scan_fn, x, (seg_p, seg_c))
            new_cache[f"seg{i}"] = nc

    x = apply_norm(x, params["final_norm"], cfg.norm_kind, cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x[:, 0] @ unembed).astype(jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------- loss
def cross_entropy(logits, labels, mask=None):
    """Mean token-level CE; labels int32 (B, S).

    Logits arrive in model dtype (bf16) — the fp32 upcast happens inside the
    reduction so XLA fuses it without materializing an fp32 logits tensor.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
