"""Explicit external-memory I/O cost model (paper Sec. 2, "Performance Metrics").

The paper measures every index in *time* = seek time + sequential transfer
time over all disk accesses of an operation.  This module implements that
accounting exactly, with the paper's own device constants (Seagate 7200rpm
HDD from [41] and a Crucial-MX500-class SSD), so that the paper's figures
(Figs. 4-9) and tables (1-2) can be reproduced deterministically on any host.

On the TPU tier the same three-term structure re-appears as the roofline
(compute / HBM / interconnect) — see repro/roofline/.
"""
from __future__ import annotations

import dataclasses
from contextlib import contextmanager

#: bytes per key / value / pair — the paper's workload (Sec. 6.1).
KEY_BYTES = 8
VALUE_BYTES = 128
PAIR_BYTES = KEY_BYTES + VALUE_BYTES


@dataclasses.dataclass(frozen=True)
class Device:
    """Secondary-storage device constants."""

    name: str
    page_bytes: int
    seek_s: float          # T_seek
    read_bw: float         # bytes/s sequential read
    write_bw: float        # bytes/s sequential write

    @property
    def pairs_per_page(self) -> int:
        return max(1, self.page_bytes // PAIR_BYTES)


#: 7200rpm, 125 MB/s, 8.5 ms seek — the constants the paper quotes from [41].
HDD = Device("hdd", page_bytes=4096, seek_s=8.5e-3, read_bw=125e6, write_bw=125e6)
#: SATA SSD in the Crucial MX500 class used by the paper's testbed.
SSD = Device("ssd", page_bytes=4096, seek_s=1.0e-4, read_bw=520e6, write_bw=450e6)


class CostModel:
    """Mutable accumulator of simulated I/O time.

    ``cost`` (page accesses) and ``time`` (seconds) follow the paper's
    terminology: *cost* counts pages, *time* adds seek + sequential terms.
    """

    def __init__(self, device: Device = HDD):
        self.device = device
        self.reset()

    def reset(self) -> None:
        self.seeks = 0
        self.bytes_read = 0
        self.bytes_written = 0
        self.pages = 0

    # -- elementary operations -------------------------------------------------
    def seek(self, n: int = 1) -> float:
        self.seeks += n
        return n * self.device.seek_s

    def seq_read(self, nbytes: int) -> float:
        self.bytes_read += nbytes
        self.pages += -(-nbytes // self.device.page_bytes)
        return nbytes / self.device.read_bw

    def seq_write(self, nbytes: int) -> float:
        self.bytes_written += nbytes
        self.pages += -(-nbytes // self.device.page_bytes)
        return nbytes / self.device.write_bw

    def read_pairs(self, npairs: int) -> float:
        return self.seq_read(npairs * PAIR_BYTES)

    def write_pairs(self, npairs: int) -> float:
        return self.seq_write(npairs * PAIR_BYTES)

    def page_read(self, n: int = 1) -> float:
        """A random single-page read: seek + one sequential page."""
        return self.seek(n) + self.seq_read(n * self.device.page_bytes)

    # -- totals ----------------------------------------------------------------
    @property
    def time(self) -> float:
        return (
            self.seeks * self.device.seek_s
            + self.bytes_read / self.device.read_bw
            + self.bytes_written / self.device.write_bw
        )

    @contextmanager
    def measure(self):
        """Measure the simulated time of one operation.

        >>> cm = CostModel()
        >>> with cm.measure() as t:
        ...     cm.seek(); cm.seq_read(4096)
        >>> t.seconds  # doctest: +ELLIPSIS
        0.0085...
        """
        before = self.time

        class _T:
            seconds = 0.0

        t = _T()
        try:
            yield t
        finally:
            t.seconds = self.time - before
