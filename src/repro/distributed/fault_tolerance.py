"""Fault tolerance: heartbeats, straggler mitigation, elastic resize.

At 1000+ nodes, machine failure is a *when*, not an *if*; the framework's
posture (exercised at toy scale on CPU, same code paths):

  * ``HeartbeatMonitor`` — hosts report heartbeats on any monotone clock
    (integer SPMD steps *or* float sim-seconds — the replication layer's
    failure detector drives it straight off the ingest sim clock); a host
    silent for ``timeout`` clock units is declared dead -> triggers
    elastic resize / replica promotion.
  * ``StragglerDetector`` — per-host step-time EWMA; hosts slower than
    ``z_threshold`` sigma above fleet mean are flagged for exclusion
    (mitigates the straggler tail that stalls synchronous SPMD steps).
  * ``elastic_resize`` — re-lowers the train step on a smaller mesh and
    restores params/optimizer from the NB-tree-manifested checkpoint with
    the new shardings (checkpoint/checkpointer.restore(shardings=...)).
    Training resumes with a proportionally smaller global batch (or the
    same batch via more microbatches — caller's policy).
"""
from __future__ import annotations

import time

import numpy as np


class HeartbeatMonitor:
    """Per-step liveness with declare-once semantics.

    A host silent for ``timeout_steps`` is declared dead exactly once (the
    one ``advance`` call that crosses the threshold returns it; later calls
    don't re-report, so the resize/recovery it triggers fires once).  A
    beat arriving *after* the declaration is ignored — a host that was
    declared dead has already been resized away, and silently readmitting
    it would split the cluster's view; re-admission is the explicit
    :meth:`revive` path (post-restart health check).

    The clock is any monotone number: integer SPMD steps (the trainer) or
    float sim-seconds (the replication layer beats on the ingest sim
    clock).  Host ids are any hashable (ints for trainer hosts, strings
    like ``"g0/n1"`` for replica nodes).  ``timeout_steps`` is accepted as
    an alias of ``timeout`` for the original trainer call sites.
    """

    def __init__(self, hosts=(), timeout: float = 3,
                 timeout_steps: float | None = None):
        self.last_beat = {h: 0.0 for h in hosts}
        self.timeout = timeout if timeout_steps is None else timeout_steps
        self.step = 0.0
        self.dead: set = set()

    def add_host(self, host, now: float | None = None) -> None:
        """Start monitoring ``host``; its beat clock starts at ``now``."""
        self.last_beat[host] = self.step if now is None else float(now)

    def beat(self, host, now: float) -> bool:
        """Record a heartbeat; returns False (ignored) for declared-dead
        hosts — late beats do not resurrect, only :meth:`revive` does."""
        if host in self.dead:
            return False
        self.last_beat[host] = float(now)
        return True

    def advance(self, now: float) -> list:
        """Returns hosts *newly* declared dead at clock value ``now``."""
        self.step = now
        newly = [h for h, s in self.last_beat.items()
                 if h not in self.dead and now - s >= self.timeout]
        self.dead.update(newly)
        return newly

    def forget(self, host) -> None:
        """Stop monitoring ``host`` entirely (retired, not merely dead)."""
        self.last_beat.pop(host, None)
        self.dead.discard(host)

    def revive(self, host, now: float | None = None) -> None:
        """Explicitly re-admit a declared-dead (or new) host.

        The beat clock restarts at ``now`` (default: the monitor's current
        clock), so the host gets a full timeout window before it can be
        re-declared.
        """
        self.dead.discard(host)
        self.last_beat[host] = self.step if now is None else float(now)


class StragglerDetector:
    def __init__(self, hosts: list[int], alpha: float = 0.2,
                 z_threshold: float = 2.0, warmup: int = 8):
        # z capped at (n-1)/sqrt(n) for a single outlier: 2.0 keeps one
        # straggler detectable in an 8-host fleet while ~3-sigma-safe at
        # hundreds of hosts (fleet std shrinks with n).
        self.ewma = {h: None for h in hosts}
        self.alpha, self.z, self.warmup = alpha, z_threshold, warmup
        self.samples = 0

    def record(self, host, step_seconds: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (step_seconds if prev is None
                           else self.alpha * step_seconds + (1 - self.alpha) * prev)
        self.samples += 1

    def stragglers(self) -> list[int]:
        if self.samples < self.warmup * len(self.ewma):
            return []
        vals = np.asarray([v for v in self.ewma.values() if v is not None])
        if len(vals) < 3:
            return []
        mu, sd = float(vals.mean()), float(vals.std() + 1e-12)
        return [h for h, v in self.ewma.items()
                if v is not None and (v - mu) / sd > self.z]


def elastic_resize(checkpointer, step: int, state_like, new_mesh,
                   param_specs_fn):
    """Restore checkpointed state onto a *different* mesh.

    ``state_like`` = {"params": ..., "opt": {"m","v","count"}} shape pytree
    (the structure the trainer checkpoints).  Returns the state resharded
    for ``new_mesh``; the caller re-jits its train step with the new mesh.
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    pspecs = param_specs_fn(state_like["params"], new_mesh)
    spec_tree = {"params": pspecs,
                 "opt": {"m": pspecs, "v": pspecs, "count": P()}}
    sh = jax.tree.map(lambda s: NamedSharding(new_mesh, s), spec_tree,
                      is_leaf=lambda s: isinstance(s, P))
    return checkpointer.restore(step, state_like, shardings=sh)
