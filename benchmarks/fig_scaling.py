"""Shard-count scaling sweep: throughput up, worst-case delay bounded.

The paper's headline is a consistently high insertion rate with *bounded
worst-case delay* on one engine; the ROADMAP north star is a sharded
serving system.  This scenario measures whether the sharded layer
(DESIGN.md §6) preserves both claims at scale-out: an insert-heavy
workload is streamed through ``sharded:<tier>`` ensembles of 1..16 shards,
reporting

* **aggregate insert throughput** — total ops over the parallel makespan
  (shards own independent cost models, so the ensemble's elapsed time is
  the *max* per-shard charged time, not the sum), and
* **p100 insert delay** — the worst single foreground op anywhere in the
  ensemble, which the cross-shard maintenance scheduler must keep at the
  single-shard bound (the Luo & Carey stall-at-scale-out failure mode).

Expected shape: throughput grows with shard count for the NB-tree tier
while p100 stays within 2x of the single-shard bound; every sim tier ends
with identical live pairs at every shard count (differential check).  The
device tier runs host-sequentially (wall clock), so its rows demonstrate
protocol + debt bounds, not parallel speedup.

Standalone CLI (CI bench-smoke)::

    PYTHONPATH=src python -m benchmarks.fig_scaling --quick \
        --out runs/fig_scaling.json
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.engine_api import make_engine
from repro.workloads import make_workload
from repro.workloads.driver import SCHEMA_VERSION, run_workload

KEY_SPACE = 1 << 20

#: per-shard configs sized so maintenance actually fires inside the
#: measured phase even at 16 shards (sigma well below n_ops / shards).
CONFIGS = {
    "nbtree": dict(f=3, sigma=512),
    "lsm": dict(mem_pairs=512),
    "btree": {},
    "bepsilon": dict(node_bytes=1 << 16, cached_levels=1),
    "jax-nbtree": dict(f=4, sigma=256, max_nodes=256),
}

#: the wall-clock device tier runs shards host-sequentially; cap its sweep.
_DEVICE_COUNTS = (1, 4)

#: one source of truth for the smoke-sized sweep (this module's --quick and
#: benchmarks/run.py --quick must produce comparable artifacts).
QUICK_KWARGS = dict(tiers=("nbtree", "lsm"), shard_counts=(1, 2, 4),
                    n_ops=1024, batch=128, preload=1024)


def _make(tier: str, n_shards: int):
    if n_shards == 1:
        return make_engine(tier, **CONFIGS[tier])
    return make_engine(f"sharded:{tier}", shards=n_shards, **CONFIGS[tier])


def _makespan(engine) -> float:
    """Ensemble elapsed charged time: max over parallel shards."""
    times = engine.shard_io_times() if hasattr(engine, "shard_io_times") \
        else [engine.io_time_s()]
    return max(max(times, default=0.0), 1e-9)


def run(tiers=("nbtree", "lsm", "bepsilon", "jax-nbtree"),
        shard_counts=(1, 2, 4, 8, 16), n_ops: int = 4096, batch: int = 256,
        preload: int = 4096, mix: str = "insert-heavy"):
    rows = []
    for tier in tiers:
        for n_shards in shard_counts:
            if tier == "jax-nbtree" and n_shards not in _DEVICE_COUNTS:
                continue
            engine = _make(tier, n_shards)
            wl = make_workload(mix, key_space=KEY_SPACE, n_ops=n_ops,
                               batch_size=batch, preload=preload)
            report = run_workload(engine, wl, maintain_budget=2)
            st = report["stats"]
            ins = report["per_kind"].get("insert", {})
            n_ins = st["n_inserts"]
            rows.append(dict(
                fig="scaling", index=tier, shards_req=n_shards,
                shards=st["shards"], mix=mix, clock=st["clock"],
                n_ops=n_ops,
                throughput_kops=n_ins / _makespan(engine) / 1e3,
                insert_p50_ms=ins.get("p50_s", 0.0) * 1e3,
                insert_p100_ms=ins.get("p100_s", 0.0) * 1e3,
                pending_debt=st["pending_debt"],
                live_pairs=st["total_pairs"]))
    return rows


def check(rows) -> list[str]:
    out = []
    sim = [r for r in rows if r["clock"] == "sim"]
    # differential: every sim tier at every shard count ends with the same
    # visible state from the one shared stream.
    pairs = {r["live_pairs"] for r in sim}
    tag = "matches paper" if len(pairs) == 1 else "MISMATCH"
    out.append(f"scaling: all sim tiers/shard counts agree on live pairs "
               f"({sorted(pairs)})  [{tag}]")
    nb = sorted((r for r in rows if r["index"] == "nbtree"),
                key=lambda r: r["shards_req"])
    if nb:
        base = nb[0]
        grows = all(b["throughput_kops"] >= a["throughput_kops"] * 0.9
                    for a, b in zip(nb, nb[1:]))
        speedup = nb[-1]["throughput_kops"] / max(base["throughput_kops"],
                                                  1e-12)
        tag = ("matches paper" if grows and speedup > 1.5 else "MISMATCH")
        out.append(f"scaling nbtree: aggregate insert throughput grows with "
                   f"shard count ({speedup:.1f}x at {nb[-1]['shards_req']} "
                   f"shards)  [{tag}]")
        bound = max(base["insert_p100_ms"], 1e-9)
        worst = max(r["insert_p100_ms"] / bound for r in nb)
        tag = "matches paper" if worst <= 2.0 else "MISMATCH"
        out.append(f"scaling nbtree: ensemble p100 insert delay stays within "
                   f"2x of the single-shard bound (worst {worst:.2f}x)  "
                   f"[{tag}]")
    # the scheduler leaves no unpaid debt anywhere after drain.
    tag = ("matches paper" if all(r["pending_debt"] == 0 for r in rows)
           else "MISMATCH")
    out.append(f"scaling: zero pending debt after drain on every row  [{tag}]")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI smoke)")
    ap.add_argument("--out", default="runs/fig_scaling.json")
    args = ap.parse_args(argv)
    kwargs = QUICK_KWARGS if args.quick else {}
    rows = run(**kwargs)
    checks = check(rows)
    for r in rows:
        print(r)
    for c in checks:
        print(" ->", c)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "rows": rows,
                   "checks": checks}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
