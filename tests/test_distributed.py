"""Distribution: sharded train parity, compression, dryrun path, resize.

Runs on 8 host-platform devices (set before jax initializes via conftest?
No — via env in this module import order; pytest-forked not available, so
this file must be run in the same session: we request 8 devices in
conftest_distributed plugin below).
"""
import os

# must happen before jax backend init; harmless if jax already initialized
# with >= 8 devices (the whole test session sets this via tests/conftest.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import param_specs
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_mesh, mesh_context
from repro.models import registry
from repro.models import transformer as T
from repro.optim import adamw, compression
from repro.train.train_step import make_train_step

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host-platform devices "
    "(run pytest with XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# The GSPMD parity and partial-manual shard_map tests need the post-0.4
# sharding stack: under the 0.4.x legacy mesh context the partitioner
# aborts (SPMD CHECK) on partial-manual shard_map and sharded/unsharded
# parity does not hold bit-exactly.  The code paths themselves still run on
# 0.4.x via the compat shims in launch/mesh.py + distributed/sharding.py.
requires_new_sharding = pytest.mark.skipif(
    not (hasattr(jax, "shard_map") and hasattr(jax.sharding, "set_mesh")),
    reason="requires jax>=0.6 sharding stack (jax.shard_map / set_mesh)")


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(registry.get_config("qwen3-8b").reduced(),
                               dtype="float32", remat="none")


def _batch(cfg, B=8, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    return {"tokens": jax.random.randint(k, (B, S), 1, cfg.vocab)}


@requires_new_sharding
def test_sharded_train_matches_single_device(mesh, cfg):
    """One sharded step == one unsharded step (GSPMD is semantics-free)."""
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = _batch(cfg)
    step = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))

    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    pspecs = param_specs(params, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    params_s = jax.device_put(params, psh)
    opt_s = jax.device_put(opt, {"m": psh, "v": psh,
                                 "count": NamedSharding(mesh, P())})
    with mesh_context(mesh):
        bsh = jax.tree.map(lambda _: NamedSharding(mesh, P(("pod", "data"))),
                           batch)
        batch_s = jax.device_put(batch, bsh)
        p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)

    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    l1 = jax.tree_util.tree_leaves(p1)[1]
    l2 = jax.tree_util.tree_leaves(p2)[1]
    np.testing.assert_allclose(np.array(l1), np.array(l2), atol=2e-5, rtol=2e-5)


@requires_new_sharding
def test_grad_compression_close_to_exact(cfg):
    """int8 error-feedback compressed step stays close to the exact step and
    the error buffers capture the residual.

    Runs on a ("pod","data") mesh — the DCN-compression deployment shape;
    3-axis meshes hit a jaxlib 0.8.2 partitioner CHECK (see
    optim/compression.py KNOWN LIMITATION).
    """
    mesh = make_mesh((2, 4), ("pod", "data"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = _batch(cfg)

    exact = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
    comp = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3),
                           grad_compression=True, mesh=mesh)

    pspecs = param_specs(params, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    with mesh_context(mesh):
        params_s = jax.device_put(params, psh)
        opt_s = jax.device_put(opt, {"m": psh, "v": psh,
                                     "count": NamedSharding(mesh, P())})
        opt_s["error"] = compression.init_error(params, 2)
        bsh = jax.tree.map(lambda _: NamedSharding(mesh, P(("pod", "data"))), batch)
        batch_s = jax.device_put(batch, bsh)
        p2, o2, m2 = jax.jit(comp)(params_s, opt_s, batch_s)
        p1, o1, m1 = jax.jit(exact)(params_s, {k: opt_s[k] for k in ("m", "v", "count")},
                                    batch_s)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    # updates differ only by quantization noise
    l1 = np.array(jax.tree_util.tree_leaves(p1)[1], np.float32)
    l2 = np.array(jax.tree_util.tree_leaves(p2)[1], np.float32)
    np.testing.assert_allclose(l1, l2, atol=5e-4, rtol=5e-2)
    err = jax.tree_util.tree_leaves(o2["error"])
    assert any(float(jnp.abs(e).max()) > 0 for e in err), "no residual captured?"


def test_microbatched_grads_match(mesh, cfg):
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = _batch(cfg, B=8)
    s1 = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3), num_microbatches=1)
    s4 = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3), num_microbatches=4)
    _, _, m1 = jax.jit(s1)(params, opt, batch)
    _, _, m4 = jax.jit(s4)(jax.tree.map(jnp.copy, params), adamw.init(params), batch)
    # microbatch losses are averaged over slices: equal for equal slices
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-3


@pytest.mark.parametrize("kind,arch", [
    ("train", "qwen3-8b"), ("prefill", "deepseek-moe-16b"),
    ("decode", "hymba-1.5b"), ("decode", "xlstm-1.3b"),
])
def test_dryrun_lowering_path(mesh, kind, arch):
    """The exact dryrun code path at reduced scale: must compile + report."""
    cfg = registry.get_config(arch).reduced()
    sp = ShapeSpec("t", 64 if kind != "prefill" else 128,
                   8 if kind != "prefill" else 4, kind)
    rec = lower_cell(arch, kind, mesh, cfg=cfg, shape=sp, cost_correct=True)
    assert rec["status"] == "ok", rec
    r = rec["roofline"]
    assert r["flops_per_dev"] > 0
    assert r["t_memory"] > 0
    assert rec["memory_analysis"]["peak_gib"] > 0


def test_elastic_resize(tmp_path, cfg):
    """Checkpoint on mesh A, restore resharded onto smaller mesh B, resume."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.distributed.fault_tolerance import elastic_resize

    mesh_a = make_mesh((4, 2), ("data", "model"))
    mesh_b = make_mesh((2, 2), ("data", "model"))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": params, "opt": opt})

    state_like = {"params": params, "opt": opt}
    state = elastic_resize(ck, 1, state_like, mesh_b, param_specs)
    # restored params identical, now placed for mesh_b
    l0 = np.array(jax.tree_util.tree_leaves(params)[0], np.float32)
    l1 = np.array(jax.tree_util.tree_leaves(state["params"])[0], np.float32)
    np.testing.assert_allclose(l0, l1)
    # one step on the new mesh works
    step = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
    with mesh_context(mesh_b):
        batch = _batch(cfg, B=4)
        p, o, m = jax.jit(step)(state["params"], state["opt"], batch)
    assert np.isfinite(float(m["loss"]))


def test_straggler_and_heartbeat():
    from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                                   StragglerDetector)
    hosts = list(range(8))
    mon = HeartbeatMonitor(hosts, timeout_steps=3)
    det = StragglerDetector(hosts, warmup=2)
    for step in range(1, 12):
        for h in hosts:
            if h == 5 and step > 6:
                continue            # host 5 dies at step 7
            mon.beat(h, step)
            det.record(h, 0.1 if h != 3 else 0.5)   # host 3 straggles
    dead = mon.advance(12)
    assert dead == [5], dead
    assert det.stragglers() == [3]
