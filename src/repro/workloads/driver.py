"""Workload driver: stream any mix through any registered engine.

``run_workload(engine, workload)`` applies the preload then the mixed
stream batch by batch, calling ``engine.maintain(budget)`` between batches
(the serving-loop deamortization knob), and records per-op latencies into
per-kind :class:`LatencyHistogram`s.  The report carries p50/p99/p100/mean
per kind, the histogram buckets, and the engine's final ``stats()``
snapshot — everything ``benchmarks/fig_mixed.py`` and the CI smoke job
need, in JSON-ready form.

CLI (used by the CI benchmark-smoke job)::

    PYTHONPATH=src python -m repro.workloads.driver \
        --engines all --mix ycsb-a --ops 512 --batch 64 --out runs/mixed.json

``--shards N`` (N > 1) wraps every requested engine in the sharded layer
(``sharded:<name>``, DESIGN.md §6) with ``--partition`` choosing range or
hash placement.  Emitted JSON carries ``schema_version`` (top level and per
report) so bench trajectory files are comparable across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.core.engine_api import (FIVE_TIERS, OpKind, StorageEngine,
                                   available_engines, make_engine)

from .generator import MIXES, Workload, make_workload

#: bump when the emitted JSON layout changes (stamped into every report so
#: trajectory files from different PRs are comparable — or visibly not).
SCHEMA_VERSION = 2


class LatencyHistogram:
    """Log-spaced latency histogram with exact sample percentiles.

    Buckets span 1 ns .. ~1000 s at 4 buckets/decade (JSON-friendly for
    artifacts); out-of-range samples are clamped into the edge buckets
    (zero-cost ops — e.g. buffered sim-tier inserts — land in the first
    bucket) so ``sum(bucket_counts) == count`` always holds; percentiles
    are computed from the retained raw samples, so p50/p99/p100 are
    exact, not bucket-resolution estimates.
    """

    EDGES = np.logspace(-9, 3, 49)          # seconds

    def __init__(self):
        self.samples: list = []

    def add(self, latencies_s) -> None:
        lat = np.asarray(latencies_s, np.float64)
        if lat.size:
            self.samples.append(lat)

    @property
    def _all(self) -> np.ndarray:
        return (np.concatenate(self.samples) if self.samples
                else np.empty(0, np.float64))

    def percentile(self, q: float) -> float:
        a = self._all
        return float(np.percentile(a, q)) if a.size else 0.0

    def to_dict(self) -> dict:
        a = self._all
        counts = (np.histogram(np.clip(a, self.EDGES[0], self.EDGES[-1]),
                               self.EDGES)[0] if a.size
                  else np.zeros(len(self.EDGES) - 1, int))
        return {
            "count": int(a.size),
            "mean_s": float(a.mean()) if a.size else 0.0,
            "p50_s": self.percentile(50),
            "p99_s": self.percentile(99),
            "p100_s": self.percentile(100),
            "bucket_edges_s": [float(e) for e in self.EDGES],
            "bucket_counts": [int(c) for c in counts],
        }


def run_workload(engine: StorageEngine, workload: Workload, *,
                 maintain_budget: int = 1) -> dict:
    """Drive ``workload`` through ``engine``; returns the JSON-ready report."""
    spec = workload.spec
    hists = {k: LatencyHistogram() for k in OpKind}

    pre = workload.preload_batch()
    engine.apply(pre)
    engine.drain()
    io_after_preload = engine.io_time_s()

    max_debt = 0
    for batch in workload.batches():
        res = engine.apply(batch)
        for k in OpKind:
            hists[k].add(res.latencies(k))
        max_debt = max(max_debt, engine.maintain(maintain_budget))
    debt_before_drain = engine.maintain(0)
    engine.drain()

    stats = engine.stats()
    return {
        "schema_version": SCHEMA_VERSION,
        "engine": engine.name,
        "workload": dataclasses.asdict(spec) | {
            "mix": {OpKind(k).name.lower(): p for k, p in spec.mix.items()}},
        "maintain_budget": maintain_budget,
        "preload_pairs": len(pre),
        "io_time_preload_s": io_after_preload,
        "max_pending_debt": int(max_debt),
        "pending_debt_before_drain": int(debt_before_drain),
        "per_kind": {OpKind(k).name.lower(): h.to_dict()
                     for k, h in hists.items() if h.samples},
        "stats": dataclasses.asdict(stats),
    }


# ---------------------------------------------------------------- CLI harness
_SMALL_CONFIGS = {
    # tiny-footprint constructor kwargs for smoke runs (CI, demos).
    "nbtree": dict(f=3, sigma=1024),
    "nbtree-basic": dict(f=3, sigma=1024),
    "nbtree-nobloom": dict(f=3, sigma=1024),
    "lsm": dict(mem_pairs=1024),
    "blsm": dict(mem_pairs=1024),
    "btree": {},
    "bepsilon": dict(node_bytes=1 << 16, cached_levels=1),
    "jax-nbtree": dict(f=4, sigma=512, max_nodes=256),
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engines", nargs="+", default=["all"],
                    help="engine names, or 'all' for the five paper tiers "
                         f"({', '.join(FIVE_TIERS)}); registered: "
                         f"{', '.join(available_engines())}")
    ap.add_argument("--mix", default="ycsb-a", choices=sorted(MIXES))
    ap.add_argument("--ops", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--preload", type=int, default=2048)
    ap.add_argument("--key-space", type=int, default=1 << 20)
    ap.add_argument("--dist", choices=("uniform", "zipfian", "hotspot"),
                    default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="workload stream seed (same seed -> same op stream)")
    ap.add_argument("--maintain-budget", type=int, default=1)
    ap.add_argument("--shards", type=int, default=1,
                    help="N > 1 wraps each engine as sharded:<name> with N "
                         "range-partitioned shards (DESIGN.md §6)")
    ap.add_argument("--partition", choices=("range", "hash"), default="range")
    ap.add_argument("--out", default="runs/driver_report.json",
                    help="write the JSON report here")
    args = ap.parse_args(argv)

    names = FIVE_TIERS if args.engines == ["all"] else tuple(args.engines)
    overrides = dict(n_ops=args.ops, batch_size=args.batch,
                     preload=args.preload, key_space=args.key_space,
                     seed=args.seed)
    if args.dist:
        overrides["dist"] = args.dist

    reports = []
    for name in names:
        base_kw = _SMALL_CONFIGS.get(name, {})
        if args.shards > 1:
            engine = make_engine(f"sharded:{name}", shards=args.shards,
                                 partition=args.partition, **base_kw)
        else:
            engine = make_engine(name, **base_kw)
        report = run_workload(engine, make_workload(args.mix, **overrides),
                              maintain_budget=args.maintain_budget)
        reports.append(report)
        pk = report["per_kind"]
        line = " ".join(
            f"{kind}[p50={h['p50_s']*1e3:.3f}ms p99={h['p99_s']*1e3:.3f}ms "
            f"p100={h['p100_s']*1e3:.3f}ms]" for kind, h in pk.items())
        print(f"{engine.name:>14} ({report['stats']['clock']}) {args.mix}: "
              f"{line} pairs={report['stats']['total_pairs']} "
              f"shards={report['stats']['shards']}")

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"schema_version": SCHEMA_VERSION, "mix": args.mix,
                       "seed": args.seed, "shards": args.shards,
                       "reports": reports}, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
