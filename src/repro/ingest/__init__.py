"""Open-loop ingestion frontend (DESIGN.md §7).

The serving layer between workload generation and the storage engines:
seeded arrival processes (:mod:`.arrivals`), a bounded-queue group-commit
frontend with admission control on a deterministic simulated clock
(:mod:`.frontend`), and per-kind SLO accounting with stall attribution
(:mod:`.slo`).  ``benchmarks/fig_saturation.py`` sweeps offered load
through this layer to produce throughput-vs-tail-latency curves — the
operational form of the paper's worst-case insertion-delay claim.
"""
from .arrivals import (ARRIVALS, ArrivalProcess, ArrivalTrace,
                       DiurnalArrivals, MMPPArrivals, PoissonArrivals,
                       make_arrivals, make_trace, multiplex)
from .frontend import (DurabilityConfig, FrontendConfig, IngestFrontend,
                       run_open_loop)
from .slo import STALL_FACTOR, SLOTracker

__all__ = [
    "ARRIVALS", "ArrivalProcess", "ArrivalTrace", "DiurnalArrivals",
    "MMPPArrivals", "PoissonArrivals", "make_arrivals", "make_trace",
    "multiplex",
    "DurabilityConfig", "FrontendConfig", "IngestFrontend", "run_open_loop",
    "STALL_FACTOR", "SLOTracker",
]
