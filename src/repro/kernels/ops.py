"""Public jit'd wrappers for the Pallas kernels.

Single dispatch point: on TPU the kernels compile natively; everywhere else
they run under ``interpret=True`` (the Pallas interpreter executes the kernel
body on CPU), so all call sites — the NB-tree device tier, the serving
engine, tests, benchmarks — use exactly one code path.
"""
from __future__ import annotations

import jax

from .bloom_filter import bloom_probe as _bloom_probe
from .merge_sorted import merge_sorted as _merge_sorted
from .merge_sorted import merge_sorted_batch as _merge_sorted_batch
from .paged_attention import paged_attention as _paged_attention
from .range_scan import range_scan as _range_scan
from .ref import bloom_build_ref, bloom_update_ref
from .sorted_search import sorted_search as _sorted_search


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def merge_sorted(a_keys, a_vals, b_keys, b_vals):
    return _merge_sorted(a_keys, a_vals, b_keys, b_vals, interpret=_interpret())


def merge_sorted_batch(a_keys, a_vals, b_keys, b_vals):
    """Merge R pairs of sorted runs in one launch (fused-flush fan-out)."""
    return _merge_sorted_batch(a_keys, a_vals, b_keys, b_vals,
                               interpret=_interpret())


def sorted_search(run_keys, run_vals, queries):
    return _sorted_search(run_keys, run_vals, queries, interpret=_interpret())


def range_scan(run_keys, run_vals, lo, hi, *, max_results: int = 128):
    return _range_scan(run_keys, run_vals, lo, hi, max_results=max_results,
                       interpret=_interpret())


def bloom_probe(words, queries, *, nbits: int, h: int = 3):
    return _bloom_probe(words, queries, nbits=nbits, h=h, interpret=_interpret())


def bloom_build(keys, nbits: int, h: int = 3):
    """Filter build: once-per-rewrite XLA path (see bloom_filter.py docstring)."""
    return bloom_build_ref(keys, nbits, h)


def bloom_update(words, keys, nbits: int, h: int = 3):
    """Incremental filter maintenance: OR a batch's bits into ``words``.

    O(batch) instead of O(run_cap); bit-identical to a from-scratch rebuild
    over the grown run (see ref.bloom_update_ref) — the per-insert-batch
    path of the fused ingest pipeline.
    """
    return bloom_update_ref(words, keys, nbits, h)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens):
    return _paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                            interpret=_interpret())
