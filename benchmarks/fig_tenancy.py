"""Noisy-neighbor sweep: weighted-fair admission vs the shared FIFO.

The multi-tenant front door (``repro.tenancy``, DESIGN.md §10) claims
*isolation*: a bursty aggressor sharing one engine with well-behaved
tenants is shed and throttled against its own queue bound, while the
well-behaved tenants' tail latency stays near what they would see running
alone.  This sweep measures exactly that, three ways per aggressor burst
rate:

* **solo** — each steady tenant alone on a fresh engine: the baseline
  p99.9 the isolation claim is measured against (the tenant's trace is
  seeded per tenant id, so it is byte-identical in every mode);
* **fair** — the full noisy-neighbor scenario (two steady Poisson victims
  + one MMPP aggressor, ``repro.workloads.tenants``) under deficit-
  round-robin admission with per-tenant bounds;
* **unfair** — the same scenario through the shared-FIFO baseline
  (``fair=False``), where aggressor bursts camp the queue ahead of every
  victim op.

Expected shape: under fair queuing each victim's end-to-end insert p99.9
stays within **2x its solo baseline** at every burst rate while the
aggressor takes all the shed; through the shared FIFO the victims' p99.9
grows with the burst rate without bound (queue-cap delay, ~seconds on the
B+-tree tier) — the textbook DRR isolation result, reproduced on the
paper's cost-model stack.

The shared engine is the incremental B+-tree tier: its per-insert random
I/O gives the server a crisp, deterministic capacity (~4.7k ops/s on the
SSD constants), so saturation — and therefore queueing — is a property of
the *admission policy*, not of maintenance noise.

Standalone CLI (CI tenancy-smoke; seed trajectory record at repo root)::

    PYTHONPATH=src python -m benchmarks.fig_tenancy --quick \
        --out runs/fig_tenancy.json
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.cost_model import SSD
from repro.core.engine_api import make_engine
from repro.ingest import FrontendConfig
from repro.tenancy import run_multi_tenant
from repro.workloads.driver import SCHEMA_VERSION
from repro.workloads.tenants import build_scenario

#: aggressor MMPP burst rates, ops/second (server capacity is ~4.7k/s).
AGG_RATES = (5_000, 20_000, 80_000)

#: serving-node knobs: small commits keep the fairness granularity fine
#: (a victim op waits at most ~one in-flight commit of service), a long
#: linger makes the solo baseline linger-dominated and stable.
FRONTEND = FrontendConfig(max_queue=4096, commit_ops=16, linger_s=5e-3)

#: scenario shape shared by every mode (victims; the aggressor's trace
#: length tracks its rate to cover the same window — see tenants module).
SCENARIO = dict(victim_rate=500.0, victim_weight=4.0, aggressor_queue=512)

_VICTIMS = (0, 1)
_AGGRESSOR = 2

#: one source of truth for the smoke-sized sweep (--quick here and in
#: benchmarks/run.py must produce comparable artifacts).
QUICK_KWARGS = dict(agg_rates=(20_000, 80_000), n_ops=500)


def _engine():
    return make_engine("btree", device=SSD)


def _rows(mode: str, agg_rate: float, rep: dict) -> list:
    out = []
    ol = rep["open_loop"]
    for tid_s, t in sorted(ol["tenants"].items()):
        sub = t["open_loop"]
        ins = sub["per_kind_e2e"].get("insert", {})
        adm = ol["admission"][tid_s]
        out.append(dict(
            fig="tenancy", mode=mode, agg_rate=agg_rate,
            tenant=int(tid_s), name=t["name"], weight=t["weight"],
            n_offered=sub["n_offered"], n_done=sub["n_done"],
            n_shed=adm["shed"],
            insert_p50_ms=ins.get("p50_s", 0.0) * 1e3,
            insert_p99_ms=ins.get("p99_s", 0.0) * 1e3,
            insert_p999_ms=ins.get("p999_s", 0.0) * 1e3,
            live_pairs=t["live_pairs"],
            utilization=ol["server"]["utilization"]))
    return out


def run(agg_rates=AGG_RATES, n_ops: int = 800, seed: int = 0):
    rows = []

    def scenario(rate):
        return build_scenario("noisy-neighbor", seed=seed, n_ops=n_ops,
                              aggressor_rate=rate, **SCENARIO)

    # solo baselines: each steady tenant alone on a fresh engine.  Tenant
    # traces are seeded per tenant id, so the solo trace is byte-identical
    # to the one served in the contended modes.
    tenants, traces = scenario(agg_rates[0])
    for tid in _VICTIMS:
        rep = run_multi_tenant(
            _engine(), [t for t in tenants if t.tenant_id == tid],
            {tid: traces[tid]}, config=FRONTEND)
        rows.extend(_rows("solo", 0.0, rep))

    for rate in agg_rates:
        tenants, traces = scenario(rate)
        for fair in (True, False):
            rep = run_multi_tenant(_engine(), tenants, traces,
                                   config=FRONTEND, fair=fair)
            rows.extend(_rows("fair" if fair else "unfair", rate, rep))
    return rows


def check(rows) -> list[str]:
    out = []
    solo = {r["tenant"]: r for r in rows if r["mode"] == "solo"}
    fair = [r for r in rows if r["mode"] == "fair"]
    unfair = [r for r in rows if r["mode"] == "unfair"]
    top_rate = max((r["agg_rate"] for r in fair), default=0)

    # isolation: every victim's p99.9 stays within 2x its solo baseline at
    # every aggressor burst rate under weighted-fair admission.
    worst = 0.0
    for r in fair:
        if r["tenant"] in solo:
            worst = max(worst, r["insert_p999_ms"]
                        / max(solo[r["tenant"]]["insert_p999_ms"], 1e-9))
    tag = "matches paper" if 0.0 < worst <= 2.0 else "MISMATCH"
    out.append(f"tenancy: fair victims' insert p99.9 stays within 2x solo "
               f"at every burst rate (worst {worst:.2f}x)  [{tag}]")

    # the aggressor, not the victims, absorbs the shed (throttled against
    # its own bound) once its bursts exceed capacity.
    agg_shed = [r["n_shed"] for r in fair
                if r["tenant"] == _AGGRESSOR and r["agg_rate"] == top_rate]
    vic_shed = sum(r["n_shed"] for r in fair if r["tenant"] in solo)
    ok = bool(agg_shed) and agg_shed[0] > 0 and vic_shed == 0
    tag = "matches paper" if ok else "MISMATCH"
    out.append(f"tenancy: fair queuing sheds only the aggressor "
               f"(aggressor shed {agg_shed[0] if agg_shed else 0}, victims "
               f"shed {vic_shed})  [{tag}]")

    # the shared FIFO has no bound: victims' p99.9 blows past 2x solo and
    # the whole distribution keeps shifting with the burst rate (growth is
    # checked on p50 — p99.9 pins at the queue-cap delay early in the
    # sweep, the median keeps climbing toward it).
    lo_rate = min((r["agg_rate"] for r in unfair), default=0)
    grow = viol = False
    for tid in _VICTIMS:
        p999 = {r["agg_rate"]: r["insert_p999_ms"] for r in unfair
                if r["tenant"] == tid}
        p50 = {r["agg_rate"]: r["insert_p50_ms"] for r in unfair
               if r["tenant"] == tid}
        if not p999 or tid not in solo:
            continue
        viol = viol or max(p999.values()) \
            > 2.0 * solo[tid]["insert_p999_ms"]
        grow = grow or p50[top_rate] > p50[lo_rate]
    tag = "matches paper" if viol and grow else "MISMATCH"
    out.append("tenancy: shared-FIFO victims blow the 2x-solo bound and "
               f"degrade with burst rate (violated={viol}, "
               f"growing={grow})  [{tag}]")

    # differential: a victim that shed nothing applied its exact solo op
    # stream, so its final live pairs must match the solo run's.
    ok = all(r["live_pairs"] == solo[r["tenant"]]["live_pairs"]
             for r in fair if r["tenant"] in solo and r["n_shed"] == 0)
    tag = "matches paper" if ok else "MISMATCH"
    out.append(f"tenancy: no-shed fair victims reach their solo live-pair "
               f"state (namespace isolation)  [{tag}]")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/fig_tenancy.json")
    args = ap.parse_args(argv)
    kwargs = dict(QUICK_KWARGS) if args.quick else {}
    rows = run(seed=args.seed, **kwargs)
    checks = check(rows)
    for r in rows:
        print(r)
    for c in checks:
        print(" ->", c)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "seed": args.seed,
                   "quick": bool(args.quick), "rows": rows,
                   "checks": checks}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
