"""Shared transformer layers: norms, RoPE/M-RoPE, GQA/MQA/SWA attention, MLPs.

Conventions (MaxText-style):
  * parameters are plain pytrees (dicts of jnp arrays), bf16 by default;
  * all softmax / norm statistics accumulate in fp32;
  * attention is einsum-based so GSPMD can shard heads over the "model"
    mesh axis without reshapes crossing sharding boundaries;
  * decode uses a contiguous KV cache (B, S_max, KVH, D) updated with
    dynamic_update_slice; the serving engine swaps in the paged-attention
    Pallas kernel + NB-tree block tables (serve/kv_cache.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .blockwise_attn import blockwise_sdpa, should_use_blockwise

# --------------------------------------------------------------------- init
def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) / np.sqrt(fan_in)).astype(dtype)


# -------------------------------------------------------------------- norms
def rms_norm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale + bias


def norm_params(key, d, kind, dtype):
    if kind == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(x, p, kind, eps):
    if kind == "rms":
        return rms_norm(x, p["scale"], eps)
    return layer_norm(x, p["scale"], p["bias"], eps)


# --------------------------------------------------------------------- RoPE
def rope_angles(positions, dim, base):
    """positions (..., S) -> cos/sin (..., S, dim//2), fp32."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, D) with cos/sin (B, S, D//2) [or broadcastable]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


def mrope_angles(positions, dim, base, sections):
    """M-RoPE (Qwen2-VL): rotary dims partitioned into (t, h, w) sections.

    positions: (3, B, S) — temporal/height/width position ids.  For pure
    text the three rows are identical and M-RoPE reduces to RoPE exactly.
    """
    half = dim // 2
    assert sum(sections) == half, (sections, dim)
    cos_all, sin_all = rope_angles(positions, dim, base)   # (3, B, S, half)
    chunks_c, chunks_s = [], []
    start = 0
    for i, sec in enumerate(sections):
        chunks_c.append(cos_all[i, ..., start:start + sec])
        chunks_s.append(sin_all[i, ..., start:start + sec])
        start += sec
    return jnp.concatenate(chunks_c, -1), jnp.concatenate(chunks_s, -1)


# ---------------------------------------------------------------- attention
def attn_params(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(kq, (d, cfg.n_heads * hd), dtype),
        "wk": _dense_init(kk, (d, cfg.n_kv_heads * hd), dtype),
        "wv": _dense_init(kv, (d, cfg.n_kv_heads * hd), dtype),
        "wo": _dense_init(ko, (cfg.n_heads * hd, d), dtype, fan_in=cfg.n_heads * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _quantize_kv(t):
    """(B,S,KVH,D) -> int8 weights + (B,S,KVH) fp32 symmetric scales."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    w = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return w, scale


def _sdpa(q, k, v, mask):
    """q (B,S,H,D), k/v (B,T,KVH,D) -> (B,S,H,D); fp32 softmax; GQA grouping."""
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    q = q.reshape(B, S, KVH, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(D)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(v.dtype)


def causal_mask(S, T=None, window=None, offset=0):
    """(S, T) bool; True = attend.  offset = query-position of row 0."""
    T = T or S
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def attention(x, p, cfg, *, positions, kind="causal", window=None,
              cache=None, cache_index=None, true_index=None,
              mrope_positions=None):
    """Full-sequence or single-step (cache) attention.

    kind: "causal" | "bidir"; window enables SWA.  If ``cache`` is given, x
    is (B, 1, d), cache = dict(k, v, pos) of (B, kv_len, ...) — a *ring*
    when kv_len < context (SWA long-context decode): the new KV lands at
    slot ``cache_index`` (= true_index % kv_len) and masking uses the
    stored true positions, so rolled-over slots are handled exactly.
    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if cfg.mrope_sections is not None:
        pos3 = mrope_positions
        if pos3 is None:
            pos3 = jnp.broadcast_to(positions, (3,) + positions.shape)
        cos, sin = mrope_angles(pos3, hd, cfg.rope_base, cfg.mrope_sections)
    else:
        cos, sin = rope_angles(positions, hd, cfg.rope_base)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        if should_use_blockwise(B, S, S, cfg.n_heads):
            # flash-style blockwise path: O(chunk^2) attention memory.
            out = blockwise_sdpa(q, k, v, qpos=positions,
                                 kpos=positions, kind=kind, window=window)
        else:
            mask = causal_mask(S, window=window) if kind == "causal" else jnp.ones((S, S), bool)
            out = _sdpa(q, k, v, jnp.broadcast_to(mask, (B, S, S)))
        new_cache = {"k": k, "v": v}  # raw per-position KV for prefill cache
    else:
        tidx = true_index if true_index is not None else cache_index
        quant = cache["k"].dtype == jnp.int8
        if quant:
            # int8 KV: per (token, kv-head) symmetric scales.  Halves the
            # decode-dominant cache-read bytes (EXPERIMENTS.md §Perf It.7).
            k_w, k_s = _quantize_kv(k)
            v_w, v_s = _quantize_kv(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], k_w, (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v_w, (0, cache_index, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], k_s, (0, cache_index, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], v_s, (0, cache_index, 0))
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache_index, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((B, 1), tidx, jnp.int32), (0, cache_index))
        T = ck.shape[1]
        if should_use_blockwise(B, 1, T, cfg.n_heads):
            # decode masking == causal-vs-stored-positions (+ window)
            qpos = jnp.broadcast_to(jnp.asarray(tidx, jnp.int32), (B, 1))
            scales = (cks, cvs) if quant else None
            out = blockwise_sdpa(q, ck, cv, qpos=qpos, kpos=cpos,
                                 kind="causal", window=window,
                                 kv_scales=scales)
        else:
            m = (cpos <= tidx) & (cpos >= 0)
            if window is not None:
                m = m & (cpos > tidx - window)
            dk, dv = (ck, cv) if not quant else (
                ck.astype(jnp.float32) * cks[..., None],
                cv.astype(jnp.float32) * cvs[..., None])
            out = _sdpa(q, dk, dv, m[:, None, :]).astype(x.dtype)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        if quant:
            new_cache.update(k_scale=cks, v_scale=cvs)
    out = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    return out, new_cache


# --------------------------------------------------------------------- MLPs
def mlp_params(key, d, d_ff, kind, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wi": _dense_init(k1, (d, d_ff), dtype),
                "wg": _dense_init(k2, (d, d_ff), dtype),
                "wo": _dense_init(k3, (d_ff, d), dtype, fan_in=d_ff)}
    return {"wi": _dense_init(k1, (d, d_ff), dtype),
            "wo": _dense_init(k3, (d_ff, d), dtype, fan_in=d_ff)}


def mlp(x, p, kind):
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
