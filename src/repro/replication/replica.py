"""Replica nodes and replica groups: WAL shipping, promotion, rebuild.

DESIGN.md §12.  A :class:`ReplicaGroup` is one range-partition of the key
space served by a *primary* plus ``R - 1`` replicas, all running the same
storage engine.  Group commits ship the commit's WAL record (the exact
``repro.wal.log`` on-disk format, same LSN) to every in-sync replica;
each replica fsyncs into its **own** segment directory and acks at its own
charged fsync return.  The group acks the commit per the configured mode:

* ``"quorum"`` — primary fsync + enough replica fsyncs that a majority of
  the R copies hold the record (``R // 2 + 1`` total).  A commit is only
  *attempted* when the quorum is currently reachable, so an acked record
  always exists on a majority and a never-acked record exists nowhere
  (commits are atomic at group scope — the chaos harness fires between
  commits, never inside one).
* ``"primary"`` — ack at the primary's fsync alone.  Replicas still
  receive every record, but a primary lost before any replica existed
  (e.g. during a rebuild window) takes acked records with it; the report
  counts those as ``lost_acked_rows`` — the measurable price of the mode.

Replicas append + fsync synchronously but *apply* lazily (every
``apply_lag_commits`` commits), so promotion genuinely replays a WAL
tail.  Promotion picks the live replica with the highest **validated**
durable LSN (each candidate re-scans its segments first, so a corrupted
tail never inflates a claim), replays its pending tail into its engine,
and restarts the group LSN chain there.  Any other surviving replica not
exactly at the new chain head is retired and rebuilt — the invariant
``in-sync ⇒ durable_lsn == group chain head`` is what makes the quorum
arithmetic sound.

Rebuild = snapshot (``dump_live`` of the primary, charged at device
write bandwidth) + WAL catch-up (primary's records past the snapshot
LSN).  Catch-up verifies LSN contiguity; a gap (the primary's own tail
was corrupted and its chain re-anchored mid-rebuild) restarts the rebuild
from a fresh snapshot rather than admitting a hole.

Everything here runs on the deterministic sim clock: fsync and snapshot
costs are charged from the engine's device constants, so a whole
replicated run (chaos included) is a pure function of (trace, config,
schedule seed).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.cost_model import PAIR_BYTES, SSD
from repro.core.engine_api import OpBatch, StorageEngine
from repro.wal.faults import (ChaosEvent, ChaosKind, flip_wal_byte,
                              tear_wal_tail)
from repro.wal.log import WriteAheadLog


@dataclasses.dataclass(frozen=True)
class ReplicationConfig:
    """Knobs of the replication layer (DESIGN.md §12).

    ``replicas`` is the TOTAL copy count R (primary included): ``R = 1``
    is the unreplicated baseline (a dead primary fails its range
    permanently), ``R = 2`` the cheapest configuration that survives a
    primary kill with zero acked-write loss under quorum acks.
    """

    replicas: int = 2
    ack_mode: str = "quorum"            # or "primary"
    heartbeat_timeout_s: float = 0.05   # silence before declared dead
    apply_lag_commits: int = 8          # replica apply laziness (tail size)
    retry_backoff_s: float = 0.005      # parked-op first retry delay
    retry_backoff_max_s: float = 0.08   # exponential backoff cap
    retry_deadline_s: float = 1.5       # parked longer than this -> shed
    segment_bytes: int = 1 << 20        # per-node WAL segment size

    def __post_init__(self):
        assert self.replicas >= 1
        assert self.ack_mode in ("quorum", "primary")
        assert self.heartbeat_timeout_s > 0 and self.apply_lag_commits >= 1
        assert 0 < self.retry_backoff_s <= self.retry_backoff_max_s
        assert self.retry_deadline_s > 0 and self.segment_bytes >= 4096

    @property
    def quorum(self) -> int:
        """Copies (primary included) that must hold a record before ack."""
        return self.replicas // 2 + 1 if self.ack_mode == "quorum" else 1


class ReplicaNode:
    """One engine + one private WAL directory; primary or replica role.

    The node's WAL mirrors the *group's* LSN chain (records arrive with
    explicit LSNs).  ``_pending`` buffers durable-but-unapplied records in
    LSN order; it is always a faithful image of the WAL tail past
    ``applied_lsn`` — :meth:`rescan` re-derives the durable horizon from
    disk and drops buffered records the scan rejected, so a corrupted
    tail can never be replayed into the engine.
    """

    def __init__(self, node_id: str, engine: StorageEngine, wal_dir: str,
                 *, segment_bytes: int = 1 << 20):
        assert engine.stats().clock == "sim", \
            "replication runs on the deterministic sim clock only"
        self.node_id = node_id
        self.engine = engine
        self.wal_dir = wal_dir
        self._segment_bytes = int(segment_bytes)
        os.makedirs(wal_dir, exist_ok=True)
        self.wal = WriteAheadLog(wal_dir, segment_bytes=self._segment_bytes)
        cm = getattr(engine, "cm", None)
        self.device = cm.device if cm is not None else SSD
        self.alive = True
        self.synced = True
        self.dead_since: float | None = None
        self.stall_s = 0.0             # one-shot chaos fsync debit
        self.applied_lsn = 0
        self._pending: list = []       # (lsn, kinds, keys, vals), durable

    @property
    def durable_lsn(self) -> int:
        return self.wal.last_lsn

    # ---------------------------------------------------------------- append
    def append(self, kinds, keys, vals, lsn: int, *,
               buffer: bool = True) -> float:
        """Durably log one shipped record; returns charged fsync seconds.

        ``buffer=False`` is the primary's path (it applies synchronously,
        so nothing waits in ``_pending``).
        """
        _, nbytes = self.wal.append_commit(kinds, keys, vals, lsn=lsn)
        if buffer:
            self._pending.append((lsn, np.asarray(kinds, np.int8).copy(),
                                  np.asarray(keys, np.uint64).copy(),
                                  np.asarray(vals, np.int64).copy()))
        dev = self.device
        sec = dev.seek_s + nbytes / dev.write_bw + self.stall_s
        self.stall_s = 0.0
        return sec

    # ----------------------------------------------------------------- apply
    def apply_pending(self, upto: int | None = None) -> tuple[int, float]:
        """Apply buffered records with LSN <= ``upto`` (default: all).

        Returns ``(ops_applied, charged_engine_seconds)`` — promotion's
        replay cost comes straight from here.
        """
        upto = self.durable_lsn if upto is None else int(upto)
        io0 = self.engine.io_time_s()
        n = 0
        while self._pending and self._pending[0][0] <= upto:
            lsn, kinds, keys, vals = self._pending.pop(0)
            self.engine.apply(OpBatch(kinds, keys, vals,
                                      np.zeros(len(kinds), np.uint64)))
            self.engine.note_applied(lsn)
            self.engine.maintain(len(kinds))
            self.applied_lsn = lsn
            n += len(kinds)
        return n, self.engine.io_time_s() - io0

    def maybe_apply(self, lag: int) -> None:
        """Lazy replica apply: only when the tail exceeds ``lag`` commits."""
        if len(self._pending) >= lag:
            self.apply_pending()

    # ---------------------------------------------------------------- faults
    def crash(self, t: float) -> None:
        self.alive = False
        self.dead_since = t

    def rescan(self) -> int:
        """Re-derive the durable horizon from disk (post-corruption).

        Re-opens the WAL — the open scan truncates any invalid tail — and
        drops buffered records past the validated LSN.  Returns the LSNs
        lost (0 when the log was intact).
        """
        before = self.wal.last_lsn
        self.wal.close()
        self.wal = WriteAheadLog(self.wal_dir,
                                 segment_bytes=self._segment_bytes)
        self._pending = [r for r in self._pending
                         if r[0] <= self.wal.last_lsn]
        return before - self.wal.last_lsn

    def describe(self) -> dict:
        return {"id": self.node_id, "alive": self.alive,
                "synced": self.synced, "durable_lsn": int(self.durable_lsn),
                "applied_lsn": int(self.applied_lsn)}


class ReplicaGroup:
    """Primary + replicas for one key-range partition; see module doc."""

    def __init__(self, gid: int, directory: str, engine_factory, config:
                 ReplicationConfig, *, key_lo: int = 0, key_hi: int = 0):
        self.gid = int(gid)
        self.dir = directory
        self._factory = engine_factory
        self.config = config
        self.key_lo, self.key_hi = int(key_lo), int(key_hi)
        self._seq = 0
        self.nodes: list[ReplicaNode] = []
        self.primary: ReplicaNode | None = None
        self.last_lsn = 0                 # group commit chain head
        self.failed = False               # unrecoverable (no copy left)
        self.write_blocked_until = 0.0    # promotion-replay completion gate
        self.spike_factor = 1.0
        self.spike_until = -np.inf
        self.rebuilds: list[dict] = []    # in-flight snapshot+catch-up
        self.retired = 0                  # nodes replaced over the run
        self.failovers: list[dict] = []
        self.downtime_s = 0.0
        self.pending_down_t: float | None = None  # exact crash instant
        self.acked_rows = 0
        for k in range(config.replicas):
            node = self._new_node()
            self.nodes.append(node)
            if k == 0:
                self.primary = node

    # ------------------------------------------------------------ membership
    def _new_node(self) -> ReplicaNode:
        node_id = f"g{self.gid}/n{self._seq}"
        wal_dir = os.path.join(self.dir, f"n{self._seq}")
        self._seq += 1
        return ReplicaNode(node_id, self._factory(), wal_dir,
                           segment_bytes=self.config.segment_bytes)

    def replicas(self) -> list[ReplicaNode]:
        return [n for n in self.nodes if n is not self.primary]

    def synced_replicas(self) -> list[ReplicaNode]:
        return [n for n in self.replicas() if n.alive and n.synced]

    # ---------------------------------------------------------- availability
    def write_available(self, now: float) -> bool:
        """True when a commit attempted now would reach its ack quorum."""
        if self.failed or self.primary is None or not self.primary.alive:
            return False
        if now < self.write_blocked_until:
            return False
        return 1 + len(self.synced_replicas()) >= self.config.quorum

    def read_available(self, now: float) -> bool:
        """Reads are primary-only: alive primary past its promotion gate."""
        return (not self.failed and self.primary is not None
                and self.primary.alive and now >= self.write_blocked_until)

    def spike(self, now: float) -> float:
        return self.spike_factor if now < self.spike_until else 1.0

    # ---------------------------------------------------------------- commit
    def commit(self, kinds, keys, vals) -> tuple[int, float]:
        """Ship one group commit's writes to every in-sync copy.

        Only call when :meth:`write_available` — the caller-side gate is
        what makes commits atomic (a record is either on every in-sync
        copy and acked, or was never attempted).  Returns ``(lsn,
        charged_ack_seconds)``: the primary's fsync plus, under quorum
        acks, the ``quorum - 1``-th fastest replica fsync (the slower
        replicas finish in parallel, off the ack path).
        """
        lsn = self.last_lsn + 1
        sec = self.primary.append(kinds, keys, vals, lsn, buffer=False)
        rep_costs = sorted(r.append(kinds, keys, vals, lsn)
                           for r in self.synced_replicas())
        extra = self.config.quorum - 1
        if extra > 0:
            sec += rep_costs[extra - 1]
        self.last_lsn = lsn
        self.acked_rows += len(kinds)
        for r in self.synced_replicas():
            r.maybe_apply(self.config.apply_lag_commits)
        return lsn, sec

    def apply_primary(self, batch: OpBatch):
        """Synchronous primary apply (the serving-path engine work)."""
        res = self.primary.engine.apply(batch)
        self.primary.engine.note_applied(self.last_lsn)
        self.primary.applied_lsn = self.last_lsn
        return res

    # -------------------------------------------------------------- failover
    def promote(self, now: float) -> dict | None:
        """Primary declared dead: promote the most-caught-up live replica.

        Returns the failover record (appended to ``self.failovers``), or
        None when no live replica exists — the group is then failed for
        good (the unreplicated baseline's fate).
        """
        dead = self.primary
        t_crash = dead.dead_since if dead.dead_since is not None else now
        self.nodes = [n for n in self.nodes if n is not dead]
        self.retired += 1
        candidates = [n for n in self.nodes if n.alive]
        if not candidates:
            self.failed = True
            self.primary = None
            self.failovers.append({
                "gid": self.gid, "t_crash": float(t_crash),
                "t_detected": float(now), "outcome": "failed",
                "new_primary": None, "replayed_ops": 0,
                "promote_s": 0.0, "t_write_restored": None, "rto_s": None,
            })
            return None
        for n in candidates:
            n.rescan()                     # durable claims must be provable
        best = max(candidates, key=lambda n: n.durable_lsn)
        replayed, promote_s = best.apply_pending()
        self.primary = best
        best.synced = True
        self.last_lsn = best.durable_lsn
        self.write_blocked_until = now + promote_s
        # survivors not exactly at the new chain head cannot stay in-sync
        # (their next shipped record would leave a hole); rebuild them.
        for r in list(self.replicas()):
            if not r.alive or r.durable_lsn != self.last_lsn:
                self.nodes.remove(r)
                self.retired += 1
                self.begin_rebuild(now + promote_s)
            else:
                r.synced = True
        # replacement for the dead primary itself
        self.begin_rebuild(now + promote_s)
        ev = {
            "gid": self.gid, "t_crash": float(t_crash),
            "t_detected": float(now), "outcome": "promoted",
            "new_primary": best.node_id, "replayed_ops": int(replayed),
            "promote_s": float(promote_s),
            "t_promoted": float(now + promote_s),
            "t_write_restored": None, "rto_s": None,
        }
        self.failovers.append(ev)
        return ev

    def replace_replica(self, node: ReplicaNode, now: float) -> None:
        """A (non-primary) replica died or diverged: retire + rebuild."""
        if node in self.nodes:
            self.nodes.remove(node)
            self.retired += 1
        if not self.failed:
            self.begin_rebuild(now)

    # --------------------------------------------------------------- rebuild
    def begin_rebuild(self, t_start: float) -> dict | None:
        """Spawn a fresh replica: snapshot ship now, catch-up at ready.

        The snapshot (primary ``dump_live`` at the current chain head) is
        applied to the new engine immediately — host-side state motion —
        while the charged transfer time (device write bandwidth over the
        snapshot bytes) sets ``ready_at``; the node joins the in-sync set
        only after catch-up at that instant.  Commits meanwhile do not
        ship to it.
        """
        if self.failed or self.primary is None or not self.primary.alive:
            return None
        if len(self.nodes) + len(self.rebuilds) >= self.config.replicas:
            return None                  # already at full strength
        keys, vals = self.primary.engine.dump_live()
        node = self._new_node()
        if len(keys):
            node.engine.apply(OpBatch.inserts(keys, vals))
            node.engine.drain()
        node.engine.note_applied(self.last_lsn)
        node.applied_lsn = self.last_lsn
        node.synced = False
        dev = node.device
        transfer_s = dev.seek_s + len(keys) * PAIR_BYTES / dev.write_bw
        rb = {"node": node, "snap_lsn": int(self.last_lsn),
              "t_start": float(t_start), "snapshot_pairs": int(len(keys)),
              "ready_at": float(t_start + transfer_s)}
        self.rebuilds.append(rb)
        return rb

    def _catch_up(self, node: ReplicaNode, after_lsn: int) -> bool:
        """Replay the primary's records past ``after_lsn`` into ``node``.

        Verifies the replayed chain is contiguous through the current
        head; False (rebuild must restart) when the primary's own log has
        a hole in that span (its tail was corrupted and re-anchored after
        the snapshot was taken).
        """
        expect = after_lsn + 1
        for rec in self.primary.wal.replay(after_lsn=after_lsn):
            if rec.lsn != expect:
                return False
            node.append(rec.kinds, rec.keys, rec.vals, rec.lsn)
            expect = rec.lsn + 1
        if expect != self.last_lsn + 1:
            return False
        node.apply_pending()
        return True

    def poll_rebuilds(self, now: float) -> list[dict]:
        """Finish every rebuild whose snapshot transfer has completed."""
        done = []
        for rb in list(self.rebuilds):
            if rb["ready_at"] > now:
                continue
            self.rebuilds.remove(rb)
            if self.failed or self.primary is None or not self.primary.alive:
                continue                 # group died mid-rebuild
            if self._catch_up(rb["node"], rb["snap_lsn"]):
                rb["node"].synced = True
                self.nodes.append(rb["node"])
                done.append(rb)
            else:                        # hole in the primary's log: restart
                self.begin_rebuild(now)
        return done

    # ----------------------------------------------------------------- chaos
    def handle_event(self, ev: ChaosEvent, slot: str) -> None:
        """Apply one chaos event addressed to this group.

        ``slot`` is the stable address (``g<gid>`` = group scope /
        primary, ``g<gid>/primary``, ``g<gid>/r<k>``): it resolves to the
        *current* occupant at fire time, so a schedule written before any
        failover keeps naming meaningful victims afterwards.
        """
        if self.failed:
            return
        if ev.kind is ChaosKind.LATENCY_SPIKE:
            self.spike_factor = max(float(ev.arg), 1.0)
            self.spike_until = ev.t + max(ev.dur_s, 0.0)
            return
        node = self._resolve(slot)
        if node is None or not node.alive:
            return
        if ev.kind is ChaosKind.CRASH:
            node.crash(ev.t)
            if node is self.primary and self.pending_down_t is None:
                self.pending_down_t = ev.t
        elif ev.kind is ChaosKind.FSYNC_STALL:
            node.stall_s += float(ev.arg)
        elif ev.kind in (ChaosKind.TORN_SEGMENT, ChaosKind.BIT_FLIP):
            if ev.kind is ChaosKind.TORN_SEGMENT:
                tear_wal_tail(node.wal_dir)
            else:
                flip_wal_byte(node.wal_dir)
            lost = node.rescan()
            if node is not self.primary and lost > 0:
                # a rolled-back replica can no longer extend the chain
                # without a hole; it leaves the in-sync set and the next
                # tick retires + rebuilds it.  The primary's applied state
                # is unaffected by its own log damage (it applies
                # synchronously); its chain re-anchors on the next append.
                node.synced = False

    def _resolve(self, slot: str) -> ReplicaNode | None:
        part = slot.partition("/")[2]
        if part in ("", "primary"):
            return self.primary
        if part.startswith("r"):
            reps = sorted(self.replicas(), key=lambda n: n.node_id)
            k = int(part[1:])
            return reps[k] if k < len(reps) else None
        return None

    # ---------------------------------------------------------------- report
    def describe(self) -> dict:
        return {
            "gid": self.gid, "failed": self.failed,
            "chain_lsn": int(self.last_lsn),
            "acked_rows": int(self.acked_rows),
            "retired_nodes": int(self.retired),
            "rebuilds_in_flight": len(self.rebuilds),
            "downtime_s": float(self.downtime_s),
            "n_failovers": len(self.failovers),
            "primary": None if self.primary is None
            else self.primary.node_id,
            "nodes": [n.describe() for n in self.nodes],
        }
