"""The paper's own scenario: insertion-intensive store vs LSM vs B+-tree.

Reproduces the headline comparison (Figs 6-9) at demo scale through the
unified StorageEngine API — every index is driven by the same OpBatch
stream — and finishes with a mixed YCSB-A-style blend through the workload
driver (the measurement regime of the paper's LSM baselines).

  PYTHONPATH=src python examples/kvstore_demo.py
"""
import numpy as np

from repro.core.cost_model import HDD
from repro.core.engine_api import BulkBTreeEngine, OpBatch, make_engine
from repro.workloads import make_workload
from repro.workloads.driver import run_workload

n = 60_000
rng = np.random.default_rng(7)
keys = np.unique(rng.integers(1, 1 << 40, size=int(n * 1.02), dtype=np.uint64))[:n]
keys = rng.permutation(keys)
load = OpBatch.inserts(keys, np.arange(n, dtype=np.int64))

nb = make_engine("nbtree", f=3, sigma=2048, device=HDD)
lsm = make_engine("lsm", mem_pairs=2048, device=HDD)
nb_t = nb.apply(load).latency_s
lsm_t = lsm.apply(load).latency_s
nb.drain()
print(f"avg insert   : NB {nb.io_time_s()/n*1e6:8.1f} us | "
      f"LSM {lsm.io_time_s()/n*1e6:8.1f} us")
print(f"WORST insert : NB {nb_t.max()*1e3:8.3f} ms | LSM {lsm_t.max()*1e3:8.1f} ms  "
      f"(<-- the paper's 1000x, Fig. 7)")

bulk = BulkBTreeEngine(keys, np.arange(n, dtype=np.int64), device=HDD)
q = OpBatch.queries(rng.choice(keys, 300, replace=False))
nbq, lsmq, btq = (eng.apply(q).latency_s.mean() for eng in (nb, lsm, bulk))
print(f"avg query    : NB {nbq*1e3:6.2f} ms | LSM {lsmq*1e3:6.2f} ms | "
      f"B+bulk {btq*1e3:6.2f} ms   (Fig. 8)")

# range scans (1% selectivity): every engine serves the same inclusive API.
span = np.uint64((1 << 40) // 100)
los = rng.integers(1, (1 << 40) - int(span), 30).astype(np.uint64)
scan = OpBatch.ranges(los, los + span)
res = {}
for name, eng in (("NB", nb), ("LSM", lsm), ("B+bulk", bulk)):
    r = eng.apply(scan)
    res[name] = (r.latency_s.mean(), sum(len(rk) for rk, _ in r.range_hits))
assert len({h for _, h in res.values()}) == 1, "engines disagree on range hits"
print("range scan 1%: " + " | ".join(
    f"{k} {v[0]*1e3:6.2f} ms" for k, v in res.items())
    + f"   ({res['NB'][1] // len(los)} hits/query, all engines agree)")

# mixed load (YCSB-A-style 50/50 blend, zipfian keys) via the driver.
print("\nmixed ycsb-a : worst-case foreground delay under 50/50 insert/read")
for name, kw in (("nbtree", dict(f=3, sigma=1024, device=HDD)),
                 ("lsm", dict(mem_pairs=1024, device=HDD))):
    wl = make_workload("ycsb-a", key_space=1 << 20, n_ops=4096,
                       batch_size=256, preload=2048)
    rep = run_workload(make_engine(name, **kw), wl, maintain_budget=1)
    ins = rep["per_kind"]["insert"]
    print(f"  {name:>6}: insert p50 {ins['p50_s']*1e6:8.1f} us | "
          f"p100 {ins['p100_s']*1e3:8.3f} ms | "
          f"live pairs {rep['stats']['total_pairs']}")
