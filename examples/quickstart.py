"""Quickstart: the NB-tree as a key-value index — both tiers in 40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

# --- host tier: the paper's algorithm + I/O cost model --------------------
from repro.core.refimpl import NBTree

nb = NBTree(f=3, sigma=4096)
keys = np.random.default_rng(0).choice(
    np.arange(1, 1_000_000, dtype=np.uint64), 50_000, replace=False)
insert_times = [nb.insert(k, i) for i, k in enumerate(keys)]
nb.drain()
print(f"[host] inserted {len(keys)} pairs; "
      f"worst-case insert {max(insert_times)*1e3:.3f} ms, "
      f"height {nb.height}")
val, t = nb.query(keys[123])
print(f"[host] point query -> {val} in {t*1e3:.2f} ms (simulated HDD)")
nb.check_invariants()

# --- device tier: batched JAX index over Pallas kernels -------------------
from repro.core.jax_nbtree import NBTreeIndex

idx = NBTreeIndex(f=4, sigma=2048)
dev_keys = keys[:20_000].astype(np.uint32)
for i in range(0, len(dev_keys), 1024):
    idx.insert_batch(dev_keys[i:i+1024], np.arange(1024, dtype=np.int32)[: len(dev_keys[i:i+1024])])
    idx.maintain(2)                       # bounded upkeep per "step"
idx.drain()
present, vals = idx.query_batch(dev_keys[:4096])
print(f"[device] batched query: {int(np.asarray(present).sum())}/4096 found "
      f"(height {idx.height}, nodes {idx._next_id})")
idx.check_invariants()
print("OK")
