"""Pallas TPU kernel: batched range scan of a sorted run (range-query hot loop).

Range analogue of ``sorted_search``: per query ``(lo, hi)`` the kernel runs a
*lockstep* pair of binary searches over the VMEM-resident run — a lower bound
for ``lo`` (leftmost index with ``run[i] >= lo``) and an upper bound for
``hi`` (leftmost index with ``run[i] > hi``, i.e. the scan is inclusive on
both ends) — then performs a masked gather of the matching span into a
fixed-capacity output tile.  Both searches share the fori step counter, so
the kernel has no data-dependent control flow; the gather is a clamped
dynamic gather (tpu.DynamicGather), the only fast dynamic addressing mode
VMEM offers.

Overflow contract: the returned ``count`` is the *total* number of matching
pairs, which may exceed the output capacity; callers detect truncation via
``count > max_results`` and either re-issue with a larger tile or page
through the run.  KEY_MAX padding keys are never returned (the upper bound is
clamped to the live prefix), so ``hi = KEY_MAX - 1`` safely means "to the
end of the run".

Grid is over query tiles of SUBLANES queries; the run (keys + values) is
fully VMEM-resident and reused across all grid steps (constant index map).
Query blocks are (SUBLANES, 1) — lane-narrow, but the per-step output tile
(SUBLANES, cap) keeps the VPU busy on the gather/mask phase.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import KEY_MAX32

LANES = 128
SUBLANES = 8


def _take(arr, idx):
    return jnp.take(arr, idx, mode="clip")


def _range_scan_kernel(run_keys_ref, run_vals_ref, lo_ref, hi_ref,
                       keys_ref, vals_ref, count_ref, *, n: int, cap: int,
                       steps: int):
    run = run_keys_ref[...].reshape(-1)
    vals = run_vals_ref[...].reshape(-1)
    lo = lo_ref[...]                           # (SUBLANES, 1) uint32
    hi = hi_ref[...]

    # NB: the sentinel is materialized *inside* the kernel — pallas kernels
    # may not capture module-level traced constants.
    sentinel = jnp.uint32(0xFFFFFFFF)
    n_live = jnp.sum((run != sentinel).astype(jnp.int32))

    def bound(q, closed: bool):
        """Leftmost i with run[i] >= q (closed=False) or run[i] > q (True)."""
        l = jnp.zeros(q.shape, jnp.int32)
        h = jnp.full(q.shape, n, jnp.int32)
        for _ in range(steps):
            mid = (l + h) >> 1
            probe = _take(run, jnp.clip(mid, 0, n - 1))
            go = (l < h) & ((probe <= q) if closed else (probe < q))
            l = jnp.where(go, mid + 1, l)
            h = jnp.where(go, h, mid)
        return l

    start = bound(lo, False)
    end = jnp.minimum(bound(hi, True), n_live)   # clamp: padding never matches
    count = jnp.maximum(end - start, 0)

    col = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, cap), 1)
    idx = start + col                            # (SUBLANES, cap)
    valid = idx < end                            # empty when lo > hi
    safe = jnp.clip(idx, 0, n - 1)
    keys_ref[...] = jnp.where(valid, _take(run, safe), sentinel)
    vals_ref[...] = jnp.where(valid, _take(vals, safe), 0)
    count_ref[...] = count


@functools.partial(jax.jit, static_argnames=("max_results", "interpret"))
def range_scan(run_keys, run_vals, lo, hi, *, max_results: int = 128,
               interpret: bool = True):
    """Inclusive range scan ``[lo, hi]`` of ``queries`` over one sorted run.

    Returns ``(keys uint32 (Q, max_results), vals int32 (Q, max_results),
    count int32 (Q,))``: per query the first ``max_results`` matching pairs in
    key order (KEY_MAX / 0 padded) and the *total* match count (may exceed
    ``max_results`` — the truncation signal).  Q is padded to a SUBLANES
    multiple internally and sliced back.
    """
    q_raw = lo.shape[0]
    qn = max(SUBLANES, -(-q_raw // SUBLANES) * SUBLANES)
    # pad queries with an empty range (lo=1 > hi=0) so pad lanes match nothing
    lo = jnp.pad(lo, (0, qn - q_raw), constant_values=1)
    hi = jnp.pad(hi, (0, qn - q_raw), constant_values=0)

    n_raw = run_keys.shape[0]
    n = max(LANES, -(-n_raw // LANES) * LANES)
    run_keys = jnp.pad(run_keys, (0, n - n_raw), constant_values=KEY_MAX32)
    run_vals = jnp.pad(run_vals, (0, n - n_raw), constant_values=0)

    cap = max(LANES, -(-max_results // LANES) * LANES)
    steps = math.ceil(math.log2(n + 1)) + 1
    kernel = functools.partial(_range_scan_kernel, n=n, cap=cap, steps=steps)

    run2 = run_keys.reshape(n // LANES, LANES)
    vals2 = run_vals.reshape(n // LANES, LANES)
    lo2 = lo.reshape(qn, 1)
    hi2 = hi.reshape(qn, 1)

    full = pl.BlockSpec((n // LANES, LANES), lambda t: (0, 0))
    qspec = pl.BlockSpec((SUBLANES, 1), lambda t: (t, 0))
    ospec = pl.BlockSpec((SUBLANES, cap), lambda t: (t, 0))
    keys, vals, count = pl.pallas_call(
        kernel,
        grid=(qn // SUBLANES,),
        in_specs=[full, full, qspec, qspec],
        out_specs=[ospec, ospec, qspec],
        out_shape=[
            jax.ShapeDtypeStruct((qn, cap), jnp.uint32),
            jax.ShapeDtypeStruct((qn, cap), jnp.int32),
            jax.ShapeDtypeStruct((qn, 1), jnp.int32),
        ],
        interpret=interpret,
    )(run2, vals2, lo2, hi2)
    return (keys[:q_raw, :max_results], vals[:q_raw, :max_results],
            count[:q_raw, 0])
