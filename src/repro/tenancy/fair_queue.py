"""Weighted-fair admission: deficit round-robin over per-tenant queues.

The single-tenant frontend (``repro.ingest.frontend``) admits through one
bounded FIFO — an aggressor that bursts faster than the engine drains
fills the shared queue and every co-tenant's ops get shed or stall behind
the backlog.  This module replaces that FIFO with one *bounded queue per
tenant* plus a deficit-round-robin (DRR) scheduler deciding whose ops the
next group commit serves:

* **Isolation at admission.**  ``offer`` sheds against the offering
  tenant's *own* bound only; an aggressor overflows its own queue while
  its co-tenants' queues stay shallow.  Shed counts are per-tenant.
* **Weighted service.**  Each scheduler round credits every backlogged
  tenant ``quantum x weight`` ops of *deficit*; ``take`` drains a
  tenant's queue only down to its deficit.  Over any backlogged interval
  tenant service converges to the weight ratio — the classic DRR
  guarantee (the error is bounded by one quantum per tenant per round).
* **Work conservation.**  ``take`` never idles while any queue is
  non-empty: the round-robin pointer skips empty queues and deficits
  reset when a queue empties, so credit cannot be hoarded while idle.

Ops are held as ``(t_arrive, local index)`` pairs against the tenant's
trace — the queue stores positions, not payloads, mirroring the
single-tenant frontend.
"""
from __future__ import annotations

import collections
import dataclasses


@dataclasses.dataclass
class _TenantState:
    weight: float
    max_queue: int
    q: collections.deque = dataclasses.field(
        default_factory=collections.deque)
    deficit: float = 0.0
    offered: int = 0
    shed: int = 0
    served: int = 0
    depth_max: int = 0


class WeightedFairQueue:
    """Per-tenant bounded queues + DRR pick; see module docstring.

    ``quantum`` is the per-round deficit credit of a weight-1.0 tenant, in
    ops.  It should be of the order of the group-commit batch size: much
    smaller wastes scheduler rounds, much larger degrades fairness
    granularity toward FIFO bursts.
    """

    def __init__(self, *, quantum: int = 64):
        assert quantum >= 1
        self.quantum = int(quantum)
        self._tenants: dict[int, _TenantState] = {}
        self._order: list[int] = []       # round-robin scan order (sorted)
        self._cursor = 0                  # next tenant the scan starts from
        self._mid_visit = False           # cursor tenant holds unspent credit

    def add_tenant(self, tenant_id: int, *, weight: float = 1.0,
                   max_queue: int = 4096) -> None:
        tid = int(tenant_id)
        assert tid not in self._tenants, f"tenant {tid} already registered"
        assert weight > 0 and max_queue >= 1
        self._tenants[tid] = _TenantState(float(weight), int(max_queue))
        self._order = sorted(self._tenants)
        self._cursor = 0

    # ----------------------------------------------------------- admission
    def offer(self, tenant_id: int, item) -> bool:
        """Enqueue one op for ``tenant_id``; False = shed (queue full)."""
        st = self._tenants[int(tenant_id)]
        st.offered += 1
        if len(st.q) >= st.max_queue:
            st.shed += 1
            return False
        st.q.append(item)
        st.depth_max = max(st.depth_max, len(st.q))
        return True

    # ------------------------------------------------------------- service
    def take(self, max_ops: int) -> list:
        """Dequeue up to ``max_ops`` items as ``(tenant_id, item)`` pairs.

        Runs DRR *visits* until the budget is filled or every queue is
        empty.  A visit credits the tenant ``quantum x weight`` once, then
        serves down to its deficit; a visit cut short by the op budget (not
        by an exhausted deficit) resumes at the same tenant with its
        *remaining* credit on the next call — never a fresh quantum —
        which is what stops a deep-queued tenant from re-crediting itself
        every group commit and monopolizing the server.  Across calls the
        cursor persists, so no tenant is systematically scanned first.
        """
        out: list = []
        n = len(self._order)
        if n == 0 or max_ops <= 0:
            return out
        idle_scans = 0
        while len(out) < max_ops and idle_scans < n:
            tid = self._order[self._cursor]
            st = self._tenants[tid]
            if not st.q:
                st.deficit = 0.0          # credit must not accrue while idle
                self._mid_visit = False
                self._cursor = (self._cursor + 1) % n
                idle_scans += 1
                continue
            idle_scans = 0
            if not self._mid_visit:
                st.deficit += self.quantum * st.weight
                self._mid_visit = True
            while st.q and st.deficit >= 1.0 and len(out) < max_ops:
                out.append((tid, st.q.popleft()))
                st.deficit -= 1.0
                st.served += 1
            if not st.q:
                st.deficit = 0.0
            if st.q and st.deficit >= 1.0:
                break       # op budget cut the visit short: resume here
            # visit complete (deficit spent or queue drained): move on.
            self._mid_visit = False
            self._cursor = (self._cursor + 1) % n
        return out

    # --------------------------------------------------------------- state
    def heads(self) -> list:
        """``(tenant_id, item)`` at the head of every non-empty queue."""
        return [(tid, self._tenants[tid].q[0])
                for tid in self._order if self._tenants[tid].q]

    def backlog(self, tenant_id: int | None = None) -> int:
        if tenant_id is not None:
            return len(self._tenants[int(tenant_id)].q)
        return sum(len(st.q) for st in self._tenants.values())

    @property
    def tenant_ids(self) -> list[int]:
        return list(self._order)

    def stats(self) -> dict:
        """Per-tenant admission ledger (JSON-ready)."""
        return {
            str(tid): {
                "weight": st.weight,
                "max_queue": st.max_queue,
                "offered": st.offered,
                "shed": st.shed,
                "served": st.served,
                "backlog": len(st.q),
                "depth_max": st.depth_max,
            }
            for tid, st in self._tenants.items()
        }
