"""Replication layer tests (DESIGN.md §12).

Covers the WAL-shipping replica groups end to end: explicit-LSN appends
and chain re-anchoring in the log, the chaos DSL and seeded random
schedules, float-clock heartbeats, quorum vs primary ack modes, the full
kill-primary failover path (promotion, WAL-tail replay, rebuild), the
R=1 counterfactual, checkpoint CRC verification with provable-step
fallback, the straggler-aware maintenance allocator, and the seeded
chaos soak.

The soak's differential invariant — the one the whole layer exists for:

* **zero lost acked writes** — every row whose quorum fsync returned is
  in the surviving ensemble after every failover the schedule caused;
* **zero resurrected unacked writes** — nothing that was never acked
  appears.
"""
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (CheckpointError, Checkpointer,
                                           EngineCheckpointer)
from repro.core.engine_api import OpKind, make_engine
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.ingest import FrontendConfig, PoissonArrivals, make_trace
from repro.replication import (ReplicatedFrontend, ReplicaGroup,
                               ReplicationConfig)
from repro.shard.partition import RangePartitioner
from repro.shard.scheduler import DebtScheduler
from repro.wal import ChaosKind, FaultSchedule, WriteAheadLog
from repro.workloads import make_workload

ENGINE_KW = dict(f=3, sigma=256)
FRONTEND = FrontendConfig(max_queue=4096, commit_ops=32, linger_s=2e-4)


def _factory():
    return make_engine("nbtree", **ENGINE_KW)


def _trace(n_ops, seed=0, rate=40_000.0, mix="insert-heavy", preload=1024):
    wl = make_workload(mix, key_space=1 << 20, n_ops=n_ops, preload=preload,
                       batch_size=128, seed=seed)
    return make_trace(wl, PoissonArrivals(rate))


def _frontend(tmp_path, *, groups=3, replicas=2, chaos=None, **rep_kw):
    rep = ReplicationConfig(replicas=replicas,
                            heartbeat_timeout_s=rep_kw.pop(
                                "heartbeat_timeout_s", 0.005), **rep_kw)
    return ReplicatedFrontend(_factory, str(tmp_path), groups=groups,
                              replication=rep, config=FRONTEND, chaos=chaos,
                              window_s=0.01)


def _differential(fe, trace):
    """(lost_acked, resurrected, lost_range) vs the acked-prefix oracle."""
    oracle = {int(k): int(v) for k, v in zip(trace.preload.keys,
                                             trace.preload.vals)}
    for _gid, _lsn, kinds, keys, vals in fe.acked:
        for kk, k, v in zip(kinds.tolist(), keys.tolist(), vals.tolist()):
            if kk == int(OpKind.INSERT):
                oracle[int(k)] = int(v)
            elif kk == int(OpKind.DELETE):
                oracle.pop(int(k), None)
    failed = {g.gid for g in fe.groups if g.failed}
    live = {}
    for g in fe.groups:
        if g.gid not in failed:
            lk, lv = g.primary.engine.dump_live()
            live.update(zip(lk.tolist(), lv.tolist()))
    okeys = np.fromiter(oracle.keys(), np.uint64, len(oracle))
    gids = fe.partitioner.shard_of(okeys)
    lost_range = sum(int(g) in failed for g in gids)
    lost = sum(1 for k, g in zip(okeys.tolist(), gids)
               if int(g) not in failed
               and (int(k) not in live or live[int(k)] != oracle[int(k)]))
    res = sum(1 for k in live if k not in oracle)
    return lost, res, lost_range


# ------------------------------------------------------------------ wal / dsl
def test_wal_explicit_lsn_reanchors_chain(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_bytes=1 << 20)
    kinds = np.full(4, int(OpKind.INSERT), np.int8)
    keys = np.arange(4, dtype=np.uint64)
    vals = keys.astype(np.int64)
    assert wal.append_commit(kinds, keys, vals)[0] == 1
    assert wal.append_commit(kinds, keys, vals, lsn=2)[0] == 2
    # a gap (fresh replica starting at a snapshot LSN) forces rotation so
    # each segment's internal chain stays contiguous
    segs = wal.n_segments
    assert wal.append_commit(kinds, keys, vals, lsn=10)[0] == 10
    assert wal.n_segments == segs + 1
    assert [r.lsn for r in wal.replay()] == [1, 2, 10]
    with pytest.raises(AssertionError):
        wal.append_commit(kinds, keys, vals, lsn=5)   # LSNs must advance
    wal.close()


def test_fault_schedule_parse_fire_and_describe():
    fs = FaultSchedule.parse(
        "crash@0.5:g0/primary;fsync_stall@0.1:g1/r0:0.02;"
        "latency_spike@0.2:g0:8:0.5")
    assert fs.pending == 3
    seen = []
    fs.register("g0/primary", lambda ev: seen.append(ev))
    fs.register("g1/r0", lambda ev: seen.append(ev))
    fired = fs.fire_due(0.25)          # spike has no handler -> unrouted
    assert [e.kind for e in fired] == [ChaosKind.FSYNC_STALL,
                                       ChaosKind.LATENCY_SPIKE]
    assert len(seen) == 1 and seen[0].arg == pytest.approx(0.02)
    assert len(fs.unrouted) == 1
    assert fs.next_time == pytest.approx(0.5)
    fs.fire_due(1.0)
    assert fs.pending == 0 and fs.next_time is None
    assert "crash" in str(fs.describe())


def test_random_schedule_spaces_destructive_hits_per_group():
    targets = [f"g{g}/{who}" for g in range(3)
               for who in ("primary", "r0")] + [f"g{g}" for g in range(3)]
    fs = FaultSchedule.random(40, seed=7, t_lo=0.0, t_hi=1.0,
                              targets=targets, min_gap_s=0.25)
    destructive = {ChaosKind.CRASH, ChaosKind.TORN_SEGMENT,
                   ChaosKind.BIT_FLIP}
    last = {}
    for ev in fs.events:
        if ev.kind in destructive:
            g = ev.target.split("/")[0]
            assert ev.t - last.get(g, -1e9) >= 0.25
            last[g] = ev.t
    # determinism: same seed, same schedule
    fs2 = FaultSchedule.random(40, seed=7, t_lo=0.0, t_hi=1.0,
                               targets=targets, min_gap_s=0.25)
    assert fs.events == fs2.events


def test_heartbeat_monitor_float_sim_time():
    m = HeartbeatMonitor(["g0/n0", "g0/n1"], timeout=0.005)
    m.beat("g0/n0", 0.0401)
    m.beat("g0/n1", 0.0403)
    assert m.advance(0.0442) == []
    m.beat("g0/n1", 0.0445)
    assert m.advance(0.0455) == ["g0/n0"]      # declared exactly once
    m.beat("g0/n1", 0.089)
    assert m.advance(0.09) == []               # no re-declaration of n0
    assert not m.beat("g0/n0", 0.091)          # late beat can't resurrect
    m.revive("g0/n0", 0.091)
    m.beat("g0/n1", 0.093)
    assert m.advance(0.095) == []
    # original trainer call sites: integer steps via timeout_steps alias
    t = HeartbeatMonitor([0, 1], timeout_steps=3)
    t.beat(0, 2)
    assert t.advance(4) == [1]


def test_range_partitioner_even():
    p = RangePartitioner.even(4, 1 << 20)
    assert p.n_shards == 4
    gids = p.shard_of(np.asarray([0, (1 << 18) + 5, (1 << 19) + 5,
                                  (3 << 18) + 5, (1 << 20) - 1], np.uint64))
    assert gids.tolist() == [0, 1, 2, 3, 3]


# ------------------------------------------------------------- replica groups
def test_group_commit_quorum_vs_primary_ack(tmp_path):
    kinds = np.full(8, int(OpKind.INSERT), np.int8)
    keys = np.arange(8, dtype=np.uint64)
    vals = keys.astype(np.int64)
    gq = ReplicaGroup(0, str(tmp_path / "q"), _factory,
                      ReplicationConfig(replicas=3, ack_mode="quorum"),
                      key_lo=0, key_hi=1 << 20)
    lsn, s_quorum = gq.commit(kinds, keys, vals)
    assert lsn == 1
    # the record is durable on the primary and every in-sync replica
    assert gq.primary.durable_lsn == 1
    assert all(r.durable_lsn == 1 for r in gq.replicas())
    gp = ReplicaGroup(0, str(tmp_path / "p"), _factory,
                      ReplicationConfig(replicas=3, ack_mode="primary"),
                      key_lo=0, key_hi=1 << 20)
    _, s_primary = gp.commit(kinds, keys, vals)
    # primary-only ack never waits on a replica leg
    assert s_primary <= s_quorum


def test_failover_promotes_most_caught_up_replica(tmp_path):
    chaos = FaultSchedule.parse("crash@0.02:g1/primary")
    fe = _frontend(tmp_path, chaos=chaos)
    trace = _trace(2_500)
    report = fe.run(trace)
    rep = report["replication"]
    assert rep["failed_groups"] == []
    assert len(rep["failovers"]) == 1
    ev = rep["failovers"][0]
    assert ev["gid"] == 1 and ev["outcome"] == "promoted"
    assert ev["t_detected"] >= 0.02 and ev["rto_s"] > 0
    assert ev["new_primary"].startswith("g1/")
    assert ev["replayed_ops"] >= 0
    lost, res, lost_range = _differential(fe, trace)
    assert (lost, res, lost_range) == (0, 0, 0)
    # the affected group went down and came back; others never blinked
    avail = {a["gid"]: a["downtime_s"] for a in rep["availability"]}
    assert avail[1] > 0
    assert avail[0] == 0 and avail[2] == 0


def test_r1_kill_loses_the_range_permanently(tmp_path):
    chaos = FaultSchedule.parse("crash@0.02:g1/primary")
    fe = _frontend(tmp_path, replicas=1, chaos=chaos)
    trace = _trace(2_500)
    report = fe.run(trace)
    rep = report["replication"]
    assert rep["failed_groups"] == [1]
    assert rep["lost_acked_rows_failed_groups"] > 0
    assert report["n_shed"] > 0                # deadline-shed, not hung
    lost, res, lost_range = _differential(fe, trace)
    assert (lost, res) == (0, 0)               # survivors stay exact
    assert lost_range > 0


def test_clean_run_has_no_failovers(tmp_path):
    fe = _frontend(tmp_path)
    trace = _trace(1_500)
    report = fe.run(trace)
    rep = report["replication"]
    assert rep["failovers"] == [] and rep["failed_groups"] == []
    assert report["n_shed"] == 0
    assert _differential(fe, trace) == (0, 0, 0)


def test_chaos_soak_differential(tmp_path):
    """10k-op seeded soak under a random schedule: the two invariants hold
    across every failover the schedule causes."""
    groups = 3
    targets = ([f"g{g}/primary" for g in range(groups)]
               + [f"g{g}/r0" for g in range(groups)]
               + [f"g{g}" for g in range(groups)])
    chaos = FaultSchedule.random(24, seed=99, t_lo=0.01, t_hi=0.22,
                                 targets=targets, min_gap_s=0.30,
                                 stall_s=0.002, spike=6.0,
                                 spike_dur_s=0.02)
    fe = _frontend(tmp_path, groups=groups, chaos=chaos)
    trace = _trace(10_000, seed=3)
    report = fe.run(trace)
    rep = report["replication"]
    assert rep["failed_groups"] == []
    assert len(fe.chaos.unrouted) == 0
    lost, res, lost_range = _differential(fe, trace)
    assert (lost, res, lost_range) == (0, 0, 0)
    assert rep["acked_commits"] > 0


# ------------------------------------------------------------ checkpoint crc
def test_checkpointer_crc_scrub_and_restore_error(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": np.arange(6, dtype=np.float32)}
    ck.save(1, tree)
    ck.save(2, {"w": np.arange(6, dtype=np.float32) * 2})
    assert ck.scrub()["clean"]
    fp = tmp_path / "step_2" / "w.npy"
    raw = bytearray(fp.read_bytes())
    raw[-1] ^= 0xFF
    fp.write_bytes(bytes(raw))
    rep = ck.scrub()
    assert not rep["clean"]
    assert rep["steps"]["2"]["bad"] and not rep["steps"]["1"]["bad"]
    with pytest.raises(CheckpointError, match=r"checksum mismatch in .*w"):
        ck.restore(2, tree)
    ck.restore(1, tree)                        # older step still provable


def test_engine_checkpointer_falls_back_past_corruption(tmp_path):
    ck = EngineCheckpointer(str(tmp_path))
    for lsn in (5, 9):
        ck.save_snapshot(lsn, np.arange(lsn, dtype=np.uint64),
                         np.arange(lsn, dtype=np.int64))
    fp = tmp_path / "step_9" / "keys.npy"
    raw = bytearray(fp.read_bytes())
    raw[len(raw) // 2] ^= 0x42
    fp.write_bytes(bytes(raw))
    lsn, keys, _vals = ck.load_latest_snapshot()
    assert lsn == 5 and len(keys) == 5         # provable step wins
    fp5 = tmp_path / "step_5" / "keys.npy"
    raw = bytearray(fp5.read_bytes())
    raw[-1] ^= 0x01
    fp5.write_bytes(bytes(raw))
    with pytest.raises(CheckpointError, match="checksum mismatch"):
        ck.load_latest_snapshot()              # nothing provable left


# ------------------------------------------------------- straggler scheduling
def test_straggler_boost_drains_slow_shard():
    def simulate(flag_straggler: bool) -> int:
        """Peak outstanding debt of shard 1 over a steady arrival stream."""
        sched = DebtScheduler(straggler_boost=2.0)
        debts, peak = [0, 0], 0
        for _ in range(60):
            debts = [d + 2 for d in debts]
            alloc = sched.allocate(debts, 3,
                                   stragglers=(1,) if flag_straggler else ())
            debts = [d - a for d, a in zip(debts, alloc)]
            peak = max(peak, debts[1])
        return peak

    assert simulate(True) < simulate(False)

    # flag or no flag, only owed units are granted, and with no straggler
    # the policy is bit-identical to the unweighted allocator
    a = DebtScheduler().allocate([5, 0, 3], 10, stragglers=(1,))
    assert a == [5, 0, 3]
    x = DebtScheduler().allocate([4, 4, 4], 7)
    y = DebtScheduler().allocate([4, 4, 4], 7, stragglers=())
    assert x == y


def test_sharded_engine_records_straggler_samples():
    from repro.core.engine_api import OpBatch

    eng = make_engine("sharded:nbtree", shards=3, f=3, sigma=64)
    rng = np.random.default_rng(0)
    for _ in range(6):
        keys = rng.integers(0, 1 << 20, 256).astype(np.uint64)
        eng.apply(OpBatch.inserts(keys, keys.astype(np.int64)))
        eng.maintain(4)
    assert eng._straggle is not None and eng._straggle.samples > 0
    eng.drain()


def test_single_engine_frontend_chaos_wal_target(tmp_path):
    """The DSL's default target ``"wal"`` routes to the single-engine
    frontend: a stall charges the next commit's fsync exactly once, a
    spike scales charged service inside its window, and CRASH propagates
    out of the loop like an injector kill."""
    from repro.ingest import DurabilityConfig, IngestFrontend
    from repro.wal.faults import SimulatedCrash

    trace = _trace(1500, seed=5)
    base = IngestFrontend(
        _factory(), FRONTEND,
        durability=DurabilityConfig(str(tmp_path / "base"))).run(trace)
    sched = FaultSchedule.parse(
        "fsync_stall@0.002::0.01;latency_spike@0.006::8:0.01")
    fe = IngestFrontend(
        _factory(), FRONTEND,
        durability=DurabilityConfig(str(tmp_path / "chaos")), chaos=sched)
    rep = fe.run(trace)
    assert rep["chaos"]["pending"] == 0
    assert len(rep["chaos"]["fired"]) == 2 and not rep["chaos"]["unrouted"]
    # the stall alone adds 10ms of charged fsync; the spike multiplies on top
    assert (rep["durability"]["wal"]["service_s_total"]
            > base["durability"]["wal"]["service_s_total"] + 0.009)
    assert rep["per_kind_e2e"]["insert"]["p100_s"] \
        > base["per_kind_e2e"]["insert"]["p100_s"]

    fe2 = IngestFrontend(
        _factory(), FRONTEND,
        durability=DurabilityConfig(str(tmp_path / "crash")),
        chaos=FaultSchedule.parse("crash@0.004"))
    with pytest.raises(SimulatedCrash):
        fe2.run(trace)
    assert fe2.acked  # some commits were acked before the kill
