"""Unified StorageEngine protocol over every index tier (DESIGN.md §5).

The paper's headline claims are *comparative* — NB-tree vs LSM-tree vs
B+-tree vs B^eps-tree on insertion rate, query latency, and worst-case
delay — so every benchmark, test and demo must be able to stream the same
operation sequence through any engine and read back the same shaped
answers.  This module is that surface:

* :class:`OpBatch` — a columnar batch of operations (``INSERT`` /
  ``DELETE`` / ``QUERY`` / ``RANGE``), the only way work enters an engine;
* :class:`OpResult` — per-op visible results plus per-op latency (simulated
  I/O seconds on the cost-model tiers, host wall-clock on the device tier);
* :class:`StorageEngine` — ``apply(OpBatch) -> OpResult``,
  ``maintain(budget) -> pending``, ``drain()``, and a uniform ``stats()``
  snapshot (:class:`EngineStats`);
* thin adapters that retrofit the five tiers (``refimpl.NBTree``,
  ``lsm.LSMTree``, ``btree.BPlusTree``, ``bepsilon.BEpsilonTree`` and the
  device-tier ``jax_nbtree.NBTreeIndex``) onto the protocol, keeping the
  existing classes as the implementation core;
* an engine registry (:func:`register_engine` / :func:`make_engine`), with
  :data:`FIVE_TIERS` naming the paper's comparison set; the
  ``sharded:<base>`` prefix builds a range-partitioned ensemble of any
  registered engine (``repro.shard``, DESIGN.md §6).

Semantics are sequential within a batch: op i+1 observes op i.  Adapters
may still vectorize — the device adapter groups maximal same-kind runs into
one fused device call, which preserves the sequential semantics because
``insert_batch`` resolves intra-batch duplicates newest-wins and queries
cannot appear inside an insert group.

Key/value domain: keys are uint64 on the cost-model tiers and uint32 on the
device tier, so a workload that must run on *all* tiers keeps its keys in
``[1, 2^31)``; values must be non-negative int32-representable (the
tombstone sentinels ``sorted_run.TOMBSTONE`` = -1 and ``TOMBSTONE32`` are
reserved).  The workload generator (``repro.workloads``) enforces both.
"""
from __future__ import annotations

import abc
import dataclasses
import enum
import time

import numpy as np

from repro.obs.metrics import LogBucketHistogram

from .bepsilon import BEpsilonTree
from .btree import BPlusTree, BPlusTreeBulk
from .cost_model import HDD, CostModel, Device
from .lsm import LSMTree
from .refimpl import NBTree
from .sorted_run import KEY_DTYPE, TOMBSTONE, VAL_DTYPE


class OpKind(enum.IntEnum):
    INSERT = 0
    DELETE = 1
    QUERY = 2
    RANGE = 3


class UnsupportedOp(RuntimeError):
    """Raised by engines that cannot serve an op kind (e.g. bulk B+-tree inserts)."""


@dataclasses.dataclass
class OpBatch:
    """Columnar operation batch: parallel arrays, one row per op.

    ``keys`` is the op key (RANGE: inclusive lower bound), ``vals`` the
    INSERT payload (ignored elsewhere), ``his`` the RANGE inclusive upper
    bound (ignored elsewhere).
    """

    kinds: np.ndarray   # int8   (B,)
    keys: np.ndarray    # uint64 (B,)
    vals: np.ndarray    # int64  (B,)
    his: np.ndarray     # uint64 (B,)

    def __post_init__(self):
        self.kinds = np.asarray(self.kinds, np.int8)
        self.keys = np.asarray(self.keys, KEY_DTYPE)
        self.vals = np.asarray(self.vals, VAL_DTYPE)
        self.his = np.asarray(self.his, KEY_DTYPE)
        n = len(self.kinds)
        assert self.keys.shape == self.vals.shape == self.his.shape == (n,), \
            "OpBatch arrays must be parallel 1-d arrays of one length"

    def __len__(self) -> int:
        return len(self.kinds)

    # ------------------------------------------------------------- constructors
    @staticmethod
    def inserts(keys, vals) -> "OpBatch":
        keys = np.asarray(keys, KEY_DTYPE)
        return OpBatch(np.full(len(keys), OpKind.INSERT, np.int8), keys,
                       np.asarray(vals, VAL_DTYPE), np.zeros(len(keys), KEY_DTYPE))

    @staticmethod
    def deletes(keys) -> "OpBatch":
        keys = np.asarray(keys, KEY_DTYPE)
        z = np.zeros(len(keys), KEY_DTYPE)
        return OpBatch(np.full(len(keys), OpKind.DELETE, np.int8), keys,
                       np.zeros(len(keys), VAL_DTYPE), z)

    @staticmethod
    def queries(keys) -> "OpBatch":
        keys = np.asarray(keys, KEY_DTYPE)
        z = np.zeros(len(keys), KEY_DTYPE)
        return OpBatch(np.full(len(keys), OpKind.QUERY, np.int8), keys,
                       np.zeros(len(keys), VAL_DTYPE), z)

    @staticmethod
    def ranges(los, his) -> "OpBatch":
        los = np.asarray(los, KEY_DTYPE)
        return OpBatch(np.full(len(los), OpKind.RANGE, np.int8), los,
                       np.zeros(len(los), VAL_DTYPE), np.asarray(his, KEY_DTYPE))

    @staticmethod
    def empty() -> "OpBatch":
        return OpBatch(np.zeros(0, np.int8), np.zeros(0, KEY_DTYPE),
                       np.zeros(0, VAL_DTYPE), np.zeros(0, KEY_DTYPE))

    @staticmethod
    def concat(batches) -> "OpBatch":
        """Concatenate batches in order (mixed kinds welcome; the result
        keeps sequential semantics).  An empty input list — or a list of
        zero-length batches — yields the empty batch instead of tripping
        ``np.concatenate`` on an empty sequence."""
        batches = [b for b in batches if len(b)]
        if not batches:
            return OpBatch.empty()
        return OpBatch(np.concatenate([b.kinds for b in batches]),
                       np.concatenate([b.keys for b in batches]),
                       np.concatenate([b.vals for b in batches]),
                       np.concatenate([b.his for b in batches]))


@dataclasses.dataclass
class OpResult:
    """Visible results + per-op latency for one applied :class:`OpBatch`.

    ``found``/``values`` are meaningful on QUERY rows, ``range_hits[i]`` is
    a ``(keys, vals)`` pair on RANGE rows (None elsewhere), ``latency_s``
    on every row (the engine's clock: simulated I/O seconds on cost-model
    tiers, amortized host wall-clock on the device tier).
    ``range_truncated[i]`` flags RANGE rows whose result hit an engine
    capacity limit and is incomplete (device tier only — the cost-model
    tiers are always exact); callers needing exactness must check it.
    """

    kinds: np.ndarray
    found: np.ndarray        # bool  (B,)
    values: np.ndarray       # int64 (B,) — -1 where not found / not a query
    range_hits: list         # list[Optional[tuple[np.ndarray, np.ndarray]]]
    latency_s: np.ndarray    # float64 (B,)
    range_truncated: np.ndarray = None  # bool (B,)

    def __post_init__(self):
        if self.range_truncated is None:
            self.range_truncated = np.zeros(len(self.kinds), bool)

    def latencies(self, kind: OpKind | None = None) -> np.ndarray:
        if kind is None:
            return self.latency_s
        return self.latency_s[self.kinds == int(kind)]


@dataclasses.dataclass
class EngineStats:
    """Uniform engine snapshot; every field is cumulative-since-construction.

    ``io_time_s`` is the engine's charged cost (simulated seconds on the
    cost-model tiers, accumulated host wall-clock on the device tier) and
    must never decrease.  ``total_pairs`` is the *logical* live pair count
    (distinct non-deleted keys — what an all-keyspace range scan would
    return); ``physical_pairs`` is the implementation's resident count,
    which may include stale duplicates and tombstones awaiting compaction.
    ``pending_debt`` is the deferred maintenance still owed (0 = fully
    maintained), the deamortization ledger of paper Sec. 5.1.

    ``bloom_probes`` / ``bloom_negative_skips`` / ``bloom_false_positives``
    are the Bloom-filter effectiveness counters of paper Sec. 5.2 (probes
    issued on point-query descents, negatives that skipped a run search,
    and positives whose search then missed).  Engines without per-run
    filters — or with filters disabled, e.g. ``nbtree-nobloom`` — report
    zeros, which is what lets saturation/query reports attribute the
    nbtree-vs-nbtree-nobloom query savings from driver JSON alone.

    ``maintain_units`` / ``maintain_wall_s`` / ``maintain_unit_p50_s`` /
    ``maintain_unit_p99_s`` / ``maintain_unit_p100_s`` record the *real*
    wall-clock cost of maintenance work units on the device tier (each
    ``maintain(1)`` step timed individually; totals are cumulative, and
    percentiles come from the shared bounded log-bucket histogram of
    :mod:`repro.obs.metrics` — exact p100, bucket-resolution p50/p99 —
    so long runs stay O(1) per snapshot), so open-loop runs — which
    charge a deterministic virtual
    service time on wall-clock engines — still report the measured
    service cost of the fused emptying cascade.  Sim-clock tiers report
    zeros (their maintenance cost is already the charged I/O delta).

    Sharded ensembles (``sharded:<base>``, DESIGN.md §6) aggregate: I/O
    counters are *summed* across shards (still monotone — retired shards'
    totals are folded in on rebalance), ``height`` is the max, and
    ``shards`` / ``shard_debt`` carry the ensemble width and the per-shard
    debt vector (single engines report ``shards=1``, ``shard_debt=[]``).
    Maintain-unit counters sum ``maintain_units``/``maintain_wall_s`` and
    take the max of the per-shard percentiles (a conservative ensemble
    tail: units run shard-local, so no shard's tail can exceed it).
    """

    engine: str
    clock: str               # "sim" (cost model) or "wall" (device tier)
    io_time_s: float
    io_seeks: int
    io_bytes_read: int
    io_bytes_written: int
    height: int
    total_pairs: int
    physical_pairs: int
    pending_debt: int
    n_inserts: int
    n_deletes: int
    n_queries: int
    n_ranges: int
    shards: int = 1
    shard_debt: list = dataclasses.field(default_factory=list)
    bloom_probes: int = 0
    bloom_negative_skips: int = 0
    bloom_false_positives: int = 0
    maintain_units: int = 0
    maintain_wall_s: float = 0.0
    maintain_unit_p50_s: float = 0.0
    maintain_unit_p99_s: float = 0.0
    maintain_unit_p100_s: float = 0.0
    #: host->device kernel dispatches issued by THIS engine (device tier;
    #: sharded ensembles sum across shards).  Per-instance — two engines
    #: in one process count independently, unlike the former module-global
    #: shim.  Sim tiers report 0.
    device_dispatches: int = 0
    #: highest WAL commit LSN applied to this engine (0 = never ran under a
    #: durable frontend).  Written by the durable ingest path via
    #: :meth:`StorageEngine.note_applied`; the recovery invariant is that a
    #: recovered engine's live table equals the acked prefix <= this LSN
    #: (``repro.wal``, DESIGN.md §9).
    applied_lsn: int = 0


class StorageEngine(abc.ABC):
    """The unified engine protocol (see module docstring).

    Subclasses implement the four scalar hooks (or override :meth:`apply`
    wholesale, as the device adapter does) plus :meth:`stats` /
    :meth:`count_live`; :meth:`maintain` and :meth:`drain` default to
    no-debt engines.
    """

    name: str = "engine"

    def __init__(self):
        self._counts = {k: 0 for k in OpKind}
        self.applied_lsn = 0        # highest durably-logged commit applied

    # ------------------------------------------------------------------ apply
    def apply(self, batch: OpBatch) -> OpResult:
        n = len(batch)
        found = np.zeros(n, bool)
        values = np.full(n, -1, VAL_DTYPE)
        range_hits: list = [None] * n
        lat = np.zeros(n, np.float64)
        for i in range(n):
            kind = OpKind(int(batch.kinds[i]))
            k = int(batch.keys[i])
            if kind is OpKind.INSERT:
                lat[i] = self._do_insert(k, int(batch.vals[i]))
            elif kind is OpKind.DELETE:
                lat[i] = self._do_delete(k)
            elif kind is OpKind.QUERY:
                found[i], values[i], lat[i] = self._do_query(k)
            else:
                rk, rv, lat[i] = self._do_range(k, int(batch.his[i]))
                range_hits[i] = (rk, rv)
            self._counts[kind] += 1
        return OpResult(batch.kinds.copy(), found, values, range_hits, lat)

    # ------------------------------------------------------------ scalar hooks
    def _do_insert(self, key: int, val: int) -> float:
        raise UnsupportedOp(f"{self.name} does not support INSERT")

    def _do_delete(self, key: int) -> float:
        raise UnsupportedOp(f"{self.name} does not support DELETE")

    def _do_query(self, key: int):
        raise UnsupportedOp(f"{self.name} does not support QUERY")

    def _do_range(self, lo: int, hi: int):
        raise UnsupportedOp(f"{self.name} does not support RANGE")

    # ------------------------------------------------------------ observability
    def attach_tracer(self, tracer) -> None:
        """Attach a :class:`repro.obs.trace.Tracer` for span emission.

        Base implementation is a no-op — engines with nothing structured
        to report (the scalar cost-model tiers) simply ignore it.  The
        device adapter forwards it to the kernel dispatch funnel
        (per-dispatch + flush-unit spans); sharded ensembles forward to
        every shard and emit split/debt events themselves.  Called by the
        ingest frontends when observability is enabled.
        """

    # ------------------------------------------------------------- maintenance
    def maintain(self, budget: int = 1) -> int:
        """Run up to ``budget`` units of deferred work; returns pending debt."""
        return 0

    def drain(self) -> None:
        """Finish all deferred work (tests / shutdown)."""
        while self.maintain(64):
            pass

    # -------------------------------------------------------------- durability
    def note_applied(self, lsn: int) -> None:
        """Record that every WAL commit up to ``lsn`` has been applied.

        Called by the durable ingest frontend after each group commit's
        ``apply`` and by WAL replay during recovery; surfaced as
        ``EngineStats.applied_lsn``.  Monotone by construction.
        """
        if lsn > self.applied_lsn:
            self.applied_lsn = int(lsn)

    def dump_live(self) -> tuple:
        """``(keys, vals)`` of every visible pair, key-sorted, cost-free.

        The snapshot primitive of the durability subsystem: an engine-table
        checkpoint is exactly this dump keyed by the commit LSN it reflects.
        Like :meth:`count_live` it is an observer — it must charge no I/O
        cost — and O(n).
        """
        raise UnsupportedOp(f"{self.name} does not support dump_live")

    def dump_live_range(self, lo: int, hi: int) -> tuple:
        """``(keys, vals)`` of visible pairs with ``lo <= key <= hi``.

        Cost-free observer like :meth:`dump_live`.  A tenant namespace
        (``repro.tenancy``) is a contiguous encoded key interval, so this
        is the per-namespace snapshot/stats primitive; sharded ensembles
        override it to consult only intersecting shards.
        """
        keys, vals = self.dump_live()
        a = int(np.searchsorted(keys, np.asarray(lo, KEY_DTYPE), "left"))
        b = int(np.searchsorted(keys, np.asarray(hi, KEY_DTYPE), "right"))
        return keys[a:b], vals[a:b]

    def count_live_range(self, lo: int, hi: int) -> int:
        """Exact number of visible keys in ``[lo, hi]`` (cost-free)."""
        return len(self.dump_live_range(lo, hi)[0])

    # ------------------------------------------------------------------- stats
    @abc.abstractmethod
    def io_time_s(self) -> float:
        """Cumulative charged cost (O(1)) — the cheap per-step poll.

        ``stats()`` carries the same number plus the full snapshot; use
        this accessor in hot loops that only need the monotone cost.
        """

    @abc.abstractmethod
    def height(self) -> int:
        """Index height / level count (O(height)) — cheap, like io_time_s."""

    @abc.abstractmethod
    def stats(self) -> EngineStats:
        """Full snapshot.  O(n): ``total_pairs`` is an exact logical count
        (a complete scan of resident pairs), so poll sparingly — per run,
        not per op; use :meth:`io_time_s` for cheap cost polling."""

    @abc.abstractmethod
    def count_live(self) -> int:
        """Exact number of visible (non-deleted, deduplicated) keys.

        Must not charge I/O cost — it is an observer, not an operation.
        O(n): scans all resident pairs.
        """


# =========================================================== cost-model tiers
class CostModelEngine(StorageEngine):
    """Adapter base for the host tiers: scalar impl + explicit CostModel."""

    clock = "sim"

    def __init__(self, impl):
        super().__init__()
        self.impl = impl

    @property
    def cm(self) -> CostModel:
        return self.impl.cm

    def _do_insert(self, key, val):
        return float(self.impl.insert(key, val))

    def _do_delete(self, key):
        return float(self.impl.delete(key))

    def _do_query(self, key):
        v, t = self.impl.query(key)
        return v is not None, -1 if v is None else int(v), float(t)

    def _do_range(self, lo, hi):
        rk, rv = self.impl.range_query(lo, hi)
        return rk, rv, float(self.impl._last_query_time)

    def dump_live(self) -> tuple:
        # an all-keyspace range scan is exact on every host tier; snapshot
        # and restore the cost counters so observation charges nothing.
        cm = self.cm
        saved = (cm.seeks, cm.bytes_read, cm.bytes_written, cm.pages)
        try:
            rk, rv = self.impl.range_query(0, int(np.iinfo(KEY_DTYPE).max))
        finally:
            cm.seeks, cm.bytes_read, cm.bytes_written, cm.pages = saved
        return (np.asarray(rk, KEY_DTYPE), np.asarray(rv, VAL_DTYPE))

    def count_live(self) -> int:
        return len(self.dump_live()[0])

    def height(self) -> int:
        return 1

    def _pending_debt(self) -> int:
        return 0

    def _bloom_stats(self) -> tuple:
        """(probes, negative_skips, false_positives); zeros by default."""
        return (0, 0, 0)

    def io_time_s(self) -> float:
        return self.cm.time

    def stats(self) -> EngineStats:
        cm = self.cm
        probes, skips, fps = self._bloom_stats()
        return EngineStats(
            engine=self.name, clock=self.clock, io_time_s=cm.time,
            io_seeks=cm.seeks, io_bytes_read=cm.bytes_read,
            io_bytes_written=cm.bytes_written, height=self.height(),
            total_pairs=self.count_live(),
            physical_pairs=int(self.impl.total_pairs()),
            pending_debt=self._pending_debt(),
            n_inserts=self._counts[OpKind.INSERT],
            n_deletes=self._counts[OpKind.DELETE],
            n_queries=self._counts[OpKind.QUERY],
            n_ranges=self._counts[OpKind.RANGE],
            bloom_probes=int(probes), bloom_negative_skips=int(skips),
            bloom_false_positives=int(fps),
            applied_lsn=self.applied_lsn)


class RefNBTreeEngine(CostModelEngine):
    """The paper-faithful NB-tree (refimpl) under the protocol."""

    name = "nbtree"

    def __init__(self, f: int = 3, sigma: int = 4096, *, device: Device = HDD,
                 **kw):
        super().__init__(NBTree(f=f, sigma=sigma, device=device, **kw))

    def maintain(self, budget: int = 1) -> int:
        """Advance the pending cascade by up to ``budget`` page quanta."""
        t = self.impl
        if t._cascade is None:
            return 0
        try:
            for _ in range(budget):
                next(t._cascade)
        except StopIteration:
            t._cascade = None
            t._frozen = None
        return 0 if t._cascade is None else 1

    def height(self) -> int:
        return self.impl.height

    def _pending_debt(self) -> int:
        return 0 if self.impl._cascade is None else 1

    def _bloom_stats(self) -> tuple:
        t = self.impl
        return (t.bloom_probes, t.bloom_negative_skips,
                t.bloom_false_positives)


class LSMEngine(CostModelEngine):
    name = "lsm"

    def __init__(self, mem_pairs: int = 4096, ratio: int = 10, *,
                 device: Device = HDD, **kw):
        super().__init__(LSMTree(mem_pairs=mem_pairs, ratio=ratio,
                                 device=device, **kw))

    def height(self) -> int:
        return len(self.impl.levels)

    def _bloom_stats(self) -> tuple:
        t = self.impl
        return (t.bloom_probes, t.bloom_negative_skips,
                t.bloom_false_positives)


class BTreeEngine(CostModelEngine):
    """Incremental B+-tree (per-insert leaf read-modify-write)."""

    name = "btree"

    def __init__(self, *, device: Device = HDD, **kw):
        super().__init__(BPlusTree(device=device, **kw))


class BEpsilonEngine(CostModelEngine):
    name = "bepsilon"

    def __init__(self, *, fanout: int = 16, node_bytes: int = 4 << 20,
                 cached_levels: int = 2, device: Device = HDD, **kw):
        super().__init__(BEpsilonTree(fanout=fanout, node_bytes=node_bytes,
                                      cached_levels=cached_levels,
                                      device=device, **kw))

    def height(self) -> int:
        h, node = 0, self.impl.root
        while not node.is_leaf:
            node = node.children[0]
            h += 1
        return h


class BulkBTreeEngine(CostModelEngine):
    """Static bulk-loaded B+-tree: QUERY/RANGE only (the paper's yardstick)."""

    name = "btree-bulk"

    def __init__(self, keys, vals, *, device: Device = HDD, **kw):
        super().__init__(BPlusTreeBulk(keys, vals, device=device, **kw))

    def _do_insert(self, key, val):
        raise UnsupportedOp("btree-bulk is static: INSERT unsupported")

    def _do_delete(self, key):
        raise UnsupportedOp("btree-bulk is static: DELETE unsupported")

    def count_live(self) -> int:
        return len(self.impl.keys)


# ================================================================ device tier
def _pad_pow2(a: np.ndarray) -> np.ndarray:
    """Pad a 1-d array to the next power-of-two length by repeating a[-1]."""
    n = len(a)
    target = 1 << max(0, n - 1).bit_length()
    if n in (0, target):
        return a
    return np.concatenate([a, np.repeat(a[-1:], target - n)])


class DeviceNBTreeEngine(StorageEngine):
    """The jax/Pallas device tier under the protocol.

    ``apply`` groups maximal same-kind op runs into one fused device call
    (sequential semantics preserved — see module docstring); latency is the
    group's host wall-clock amortized over its ops, and ``stats().clock`` is
    ``"wall"`` so drivers never mix it with simulated seconds.

    Mixed workloads produce same-kind runs of arbitrary length, and the
    fused device calls are shape-specialized jits — so every group is padded
    to a power-of-two bucket to bound recompiles: QUERY/RANGE pads repeat
    the last op and drop the extra outputs (read-only), INSERT/DELETE pads
    repeat the last op verbatim, a blind re-write of the same (key, value)
    that newest-wins dedup makes logically invisible (the physical duplicate
    is retired at the next leaf compaction, like any stale copy).
    """

    name = "jax-nbtree"
    clock = "wall"

    def __init__(self, f: int = 4, sigma: int = 2048, *, max_nodes: int = 256,
                 max_results: int = 512, **kw):
        super().__init__()
        from .jax_nbtree import NBTreeIndex, TOMBSTONE32  # jax import deferred
        self._tombstone32 = TOMBSTONE32
        self.idx = NBTreeIndex(f=f, sigma=sigma, max_nodes=max_nodes, **kw)
        self._max_results = max_results
        self._wall_s = 0.0
        # wall-clock per maintenance work unit (each maintain(1) timed
        # individually) — the real service cost of the fused emptying
        # cascade, surfaced as EngineStats maintain-unit percentiles.
        # Shared log-bucket histogram (repro.obs.metrics): O(#buckets)
        # memory forever, exact count/total/p100, bucket-interpolated
        # p50/p99 — so long-running servers pay O(1) per unit and per
        # stats() snapshot.
        self._maintain_unit_s = LogBucketHistogram()
        self._t_origin = time.perf_counter()
        self._tracer = None

    # ------------------------------------------------------------------ apply
    def apply(self, batch: OpBatch) -> OpResult:
        import jax

        n = len(batch)
        found = np.zeros(n, bool)
        values = np.full(n, -1, VAL_DTYPE)
        range_hits: list = [None] * n
        truncated = np.zeros(n, bool)
        lat = np.zeros(n, np.float64)
        kinds = np.asarray(batch.kinds)
        i = 0
        while i < n:
            j = i + 1
            while j < n and kinds[j] == kinds[i]:
                j += 1
            kind = OpKind(int(kinds[i]))
            sl = slice(i, j)
            real = j - i
            t0 = time.perf_counter()
            if kind is OpKind.INSERT:
                self.idx.insert_batch(
                    _pad_pow2(batch.keys[sl].astype(np.uint32)),
                    _pad_pow2(batch.vals[sl].astype(np.int32)))
                jax.block_until_ready(self.idx.run_keys)
            elif kind is OpKind.DELETE:
                self.idx.delete_batch(_pad_pow2(batch.keys[sl].astype(np.uint32)))
                jax.block_until_ready(self.idx.run_keys)
            elif kind is OpKind.QUERY:
                pres, vals = self.idx.query_batch(
                    _pad_pow2(batch.keys[sl].astype(np.uint32)))
                pres = np.asarray(pres)[:real]
                vals = np.asarray(vals)[:real]
                found[sl] = pres
                values[sl] = np.where(pres, vals.astype(np.int64), -1)
            else:
                self._apply_ranges(batch, sl, range_hits, truncated)
            dt = time.perf_counter() - t0
            self._wall_s += dt
            lat[sl] = dt / (j - i)
            self._counts[kind] += j - i
            i = j
        return OpResult(kinds.copy(), found, values, range_hits, lat,
                        truncated)

    def _apply_ranges(self, batch: OpBatch, sl: slice, range_hits: list,
                      truncated: np.ndarray) -> None:
        los = _pad_pow2(batch.keys[sl].astype(np.uint32))
        his = _pad_pow2(batch.his[sl].astype(np.uint32))
        while True:
            rk, rv, cnt, trunc = self.idx.range_query_batch(
                los, his, max_results=self._max_results)
            trunc = np.asarray(trunc)
            if not trunc.any() or self._max_results >= (1 << 20):
                break
            self._max_results *= 2      # sticky: later batches start larger
        rk, rv, cnt = np.asarray(rk), np.asarray(rv), np.asarray(cnt)
        for b in range(sl.stop - sl.start):
            c = int(cnt[b])
            range_hits[sl.start + b] = (rk[b, :c].astype(KEY_DTYPE),
                                        rv[b, :c].astype(VAL_DTYPE))
            truncated[sl.start + b] = bool(trunc[b])

    # ------------------------------------------------------------- maintenance
    def maintain(self, budget: int = 1) -> int:
        """Run up to ``budget`` units, timing each unit individually.

        ``budget <= 0`` is the conventional free debt poll.  Units run one
        at a time so every flush/split gets its own wall-clock sample —
        the p50/p99/p100 the stats snapshot reports.
        """
        if budget <= 0:
            return self.idx.maintain(0)
        pending = self.idx.maintain(0)
        for _ in range(int(budget)):
            if not pending:
                break
            u0 = self.idx.units_done
            t0 = time.perf_counter()
            pending = self.idx.maintain(1)
            dt = time.perf_counter() - t0
            self._wall_s += dt
            if self.idx.units_done > u0:   # not a stale-entry-only pop
                self._maintain_unit_s.add(dt)
                if self._tracer is not None:
                    self._tracer.complete(
                        "flush_unit", "maintain_unit",
                        t0 - self._t_origin, dt,
                        unit=int(self.idx.units_done))
        return pending

    def drain(self) -> None:
        while self.maintain(64):
            pass

    # ------------------------------------------------------------------- stats
    def dump_live(self) -> tuple:
        run_keys = np.asarray(self.idx.run_keys)
        run_vals = np.asarray(self.idx.run_vals)
        seen: dict = {}

        # pre-order (ancestors first) + leftmost-first within a run is the
        # freshest-copy-wins order both query paths resolve by.
        def rec(node):
            ks = run_keys[node.nid][: node.count]
            vs = run_vals[node.nid][: node.count]
            for k, v in zip(ks.tolist(), vs.tolist()):
                if k not in seen:
                    seen[k] = v
            for c in node.children:
                rec(c)

        rec(self.idx.root)
        live = sorted((k, v) for k, v in seen.items()
                      if v != self._tombstone32)
        keys = np.asarray([k for k, _ in live], KEY_DTYPE)
        vals = np.asarray([v for _, v in live], VAL_DTYPE)
        return keys, vals

    def count_live(self) -> int:
        return len(self.dump_live()[0])

    def io_time_s(self) -> float:
        return self._wall_s

    def height(self) -> int:
        return self.idx.height

    def attach_tracer(self, tracer) -> None:
        """Forward to the kernel layer: per-dispatch spans flow from the
        ``NBTreeIndex`` dispatch funnel, flush-unit spans from
        :meth:`maintain` — both on this engine's wall clock (seconds since
        engine construction)."""
        self._tracer = tracer
        self.idx.attach_tracer(tracer, t_origin=self._t_origin)

    def stats(self) -> EngineStats:
        mu = self._maintain_unit_s
        return EngineStats(
            engine=self.name, clock=self.clock, io_time_s=self._wall_s,
            io_seeks=0, io_bytes_read=0, io_bytes_written=0,
            height=self.height(), total_pairs=self.count_live(),
            physical_pairs=int(self.idx.total_pairs()),
            pending_debt=len(self.idx._pending),
            n_inserts=self._counts[OpKind.INSERT],
            n_deletes=self._counts[OpKind.DELETE],
            n_queries=self._counts[OpKind.QUERY],
            n_ranges=self._counts[OpKind.RANGE],
            bloom_probes=self.idx.bloom_probes,
            bloom_negative_skips=self.idx.bloom_negative_skips,
            bloom_false_positives=self.idx.bloom_false_positives,
            maintain_units=mu.count,
            maintain_wall_s=mu.total,
            maintain_unit_p50_s=mu.quantile(0.50),
            maintain_unit_p99_s=mu.quantile(0.99),
            maintain_unit_p100_s=mu.max if mu.count else 0.0,
            device_dispatches=self.idx.dispatch_count,
            applied_lsn=self.applied_lsn)


# =================================================================== registry
_REGISTRY: dict = {}

#: the paper's comparison set — one engine per tier, every benchmark's axis.
FIVE_TIERS = ("nbtree", "lsm", "btree", "bepsilon", "jax-nbtree")


def register_engine(name: str, factory) -> None:
    assert name not in _REGISTRY, f"duplicate engine name {name!r}"
    _REGISTRY[name] = factory


def make_engine(name: str, **kw) -> StorageEngine:
    if name.startswith("sharded:"):
        # range-partitioned ensemble of any registered engine (DESIGN.md §6):
        # make_engine("sharded:nbtree", shards=4, **base_kw).  Imported
        # lazily — repro.shard programs against this module.
        from repro.shard import ShardedEngine
        base = name.split(":", 1)[1]
        if base not in _REGISTRY:
            raise KeyError(f"unknown base engine {base!r} for {name!r}; "
                           f"registered: {sorted(_REGISTRY)}")
        eng = ShardedEngine(base, **kw)
        eng.name = name
        return eng
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None
    eng = factory(**kw)
    eng.name = name
    return eng


def available_engines() -> tuple:
    return tuple(sorted(_REGISTRY))


register_engine("nbtree", RefNBTreeEngine)
register_engine("nbtree-basic",
                lambda **kw: RefNBTreeEngine(deamortize=False, **kw))
register_engine("nbtree-nobloom",
                lambda **kw: RefNBTreeEngine(use_bloom=False, **kw))
register_engine("lsm", LSMEngine)
register_engine("blsm", lambda **kw: LSMEngine(**{"max_levels": 3, **kw}))
register_engine("btree", BTreeEngine)
register_engine("bepsilon", BEpsilonEngine)
register_engine("jax-nbtree", DeviceNBTreeEngine)
