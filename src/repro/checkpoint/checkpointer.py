"""Sharded, async, restartable checkpointing with an NB-tree manifest.

Layout (one directory per run):
  step_<N>/<flat.param.path>.npy       one file per pytree leaf
  manifest.npz + manifest.json          NB-tree-indexed shard manifest

The manifest is a *paper-native* application: checkpoint writes are
insertion-intensive (every step inserts (step, leaf) -> file records,
incremental checkpoints insert only changed leaves) and restores are point
queries/range scans — so the manifest is a host-tier NB-tree
(core/refimpl.NBTree, zero-I/O-cost instance) serialized alongside the data.
Restore at a *different* mesh/topology is supported because leaves are saved
unsharded (test scale) or per-shard with the shard grid recorded; load
re-shards via jax.device_put with the target NamedSharding — this is the
elastic-resize path (distributed/fault_tolerance.py).

Async: ``save(..., blocking=False)`` snapshots to host then writes on a
daemon thread; ``wait()`` joins.  A save is atomic: data lands in a temp
dir, renamed after the manifest fsync (restart-safe).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

from ..core.cost_model import CostModel, Device
from ..core.refimpl import NBTree

_NULL_DEVICE = Device("null", page_bytes=4096, seek_s=0.0, read_bw=1e18, write_bw=1e18)


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        path = ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[path] = leaf
    return out


def _key_of(step: int, leaf_idx: int) -> int:
    return (step << 20) | leaf_idx


class Checkpointer:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        # zero-cost NB-tree (manifest ops are host metadata, not disk sim).
        self.manifest = NBTree(f=4, sigma=1024, cost=CostModel(_NULL_DEVICE),
                               use_bloom=False)
        self.leaf_names: list[str] = []
        self._thread: threading.Thread | None = None
        self._load_manifest()

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, *, blocking: bool = True) -> None:
        self.wait()
        flat = _flatten(tree)

        def to_host(l):
            a = np.asarray(l)
            if a.dtype.kind == "V":  # bf16 etc: store as lossless f32
                a = np.asarray(jax.numpy.asarray(l).astype(jax.numpy.float32))
            return a

        host = {p: to_host(l) for p, l in flat.items()}  # device->host snap

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            for path, arr in host.items():
                np.save(os.path.join(tmp, path + ".npy"), arr)
                if path not in self.leaf_names:
                    self.leaf_names.append(path)
                self.manifest.insert(_key_of(step, self.leaf_names.index(path)),
                                     step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._write_manifest(step)

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                 if d.startswith("step_")]
        return max(steps) if steps else None

    def restore(self, step: int, like, shardings=None):
        """Rebuild the pytree of ``like`` (shapes/dtypes) from step files.

        ``shardings``: optional pytree of NamedSharding for a (possibly
        different) target mesh — the elastic-resize entry point.
        """
        self.wait()
        d = os.path.join(self.dir, f"step_{step}")
        flat = _flatten(like)
        host = {}
        for path, leaf in flat.items():
            # manifest point query proves the leaf belongs to this step.
            idx = self.leaf_names.index(path)
            assert self.manifest.get(_key_of(step, idx)) is not None, (
                f"manifest missing {path} @ step {step}")
            arr = np.load(os.path.join(d, path + ".npy"))
            assert arr.shape == tuple(leaf.shape), (path, arr.shape, leaf.shape)
            host[path] = arr

        def rebuild(tree, sh_tree):
            flat_kp = jax.tree_util.tree_flatten_with_path(tree)[0]
            leaves = []
            sh_flat = (jax.tree_util.tree_leaves(sh_tree)
                       if sh_tree is not None else [None] * len(flat_kp))
            for (kp, leaf), sh in zip(flat_kp, sh_flat):
                path = ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                                for k in kp)
                arr = host[path]
                if arr.dtype != leaf.dtype:  # bf16 round-trips through f32
                    arr = np.asarray(
                        jax.numpy.asarray(arr).astype(leaf.dtype))
                leaves.append(jax.device_put(arr, sh) if sh is not None
                              else jax.numpy.asarray(arr))
            treedef = jax.tree_util.tree_structure(tree)
            return jax.tree_util.tree_unflatten(treedef, leaves)

        return rebuild(like, shardings)

    # ------------------------------------------------------------- manifest
    def _write_manifest(self, step: int) -> None:
        keys, vals = [], []
        stack = [self.manifest.root]
        while stack:
            n = stack.pop()
            keys.extend(int(k) for k in n.run.live_keys)
            vals.extend(int(v) for v in n.run.live_vals)
            stack.extend(n.children)
        keys.extend(int(k) for k in self.manifest._buf.keys())
        vals.extend(int(v) for v in self.manifest._buf.values())
        np.savez(os.path.join(self.dir, "manifest.npz"),
                 keys=np.asarray(keys, np.uint64), vals=np.asarray(vals, np.int64))
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump({"leaf_names": self.leaf_names, "last_step": step}, f)

    def _load_manifest(self) -> None:
        j = os.path.join(self.dir, "manifest.json")
        z = os.path.join(self.dir, "manifest.npz")
        if not (os.path.exists(j) and os.path.exists(z)):
            return
        meta = json.load(open(j))
        self.leaf_names = meta["leaf_names"]
        data = np.load(z)
        for k, v in zip(data["keys"], data["vals"]):
            self.manifest.insert(k, v)
        self.manifest.drain()
