"""Production mesh construction.

Single pod : (16, 16)    axes ("data", "model")      = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Defined as a *function* so importing this module never touches JAX device
state (device count is locked at first backend init — the dry-run sets
XLA_FLAGS before any import; tests and benches see the real 1-CPU world).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n: int) -> dict:
    """axis_types only where the installed jax has it (added after 0.4.x)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def mesh_context(mesh):
    """``jax.sharding.set_mesh(mesh)`` on current jax; the legacy ``Mesh``
    context manager (same thread-local resource env) on 0.4.x."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def current_mesh():
    """``jax.sharding.get_abstract_mesh()`` on current jax; the thread-local
    physical mesh (what the ``Mesh`` context manager sets) on 0.4.x."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic resize)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes)))


def host_device_flag(n: int = 512) -> str:
    return f"--xla_force_host_platform_device_count={n}"
