"""Segment-based group-commit write-ahead log (DESIGN.md §9).

Durability layer under :class:`~repro.ingest.frontend.IngestFrontend`: one
WAL record per *group commit*, holding the commit's write ops (INSERT /
DELETE rows; reads are not logged) and its commit LSN.  An op is acked only
after its commit's record is fsynced — the ack instant *is* fsync return.

On-disk format (little-endian), one directory of segment files::

    wal_<first_lsn:016d>.log        records, appended in LSN order

    record := header ‖ payload
    header := magic:u32 ‖ payload_len:u32 ‖ lsn:u64 ‖ crc32(payload):u32
    payload := n_ops:u32 ‖ kinds:int8[n] ‖ keys:u64[n] ‖ vals:i64[n]

Properties the recovery path relies on:

* **Per-record checksums.**  A record is valid iff its header parses, its
  payload is fully present, its CRC matches, and its LSN is exactly
  ``previous + 1``.  Anything else is garbage.
* **Garbage-tail truncation on open.**  Opening the log scans every
  segment in LSN order and physically truncates the file at the first
  invalid record (a torn group commit from a crash between append and
  fsync); all bytes past it — and any later segments — are discarded.
  A torn commit was by construction never acked, so truncation is exactly
  the "no resurrected unacked writes" invariant.
* **Segment rotation.**  A segment is closed once it exceeds
  ``segment_bytes``; the next segment's filename carries the first LSN it
  will contain, which is what makes checkpoint garbage collection
  (:meth:`WriteAheadLog.truncate_upto`) a pure file unlink.
* **Checkpoint truncation.**  ``truncate_upto(lsn)`` unlinks every
  *closed* segment whose records all have LSN ≤ ``lsn`` (the newest
  segment is always kept so the next-LSN counter survives restarts with
  an empty tail).
"""
from __future__ import annotations

import dataclasses
import os
import struct
import time as _time
import zlib

import numpy as np

from .faults import CrashPoint, FaultInjector, reach as _reach

_MAGIC = 0x314C4157                      # "WAL1"
_HEADER = struct.Struct("<IIQI")         # magic, payload_len, lsn, crc
_COUNT = struct.Struct("<I")
_OP_BYTES = 1 + 8 + 8                    # kind + key + val per op
_MAX_OPS = 1 << 24                       # sanity bound on a parsed header


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One durable group commit: LSN + the commit's write ops."""

    lsn: int
    kinds: np.ndarray        # int8  (n,)
    keys: np.ndarray         # uint64 (n,)
    vals: np.ndarray         # int64 (n,)

    def __len__(self) -> int:
        return len(self.kinds)


def _encode_payload(kinds, keys, vals) -> bytes:
    kinds = np.ascontiguousarray(kinds, np.int8)
    keys = np.ascontiguousarray(keys, np.uint64)
    vals = np.ascontiguousarray(vals, np.int64)
    n = len(kinds)
    assert keys.shape == vals.shape == (n,)
    return (_COUNT.pack(n) + kinds.tobytes() + keys.tobytes()
            + vals.tobytes())


def _decode_payload(buf: bytes):
    (n,) = _COUNT.unpack_from(buf, 0)
    if len(buf) != _COUNT.size + n * _OP_BYTES:
        raise ValueError("payload length mismatch")
    o = _COUNT.size
    kinds = np.frombuffer(buf, np.int8, n, o)
    keys = np.frombuffer(buf, np.uint64, n, o + n)
    vals = np.frombuffer(buf, np.int64, n, o + 9 * n)
    return kinds.copy(), keys.copy(), vals.copy()


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _segment_name(first_lsn: int) -> str:
    return f"wal_{first_lsn:016d}.log"


@dataclasses.dataclass
class _Segment:
    path: str
    first_lsn: int           # LSN the segment was opened at (may hold none)
    last_lsn: int            # last valid record inside (first_lsn-1 if empty)
    size: int                # valid byte length


class WriteAheadLog:
    """Append-only segmented redo log; see module docstring.

    ``append_commit`` is the only mutator on the hot path: one buffered
    write + one ``fsync`` per group commit.  ``injector`` threads the
    crash-point harness through the append path (production passes None).
    """

    def __init__(self, directory: str, *, segment_bytes: int = 1 << 20,
                 injector: FaultInjector | None = None, tracer=None):
        assert segment_bytes >= 4096
        self.dir = directory
        self.segment_bytes = int(segment_bytes)
        self.injector = injector
        # optional repro.obs tracer: one wall-clock "wal_fsync" span per
        # append_commit (standalone/device use; the sim-clock frontend
        # emits its own charged spans instead and passes no tracer here).
        self.tracer = tracer
        self._t_origin = _time.perf_counter()
        os.makedirs(directory, exist_ok=True)
        # counters (cumulative since open; JSON-ready via stats()).
        self.appends = 0
        self.syncs = 0
        self.bytes_appended = 0
        self.truncated_tail_bytes = 0     # garbage discarded on open
        self.gc_segments = 0              # segments unlinked by truncate_upto
        self._fh = None                   # append handle on the last segment
        self._segments: list[_Segment] = []
        self._recover()

    # ------------------------------------------------------------------ open
    def _recover(self) -> None:
        """Scan segments in order, truncate the garbage tail, set last LSN."""
        names = sorted(n for n in os.listdir(self.dir)
                       if n.startswith("wal_") and n.endswith(".log"))
        prev_lsn = 0
        dirty = False
        for k, name in enumerate(names):
            path = os.path.join(self.dir, name)
            first = int(name[4:-4])
            seg = _Segment(path, first, first - 1, 0)
            valid_end, last = self._scan(path, expect_next=first)
            size = os.path.getsize(path)
            if valid_end < size:
                # torn tail: physically truncate, drop all later segments
                # (they were appended after the torn record and cannot be
                # trusted to continue the LSN chain).
                with open(path, "r+b") as f:
                    f.truncate(valid_end)
                    f.flush()
                    os.fsync(f.fileno())
                self.truncated_tail_bytes += size - valid_end
                dirty = True
            seg.last_lsn = last if last is not None else first - 1
            seg.size = valid_end
            self._segments.append(seg)
            prev_lsn = seg.last_lsn
            if dirty:
                for later in names[k + 1:]:
                    lp = os.path.join(self.dir, later)
                    self.truncated_tail_bytes += os.path.getsize(lp)
                    os.unlink(lp)
                break
        if dirty:
            _fsync_dir(self.dir)
        self.last_lsn = prev_lsn if self._segments else 0

    def _scan(self, path: str, *, expect_next: int):
        """Return (valid_end_offset, last_valid_lsn|None) for one segment."""
        last = None
        nxt = expect_next
        end = 0
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        while off + _HEADER.size <= len(data):
            magic, plen, lsn, crc = _HEADER.unpack_from(data, off)
            if magic != _MAGIC or plen > _MAX_OPS * _OP_BYTES + _COUNT.size:
                break
            if off + _HEADER.size + plen > len(data):
                break                           # torn payload
            payload = data[off + _HEADER.size: off + _HEADER.size + plen]
            if zlib.crc32(payload) != crc or lsn != nxt:
                break
            off += _HEADER.size + plen
            end = off
            last = lsn
            nxt = lsn + 1
        return end, last

    # ---------------------------------------------------------------- append
    def _open_segment(self, first_lsn: int) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None
        seg = _Segment(os.path.join(self.dir, _segment_name(first_lsn)),
                       first_lsn, first_lsn - 1, 0)
        self._segments.append(seg)
        self._fh = open(seg.path, "ab")
        _fsync_dir(self.dir)

    def _ensure_segment(self, nbytes: int, lsn: int):
        if not self._segments:
            self._open_segment(lsn)
        elif self._fh is None:
            # reopened log: append to the recovered tail segment.
            self._fh = open(self._segments[-1].path, "ab")
        tail = self._segments[-1]
        if (tail.size and tail.size + nbytes > self.segment_bytes) or \
                lsn != tail.last_lsn + 1:
            # rotation on size, or on an LSN discontinuity: the chain check
            # is per segment (anchored at the filename's first LSN), so a
            # caller that must skip LSNs — a primary whose corrupted tail
            # was rolled back but whose applied state is ahead, or a fresh
            # replica starting at a snapshot LSN — gets a new segment whose
            # name re-anchors the chain.
            self._open_segment(lsn)
        return self._fh, self._segments[-1]

    def append_commit(self, kinds, keys, vals, *,
                      lsn: int | None = None) -> tuple[int, int]:
        """Durably log one group commit; returns ``(lsn, bytes_written)``.

        Blocks until the record is fsynced — the caller's ack instant.
        ``lsn`` overrides the self-assigned ``last_lsn + 1`` for logs that
        mirror an external chain (replication ships the *group's* LSN to
        every replica WAL); it must still advance monotonically.
        """
        t_span0 = _time.perf_counter()
        lsn = self.last_lsn + 1 if lsn is None else int(lsn)
        assert lsn > self.last_lsn, "WAL LSNs must advance"
        payload = _encode_payload(kinds, keys, vals)
        rec = _HEADER.pack(_MAGIC, len(payload), lsn,
                           zlib.crc32(payload)) + payload
        _reach(self.injector, CrashPoint.BEFORE_WAL_APPEND)
        f, seg = self._ensure_segment(len(rec), lsn)
        pos = seg.size
        f.write(rec)
        f.flush()
        self.appends += 1
        self.bytes_appended += len(rec)

        def tear():
            # crash between append and fsync: the OS may persist any prefix
            # of the unsynced bytes — emulate the adversarial torn write.
            f.truncate(pos + max(1, len(rec) // 2))
            f.flush()
            os.fsync(f.fileno())
            f.close()

        _reach(self.injector, CrashPoint.AFTER_WAL_APPEND, on_crash=tear)
        os.fsync(f.fileno())
        self.syncs += 1
        seg.size = pos + len(rec)
        seg.last_lsn = lsn
        self.last_lsn = lsn
        if self.tracer is not None:
            self.tracer.complete("wal_fsync", "append_commit",
                                 t_span0 - self._t_origin,
                                 _time.perf_counter() - t_span0,
                                 lsn=int(lsn), nbytes=len(rec))
        return lsn, len(rec)

    # ---------------------------------------------------------------- replay
    def replay(self, after_lsn: int = 0, *, key_lo: int | None = None,
               key_hi: int | None = None):
        """Yield :class:`WalRecord` for every record with LSN > ``after_lsn``.

        ``key_lo``/``key_hi`` (inclusive) restrict replay to ops whose key
        falls inside the interval: records are filtered row-wise and
        records left empty are skipped entirely.  A tenant namespace
        (``repro.tenancy``) is one contiguous encoded-key interval, so this
        is what lets recovery rebuild a single namespace without replaying
        every co-tenant's writes — tenant identity rides in the key's high
        bits, so the shared log needs no per-tenant records.

        Reads through independent handles, so replaying an open log (tests,
        live verification) is safe.
        """
        lo = np.uint64(0 if key_lo is None else key_lo)
        hi = np.uint64(np.iinfo(np.uint64).max if key_hi is None else key_hi)
        filtered = key_lo is not None or key_hi is not None
        for seg in self._segments:
            if seg.last_lsn <= after_lsn or seg.size == 0:
                continue
            with open(seg.path, "rb") as f:
                data = f.read(seg.size)
            off = 0
            while off + _HEADER.size <= len(data):
                _, plen, lsn, _ = _HEADER.unpack_from(data, off)
                payload = data[off + _HEADER.size: off + _HEADER.size + plen]
                off += _HEADER.size + plen
                if lsn <= after_lsn:
                    continue
                kinds, keys, vals = _decode_payload(payload)
                if filtered:
                    m = (keys >= lo) & (keys <= hi)
                    if not m.any():
                        continue
                    kinds, keys, vals = kinds[m], keys[m], vals[m]
                yield WalRecord(lsn, kinds, keys, vals)

    # -------------------------------------------------------------- truncate
    def truncate_upto(self, lsn: int) -> int:
        """Unlink closed segments fully covered by a checkpoint at ``lsn``.

        Returns the number of segments removed.  The newest segment is
        always kept (even if fully covered) so the LSN counter survives a
        restart with an empty tail.
        """
        removed = 0
        while len(self._segments) > 1 and self._segments[0].last_lsn <= lsn:
            seg = self._segments.pop(0)
            os.unlink(seg.path)
            removed += 1
        if removed:
            _fsync_dir(self.dir)
            self.gc_segments += removed
        return removed

    # ----------------------------------------------------------------- misc
    @property
    def n_segments(self) -> int:
        return len(self._segments)

    def stats(self) -> dict:
        return {
            "last_lsn": int(self.last_lsn),
            "appends": int(self.appends),
            "syncs": int(self.syncs),
            "bytes_appended": int(self.bytes_appended),
            "segments": int(self.n_segments),
            "gc_segments": int(self.gc_segments),
            "truncated_tail_bytes": int(self.truncated_tail_bytes),
        }

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None
