"""Windowed metrics: log-bucket histograms and fixed-width timelines.

Two consumers share :class:`LogBucketHistogram`:

* ``workloads/driver.py`` — previously kept every latency sample in an
  unbounded Python list just to call ``np.percentile`` at the end;
* ``engine_api.DeviceNBTreeEngine`` — previously kept a bounded deque of
  maintain-unit wall times and its own percentile code.

Both now use the same bounded structure: 4 buckets per decade across
1ns..1000s (the exact edges the SLO tracker already reports, so JSON
shapes stay comparable), plus *exact* running count/sum/min/max.  Tail
percentiles (p50/p99/p99.9) are interpolated within the owning bucket,
which bounds their relative error by the bucket width (~78% per bucket,
i.e. the reported quantile is within one bucket of the exact sample
quantile — property-tested in ``tests/test_obs.py``).  p100 and the mean
stay exact, because figure checks (``fig_scaling``, ``fig_mixed``)
compare p100 against paper bounds and must not inherit bucketing error.

:class:`WindowedMetrics` turns per-commit observations into fixed-width
timeline rows on the *sim clock*: ops/s, p50/p99/p99.9, queue-depth and
maintenance-debt gauges per window.  Windows are closed deterministically
(a clock jump emits the intervening empty windows), so a timeline is a
pure function of (trace, engine config) and byte-reproducible across
runs — the determinism contract BENCH_stability.json relies on.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

#: Shared bucket edges: 4 buckets/decade, 1 ns .. 1000 s.  Identical to
#: ``ingest.slo.BUCKET_EDGES_S`` and the driver's former ``EDGES`` so all
#: report shapes remain mutually comparable.
BUCKET_EDGES_S = np.logspace(-9, 3, 49)

#: A window whose p99 exceeds ``stall_k`` x the trailing-median p99 is a
#: stall window (see obs/stall.py); mirrors ``slo.STALL_FACTOR``'s role
#: for per-op accounting but applied to windowed timelines.
DEFAULT_STALL_K = 4.0


@dataclasses.dataclass
class ObsConfig:
    """Observability switches threaded through frontends and engines.

    Default-off: every hot-path hook is behind ``if obs is None`` (or an
    equivalent attribute check), so tier-1 timings are untouched unless a
    caller explicitly opts in.
    """

    enabled: bool = True
    #: fixed window width on the owning clock (sim seconds for cost-model
    #: tiers, wall seconds for the device tier)
    window_s: float = 1.0
    #: write Chrome trace_event JSON here at end of run (None = keep the
    #: ring buffer in memory only)
    trace_path: str | None = None
    #: ring-buffer capacity, in events; oldest spans are dropped first
    trace_capacity: int = 1 << 16
    #: stalled-window threshold multiplier over the trailing-median p99
    stall_k: float = DEFAULT_STALL_K
    #: windows of history for the trailing median
    stall_trailing: int = 16


class LogBucketHistogram:
    """Bounded-memory latency histogram with exact extremes.

    Memory is O(#buckets) regardless of sample count.  ``summary()``
    matches the JSON shape of ``slo._tail_summary`` (count/mean/p50/p99/
    p999/p100/bucket edges+counts) so downstream report readers cannot
    tell which implementation produced a block.
    """

    __slots__ = ("counts", "count", "total", "min", "max", "_edges")

    def __init__(self, edges: np.ndarray = BUCKET_EDGES_S):
        self._edges = np.asarray(edges, dtype=np.float64)
        self.counts = np.zeros(len(self._edges) - 1, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = 0.0

    def add(self, x: float) -> None:
        i = int(np.searchsorted(self._edges, x, side="right")) - 1
        i = min(max(i, 0), len(self.counts) - 1)  # clamp, never drop
        self.counts[i] += 1
        self.count += 1
        self.total += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x

    def add_many(self, xs) -> None:
        xs = np.asarray(xs, dtype=np.float64)
        if xs.size == 0:
            return
        idx = np.clip(np.searchsorted(self._edges, xs, side="right") - 1,
                      0, len(self.counts) - 1)
        np.add.at(self.counts, idx, 1)
        self.count += int(xs.size)
        self.total += float(xs.sum())
        self.min = min(self.min, float(xs.min()))
        self.max = max(self.max, float(xs.max()))

    def merge(self, other: "LogBucketHistogram") -> None:
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile; exact at q=0 and q=1."""
        if self.count == 0:
            return 0.0
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        rank = q * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c > rank:
                # linear interpolation inside the bucket, clamped to the
                # exact extremes so p-anything never exceeds the true max
                lo, hi = self._edges[i], self._edges[i + 1]
                frac = (rank - cum) / c
                v = lo + frac * (hi - lo)
                return float(min(max(v, self.min), self.max))
            cum += c
        return self.max

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        """JSON block shaped like ``slo._tail_summary``."""
        if self.count == 0:
            return {"count": 0, "mean_s": 0.0, "p50_s": 0.0, "p99_s": 0.0,
                    "p999_s": 0.0, "p100_s": 0.0,
                    "bucket_edges_s": [float(e) for e in self._edges],
                    "bucket_counts": [0] * len(self.counts)}
        return {
            "count": int(self.count),
            "mean_s": float(self.mean),
            "p50_s": self.quantile(0.50),
            "p99_s": self.quantile(0.99),
            "p999_s": self.quantile(0.999),
            "p100_s": float(self.max),
            "bucket_edges_s": [float(e) for e in self._edges],
            "bucket_counts": [int(c) for c in self.counts],
        }

    def reset(self) -> None:
        self.counts[:] = 0
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = 0.0


class WindowedMetrics:
    """Fixed-width timeline rollover on an externally supplied clock.

    Feed it per-commit observations via :meth:`record`; it closes windows
    whenever the clock crosses a window boundary, including emitting the
    empty windows a clock jump skips over (an idle second is a real
    second of the timeline — dropping it would hide stalls).  ``finish``
    flushes the trailing partial window and computes run-level scores:

    * **stall-free %** — share of non-empty windows whose p99 stays under
      ``stall_k`` x the trailing-median p99 (obs/stall.py's detector);
    * **fluctuation score** — coefficient of variation (std/mean) of
      per-window throughput over non-empty windows, the "Towards a
      B+-tree with Fluctuation-Free Performance" metric: 0 is perfectly
      flat, LSM saw-tooth pushes it up.
    """

    def __init__(self, window_s: float = 1.0, *, t0: float = 0.0,
                 stall_k: float = DEFAULT_STALL_K, stall_trailing: int = 16):
        if window_s <= 0:
            raise ValueError("window_s must be positive")
        self.window_s = float(window_s)
        self.stall_k = float(stall_k)
        self.stall_trailing = int(stall_trailing)
        self._t0 = float(t0)
        self._win = 0          # index of the currently open window
        self._ops = 0
        self._hist = LogBucketHistogram()
        self._queue_peak = 0
        self._debt_peak = 0
        self._shed = 0
        self.windows: list[dict] = []

    # -- feeding -----------------------------------------------------------
    def _win_of(self, t: float) -> int:
        return int((t - self._t0) / self.window_s)

    def _close_through(self, win: int) -> None:
        """Close every window strictly before ``win`` (emitting empties)."""
        while self._win < win:
            self._emit()
            self._win += 1

    def _emit(self) -> None:
        h = self._hist
        w = {
            "t_start_s": self._t0 + self._win * self.window_s,
            "t_end_s": self._t0 + (self._win + 1) * self.window_s,
            "ops": int(self._ops),
            "ops_per_s": self._ops / self.window_s,
            "p50_s": h.quantile(0.50),
            "p99_s": h.quantile(0.99),
            "p999_s": h.quantile(0.999),
            "p100_s": float(h.max) if h.count else 0.0,
            "queue_peak": int(self._queue_peak),
            "debt_peak": int(self._debt_peak),
            "shed": int(self._shed),
        }
        self.windows.append(w)
        self._ops = 0
        self._hist.reset()
        self._queue_peak = 0
        self._debt_peak = 0
        self._shed = 0

    def record(self, t: float, latency_s, *, ops: int = 1,
               queue_depth: int = 0, debt: int = 0) -> None:
        """Record ``ops`` operations completing at sim time ``t``.

        ``latency_s`` may be a scalar or an array of per-op latencies.
        """
        self._close_through(self._win_of(t))
        self._ops += int(ops)
        if np.ndim(latency_s) == 0:
            self._hist.add(float(latency_s))
        else:
            self._hist.add_many(latency_s)
        if queue_depth > self._queue_peak:
            self._queue_peak = int(queue_depth)
        if debt > self._debt_peak:
            self._debt_peak = int(debt)

    def record_shed(self, t: float, n: int = 1) -> None:
        self._close_through(self._win_of(t))
        self._shed += int(n)

    # -- finishing ---------------------------------------------------------
    def finish(self, t_end: float | None = None) -> dict:
        """Close out the timeline and return the summary block.

        ``t_end`` extends the timeline with trailing empty windows up to
        that instant (e.g. the drain-complete time).
        """
        if t_end is not None:
            self._close_through(self._win_of(t_end))
        # flush the open (possibly partial) window if it saw anything
        if self._ops or self._hist.count or self._shed:
            self._emit()
        return self.summary()

    def summary(self) -> dict:
        from repro.obs.stall import detect_stalls

        active = [w for w in self.windows if w["ops"] > 0]
        n_active = len(active)
        stalled = detect_stalls(self.windows, k=self.stall_k,
                                trailing=self.stall_trailing)
        rates = np.asarray([w["ops_per_s"] for w in active], dtype=np.float64)
        if n_active >= 2 and rates.mean() > 0:
            fluctuation = float(rates.std() / rates.mean())
        else:
            fluctuation = 0.0
        stall_free_pct = (100.0 * (1.0 - len(stalled) / n_active)
                          if n_active else 100.0)
        return {
            "window_s": self.window_s,
            "n_windows": len(self.windows),
            "n_active_windows": n_active,
            "stall_k": self.stall_k,
            "stalled_windows": [w["index"] for w in stalled],
            "stall_free_pct": stall_free_pct,
            "fluctuation_score": fluctuation,
            "timeline": self.windows,
        }
