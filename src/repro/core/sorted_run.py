"""Sorted-run primitives shared by the host-side (numpy) index implementations.

A *run* is the on-disk representation of a d-tree (paper Sec. 4.1): the leaf
level of a B+-tree written sequentially in key order.  Internal d-nodes
degenerate to binary search over the sorted array (same asymptotics,
``log_B sigma`` with B = page fanout), which is also the TPU-native layout —
see DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses

import numpy as np

KEY_DTYPE = np.uint64
VAL_DTYPE = np.int64

#: sentinel for padded key slots (sorts after every real key).
KEY_MAX = np.uint64(0xFFFFFFFFFFFFFFFF)

#: value tombstone bit — delta record that deletes its key (paper Sec. 3.2.2).
TOMBSTONE = np.int64(-1)


@dataclasses.dataclass
class Run:
    """An immutable sorted run with a lazy-removal watermark (paper Sec. 5.1).

    ``keys[:wm]`` have already been flushed to children and are dead; they
    remain on disk until the run is rewritten ("lazy removal").
    """

    keys: np.ndarray
    vals: np.ndarray
    wm: int = 0

    def __post_init__(self):
        assert self.keys.dtype == KEY_DTYPE, self.keys.dtype
        assert len(self.keys) == len(self.vals)

    @staticmethod
    def empty() -> "Run":
        return Run(np.empty(0, KEY_DTYPE), np.empty(0, VAL_DTYPE))

    @property
    def live_keys(self) -> np.ndarray:
        return self.keys[self.wm:]

    @property
    def live_vals(self) -> np.ndarray:
        return self.vals[self.wm:]

    def __len__(self) -> int:  # number of *live* pairs
        return len(self.keys) - self.wm

    @property
    def disk_pairs(self) -> int:  # pairs physically on disk (incl. dead prefix)
        return len(self.keys)

    def lookup(self, key: np.uint64):
        """Binary search among live pairs; returns value or None."""
        k = self.live_keys
        i = int(np.searchsorted(k, key))
        if i < len(k) and k[i] == key:
            return self.live_vals[i]
        return None

    def range(self, lo, hi):
        """Live pairs with lo <= key <= hi (inclusive), in key order.

        ``lo > hi`` is an empty range.  Returns (keys, vals) copies — the
        sequential leaf scan between the two d-tree descents.
        """
        k = self.live_keys
        i0 = int(np.searchsorted(k, lo, side="left"))
        i1 = int(np.searchsorted(k, hi, side="right"))
        if i1 <= i0:
            return np.empty(0, KEY_DTYPE), np.empty(0, VAL_DTYPE)
        return k[i0:i1].copy(), self.live_vals[i0:i1].copy()


def merge_runs(a_keys, a_vals, b_keys, b_vals):
    """Merge two sorted (keys, vals) streams; on duplicate keys *a wins*.

    ``a`` is the newer data (flushed down from the parent), so its delta
    records supersede the child's older pairs — the resolution rule of
    paper Sec. 3.2.2.  Pure numpy; the device tier uses the Pallas
    ``merge_sorted`` kernel with identical semantics (kernels/ref.py).
    """
    if len(a_keys) == 0:
        return b_keys.copy(), b_vals.copy()
    if len(b_keys) == 0:
        return a_keys.copy(), a_vals.copy()
    keys = np.concatenate([a_keys, b_keys])
    vals = np.concatenate([a_vals, b_vals])
    # stable sort with 'a' entries first so that on ties the 'a' copy leads.
    order = np.argsort(keys, kind="stable")
    keys, vals = keys[order], vals[order]
    keep = np.ones(len(keys), bool)
    keep[1:] = keys[1:] != keys[:-1]  # drop the older duplicate (it follows)
    return keys[keep], vals[keep]


def drop_tombstones(keys, vals):
    """Resolve delete-deltas at the last level (paper Sec. 3.2.2)."""
    keep = vals != TOMBSTONE
    return keys[keep], vals[keep]


def partition_by_pivots(keys, vals, pivots):
    """Split a sorted stream into len(pivots)+1 key-disjoint slices.

    Slice i holds keys in [pivots[i-1], pivots[i]) — the cross-s-node
    linkage property (paper Sec. 3.1.1).
    """
    cuts = np.searchsorted(keys, np.asarray(pivots, dtype=keys.dtype), side="left")
    bounds = [0, *cuts.tolist(), len(keys)]
    return [
        (keys[bounds[i]:bounds[i + 1]], vals[bounds[i]:bounds[i + 1]])
        for i in range(len(bounds) - 1)
    ]
