import os

# 8 placeholder devices for the distribution/integration tests (the dry-run
# uses 512, but only inside launch/dryrun.py).  Harmless for single-device
# tests: unsharded computations run on device 0.  Must be set before the
# first jax import anywhere in the session.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
