"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here defines the *semantics*; the Pallas kernels must match it
bit-for-bit (integer ops) or to numerical tolerance (attention).  Tests sweep
shapes/dtypes and assert allclose kernel-vs-oracle (interpret=True on CPU).

Device-tier conventions (DESIGN.md §2): keys are uint32 (TPU-native lane
width), ``KEY_MAX`` = 0xFFFFFFFF is the padding sentinel and sorts last,
values are int32 payload references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

KEY_MAX32 = jnp.uint32(0xFFFFFFFF)

# Murmur3/xxhash-style 32-bit mixing constants for Bloom hashing.
BLOOM_MULTS = (0x85EBCA6B, 0xC2B2AE35, 0x9E3779B1, 0x27D4EB2F, 0x165667B1, 0xD3A2646C)


def merge_sorted_ref(a_keys, a_vals, b_keys, b_vals):
    """Merge two sorted runs; equal keys keep the ``a`` copy *first*.

    ``a`` is the newer stream (flushed from the parent d-tree), so a query
    that takes the leftmost match sees the freshest record — the delta-record
    resolution rule of paper Sec. 3.2.2.  Output length = len(a)+len(b);
    KEY_MAX padding naturally sorts to the tail.
    """
    keys = jnp.concatenate([a_keys, b_keys])
    vals = jnp.concatenate([a_vals, b_vals])
    # stable ascending sort; 'a' entries precede 'b' entries on equal keys
    # because they come first in the concatenation.
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


def sorted_search_ref(run_keys, run_vals, queries):
    """Batched B+-tree-leaf search of ``queries`` in one sorted run.

    Returns (found: bool (Q,), vals: int32 (Q,), idx: int32 (Q,)) where idx is
    the *leftmost* position with run_keys[idx] == q (the freshest copy under
    duplicate-keeping merges).  Padding keys KEY_MAX never match.
    """
    idx = jnp.searchsorted(run_keys, queries, side="left").astype(jnp.int32)
    n = run_keys.shape[0]
    safe = jnp.minimum(idx, n - 1)
    hit_key = run_keys[safe]
    found = (idx < n) & (hit_key == queries) & (queries != KEY_MAX32)
    vals = jnp.where(found, run_vals[safe], jnp.int32(-1))
    return found, vals, idx


def range_scan_ref(run_keys, run_vals, lo, hi, max_results: int = 128):
    """Inclusive range scan [lo, hi] of one sorted run (range_scan oracle).

    Returns (keys uint32 (Q, max_results), vals int32 (Q, max_results),
    count int32 (Q,)); ``count`` is the total number of matches and may
    exceed ``max_results`` (the caller's truncation signal).  KEY_MAX
    padding in the run never matches: the upper bound is clamped to the
    live (non-sentinel) prefix.
    """
    n = run_keys.shape[0]
    n_live = jnp.sum((run_keys != KEY_MAX32).astype(jnp.int32))
    start = jnp.searchsorted(run_keys, lo, side="left").astype(jnp.int32)
    end = jnp.minimum(
        jnp.searchsorted(run_keys, hi, side="right").astype(jnp.int32), n_live)
    count = jnp.maximum(end - start, 0)
    idx = start[:, None] + jnp.arange(max_results, dtype=jnp.int32)
    valid = idx < end[:, None]
    safe = jnp.clip(idx, 0, n - 1)
    keys = jnp.where(valid, run_keys[safe], KEY_MAX32)
    vals = jnp.where(valid, run_vals[safe], 0)
    return keys, vals, count


def bloom_hash_ref(keys, h: int, nbits: int):
    """(h, N) bit positions via 32-bit multiply-xorshift mixing."""
    x = keys.astype(jnp.uint32)[None, :]
    m = jnp.asarray(BLOOM_MULTS[:h], jnp.uint32)[:, None]
    x = x * m
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x2C1B3C6D)
    x = x ^ (x >> 12)
    x = x * jnp.uint32(0x297A2D39)
    x = x ^ (x >> 15)
    return (x % jnp.uint32(nbits)).astype(jnp.int32)


def bloom_build_ref(keys, nbits: int, h: int = 3):
    """Bloom bit array as (nbits//32,) uint32 words.

    OR-scatter realized as ONE 0/1 max-scatter into an (nbits,) cell array
    (max == OR on single bits) followed by a 32-cells-per-word pack — each
    cell lands on a distinct bit, so the shifted sum carries nothing and
    equals the bitwise OR.  ~30x faster than the per-bit-plane scatter loop
    it replaced (one scatter instead of 32) with a bit-identical layout:
    bit ``pos % 32`` of word ``pos // 32``.  Build runs on every run
    rewrite inside the fused emptying cascade, so it IS on the ingest
    critical path; per-batch root maintenance uses the O(batch)
    ``bloom_update_ref`` instead.
    """
    assert nbits % 32 == 0
    pos = bloom_hash_ref(keys, h, nbits).reshape(-1)      # h-major (h*N,)
    valid = jnp.tile(keys != KEY_MAX32, (h,))
    cells = jnp.zeros(nbits, jnp.uint32).at[pos].max(valid.astype(jnp.uint32))
    return (cells.reshape(-1, 32) << jnp.arange(32, dtype=jnp.uint32)).sum(
        axis=1, dtype=jnp.uint32)


def bloom_update_ref(words, keys, nbits: int, h: int = 3):
    """OR ``keys``' bits into an existing filter — O(batch), not O(run).

    A Bloom filter of a key set is the bitwise OR of its members' bit
    patterns, so ``update(build(S), B) == build(S ∪ B)`` *exactly* (not
    merely a superset): a run that only ever grows between rewrites can
    maintain its filter incrementally per insert batch and stay
    bit-identical to a from-scratch rebuild.  That identity is the fused
    ingest path's Bloom invariant (DESIGN.md §8) and is property-tested.
    """
    return words | bloom_build_ref(keys, nbits, h)


def bloom_probe_ref(words, queries, nbits: int, h: int = 3):
    """Membership probe → bool (Q,).  No false negatives by construction."""
    pos = bloom_hash_ref(queries, h, nbits)  # (h, Q)
    w = words[pos // 32]
    bit = (w >> (pos % 32).astype(jnp.uint32)) & jnp.uint32(1)
    return jnp.all(bit == 1, axis=0)


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """Decode-step attention over a paged KV cache (fp32 accumulation).

    q:            (B, KVH, G, D)   one new query token per sequence
    k_pages:      (KVH, P, S, D)   P physical pages of S slots
    v_pages:      (KVH, P, S, D)
    block_tables: (B, MP) int32    logical page p of seq b -> physical page
    seq_lens:     (B,) int32       valid tokens per sequence
    returns:      (B, KVH, G, D)
    """
    B, KVH, G, D = q.shape
    _, P, S, _ = k_pages.shape
    MP = block_tables.shape[1]

    def per_seq(qb, bt, ln):
        # gather this sequence's pages: (KVH, MP*S, D)
        k = k_pages[:, bt].reshape(KVH, MP * S, D)
        v = v_pages[:, bt].reshape(KVH, MP * S, D)
        scores = jnp.einsum("hgd,htd->hgt", qb.astype(jnp.float32), k.astype(jnp.float32))
        scores = scores / jnp.sqrt(jnp.float32(D))
        mask = jnp.arange(MP * S) < ln
        scores = jnp.where(mask[None, None, :], scores, -jnp.inf)
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("hgt,htd->hgd", p, v.astype(jnp.float32)).astype(q.dtype)

    return jax.vmap(per_seq)(q, block_tables, seq_lens)
