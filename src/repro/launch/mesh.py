"""Production mesh construction.

Single pod : (16, 16)    axes ("data", "model")      = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Defined as a *function* so importing this module never touches JAX device
state (device count is locked at first backend init — the dry-run sets
XLA_FLAGS before any import; tests and benches see the real 1-CPU world).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic resize)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def host_device_flag(n: int = 512) -> str:
    return f"--xla_force_host_platform_device_count={n}"
