"""ShardedEngine: range-partitioned ensemble of any registered engine.

This is the scale-out layer of DESIGN.md §6.  ``ShardedEngine`` is itself a
:class:`~repro.core.engine_api.StorageEngine`, so every driver, benchmark
and conformance test that programs against the unified protocol works on an
ensemble unchanged — ``make_engine("sharded:nbtree", shards=4)`` is a
drop-in for ``make_engine("nbtree")``.

Semantics and structure:

* **Partitioning.**  Keys are routed by a :class:`RangePartitioner` whose
  pivots are sampled as quantiles of the first insert batch (hash
  partitioning is available via ``partition="hash"``).  Point ops go to
  exactly one shard; RANGE ops fan out to every shard whose interval
  intersects ``[lo, hi]``.
* **Order-preserving split/merge.**  An incoming :class:`OpBatch` is split
  into per-shard sub-batches that keep the *original op order* (a RANGE op
  is placed into each overlapping shard's stream at its original
  position), so the sequential within-batch semantics of the protocol hold
  per shard; results are scattered back to original positions, and a
  fanned-out RANGE merges its per-shard sorted fragments with a stable
  key sort (shards are disjoint, so no cross-shard dedup is needed).
  Sub-batch selection preserves the generator's kind grouping, so a device
  shard still serves its slice in <= 4 fused pow2-bucketed jitted calls.
* **Cross-shard deamortized maintenance.**  ``maintain(budget)`` hands the
  step budget to a :class:`DebtScheduler` (heaviest pending debt first,
  round-robin tiebreak) so the ensemble's worst-case insertion delay stays
  at the single-shard bound instead of degenerating into unscheduled
  background stalls (Luo & Carey 2019).  Leftover budget funds *hot-shard
  splitting*: when one shard's live-pair count exceeds ``skew_factor``
  times the mean of its peers, its pairs are cut at their median key into
  two fresh shards and the pivot table grows — how a moving-hotspot ingest
  is kept balanced.
* **Aggregated stats.**  ``stats()`` sums the monotone I/O counters (a
  retired-shard accumulator keeps them monotone *across rebalances*),
  takes the max height, and carries the per-shard debt vector
  (``EngineStats.shard_debt``).
"""
from __future__ import annotations

import numpy as np

from repro.core.engine_api import (EngineStats, OpBatch, OpKind, OpResult,
                                   StorageEngine, make_engine)
from repro.core.sorted_run import KEY_DTYPE, VAL_DTYPE
from repro.distributed.fault_tolerance import StragglerDetector

from .partition import HashPartitioner, RangePartitioner
from .scheduler import DebtScheduler


class ShardedEngine(StorageEngine):
    """Range- (or hash-) partitioned ensemble of one registered base engine."""

    name = "sharded"

    def __init__(self, base: str = "nbtree", *, shards: int = 4,
                 partition: str = "range", skew_factor: float = 4.0,
                 min_split_pairs: int = 512, max_shards: int = 64, **base_kw):
        super().__init__()
        assert shards >= 1 and partition in ("range", "hash")
        assert skew_factor > 1.0
        self.base = base
        self.n_target = int(shards)
        self.skew_factor = float(skew_factor)
        self.min_split_pairs = int(min_split_pairs)
        self.max_shards = max(int(max_shards), int(shards))
        self._base_kw = dict(base_kw)
        self._sched = DebtScheduler()
        self._straggle: StragglerDetector | None = None
        self.partitioner = None
        self._engines: list[StorageEngine] = []
        self._debts: list[int] = []
        self._approx_live: list[int] = []   # split trigger only; never exact
        self._inherited_s: list[float] = []
        self.n_splits = 0
        # monotone counters of shards retired by rebalances
        # (io_s, seeks, rd, wr, bloom probes / skips / false positives,
        #  maintain units, maintain wall seconds, device dispatches)
        self._retired = [0.0, 0, 0, 0, 0, 0, 0, 0, 0.0, 0]
        self._tracer = None
        if partition == "hash":
            self.partitioner = HashPartitioner(shards)
            self._spawn_all()

    # ------------------------------------------------------------ observability
    def attach_tracer(self, tracer) -> None:
        """Forward the tracer to every shard (current and future) and emit
        ensemble-level events: one ``shard_split`` instant per rebalance
        and a ``cascade`` debt-allocation instant whenever the scheduler
        hands out budget.  Event timestamps are the ensemble's *charged*
        I/O seconds — deterministic on sim tiers, and monotone."""
        self._tracer = tracer
        for e in self._engines:
            e.attach_tracer(tracer)

    # ------------------------------------------------------------ construction
    def _make_shard(self) -> StorageEngine:
        return make_engine(self.base, **self._base_kw)

    def _spawn_all(self) -> None:
        n = self.partitioner.n_shards
        self._engines = [self._make_shard() for _ in range(n)]
        self._debts = [0] * n
        self._approx_live = [0] * n
        self._inherited_s = [0.0] * n   # retired predecessors' charged time
        self._straggle = StragglerDetector(list(range(n)), warmup=4)
        if self._tracer is not None:
            for e in self._engines:
                e.attach_tracer(self._tracer)

    def _bootstrap(self, batch: OpBatch) -> None:
        """Sample range pivots from the first batch (insert keys preferred)."""
        keys = batch.keys[batch.kinds == int(OpKind.INSERT)]
        if len(keys) == 0:
            keys = batch.keys
        self.partitioner = RangePartitioner.from_sample(keys, self.n_target)
        self._spawn_all()

    @property
    def shard_engines(self) -> tuple:
        return tuple(self._engines)

    def shard_io_times(self) -> list[float]:
        """Per-shard monotone charged cost (parallel-makespan ingredient).

        A shard's lineage time includes its retired predecessors: the work a
        pre-split shard did happened serially on the same logical partition,
        so dropping it on split would make the ensemble makespan (and hence
        aggregate throughput) look better right after every rebalance.
        """
        return [inh + e.io_time_s()
                for inh, e in zip(self._inherited_s, self._engines)]

    # ------------------------------------------------------------------ apply
    def apply(self, batch: OpBatch) -> OpResult:
        n = len(batch)
        if n == 0:
            return OpResult(batch.kinds.copy(), np.zeros(0, bool),
                            np.full(0, -1, VAL_DTYPE), [], np.zeros(0))
        if self.partitioner is None:
            self._bootstrap(batch)

        kinds = np.asarray(batch.kinds)
        keys = np.asarray(batch.keys)
        his = np.asarray(batch.his)
        sid = self.partitioner.shard_of(keys)
        pos: list[list[int]] = [[] for _ in self._engines]
        for i in range(n):
            if kinds[i] == int(OpKind.RANGE):
                for s in self.partitioner.shards_for_range(int(keys[i]),
                                                           int(his[i])):
                    pos[s].append(i)
            else:
                pos[int(sid[i])].append(i)

        found = np.zeros(n, bool)
        values = np.full(n, -1, VAL_DTYPE)
        lat = np.zeros(n, np.float64)
        truncated = np.zeros(n, bool)
        range_parts: dict[int, list] = {}
        for s, idx_list in enumerate(pos):
            if not idx_list:
                continue
            idx = np.asarray(idx_list, np.int64)
            sub = OpBatch(kinds[idx], keys[idx], batch.vals[idx], his[idx])
            res = self._engines[s].apply(sub)
            pmask = np.asarray(sub.kinds) != int(OpKind.RANGE)
            pidx = idx[pmask]
            found[pidx] = res.found[pmask]
            values[pidx] = res.values[pmask]
            lat[pidx] = res.latency_s[pmask]
            for j in np.nonzero(~pmask)[0]:
                i = int(idx[j])
                range_parts.setdefault(i, []).append(res.range_hits[j])
                # fan-out runs shard-parallel: the op costs its slowest leg
                lat[i] = max(lat[i], float(res.latency_s[j]))
                truncated[i] |= bool(res.range_truncated[j])
            ins = int((np.asarray(sub.kinds) == int(OpKind.INSERT)).sum())
            dels = int((np.asarray(sub.kinds) == int(OpKind.DELETE)).sum())
            self._approx_live[s] += ins - dels
            self._debts[s] = self._engines[s].maintain(0)

        range_hits: list = [None] * n
        for i in np.nonzero(kinds == int(OpKind.RANGE))[0]:
            parts = range_parts.get(int(i), [])
            if not parts:
                range_hits[int(i)] = (np.zeros(0, KEY_DTYPE),
                                      np.zeros(0, VAL_DTYPE))
                continue
            rk = np.concatenate([p[0] for p in parts])
            rv = np.concatenate([p[1] for p in parts])
            order = np.argsort(rk, kind="stable")   # shards are disjoint
            range_hits[int(i)] = (rk[order], rv[order])
        for k in OpKind:                            # each op counted once
            self._counts[k] += int((kinds == int(k)).sum())
        return OpResult(batch.kinds.copy(), found, values, range_hits, lat,
                        truncated)

    # ------------------------------------------------------------- maintenance
    def maintain(self, budget: int = 1) -> int:
        """Debt-weighted cross-shard maintenance; returns ensemble debt."""
        if not self._engines:
            return 0
        budget = int(budget)
        slow = self._straggle.stragglers() if self._straggle else ()
        alloc = self._sched.allocate(self._debts, budget, stragglers=slow)
        if self._tracer is not None and sum(alloc) > 0:
            self._tracer.instant("cascade", "debt_alloc", self.io_time_s(),
                                 debts=list(self._debts), alloc=list(alloc),
                                 stragglers=list(slow))
        for s, units in enumerate(alloc):
            if units:
                before = self._engines[s].io_time_s()
                self._debts[s] = self._engines[s].maintain(units)
                # per-unit charged seconds feed the straggler EWMA: a shard
                # whose units cost more time is nearer a forced drain at
                # equal debt, so the scheduler front-loads it next step
                self._straggle.record(
                    s, (self._engines[s].io_time_s() - before) / units)
        if (sum(alloc) < budget and self.partitioner.can_split
                and len(self._engines) < self.max_shards):
            self._maybe_split_hot()
        return sum(self._debts)

    def drain(self) -> None:
        for e in self._engines:
            e.drain()
        self._debts = [0] * len(self._engines)

    # ------------------------------------------------------- hot-shard splits
    def _maybe_split_hot(self) -> bool:
        n = len(self._engines)
        if n < 2:       # skew is relative: a lone shard has no peers to lag
            return False
        total = sum(self._approx_live)
        s = int(np.argmax(self._approx_live))
        # compare against the mean of the *other* shards: a hot shard is
        # always part of the ensemble mean, so an inclusive-mean threshold
        # of skew_factor >= n is unreachable (max live <= n * mean) and the
        # default config would never rebalance.
        peers = max(1.0, (total - self._approx_live[s]) / (n - 1))
        if (self._approx_live[s] < self.min_split_pairs
                or self._approx_live[s] <= self.skew_factor * peers):
            return False
        return self._split_shard(s)

    def _split_shard(self, sid: int) -> bool:
        """Cut shard ``sid`` at its median live key into two fresh shards."""
        eng = self._engines[sid]
        eng.drain()
        lo, hi = self.partitioner.interval(sid)
        res = eng.apply(OpBatch.ranges([lo], [hi]))
        rk, rv = res.range_hits[0]
        if bool(res.range_truncated[0]):    # would silently drop live pairs
            raise RuntimeError(
                f"hot-shard split of shard {sid} truncated its extraction "
                f"range scan ({len(rk)} pairs returned)")
        if len(rk) < 2:
            self._approx_live[sid] = len(rk)    # correct a stale trigger
            return False
        q = int(rk[len(rk) // 2])
        if q == int(rk[0]):                     # duplicate-heavy left half:
            above = np.nonzero(rk > rk[0])[0]   # first key that can separate
            if len(above) == 0:
                self._approx_live[sid] = len(rk)
                return False
            q = int(rk[above[0]])
        st = eng.stats()                        # keep aggregate stats monotone
        self._retired[0] += st.io_time_s
        self._retired[1] += st.io_seeks
        self._retired[2] += st.io_bytes_read
        self._retired[3] += st.io_bytes_written
        self._retired[4] += st.bloom_probes
        self._retired[5] += st.bloom_negative_skips
        self._retired[6] += st.bloom_false_positives
        self._retired[7] += st.maintain_units
        self._retired[8] += st.maintain_wall_s
        self._retired[9] += st.device_dispatches
        lineage_s = self._inherited_s[sid] + eng.io_time_s()
        left = rk < np.uint64(q)
        a, b = self._make_shard(), self._make_shard()
        if self._tracer is not None:
            a.attach_tracer(self._tracer)
            b.attach_tracer(self._tracer)
            self._tracer.instant(
                "shard_split", "split", self.io_time_s(), shard=int(sid),
                pivot=int(q), left_pairs=int(left.sum()),
                right_pairs=int((~left).sum()),
                n_shards=len(self._engines) + 1)
        a.apply(OpBatch.inserts(rk[left], rv[left]))
        b.apply(OpBatch.inserts(rk[~left], rv[~left]))
        self.partitioner.split(sid, q)
        self._engines[sid:sid + 1] = [a, b]
        self._approx_live[sid:sid + 1] = [int(left.sum()), int((~left).sum())]
        # both children continue the same partition's serial history
        self._inherited_s[sid:sid + 1] = [lineage_s, lineage_s]
        # the rewrite itself is deferred work the scheduler keeps paying off
        self._debts[sid:sid + 1] = [a.maintain(0), b.maintain(0)]
        # shard indices shifted: per-index EWMA history is stale, restart it
        self._straggle = StragglerDetector(list(range(len(self._engines))),
                                           warmup=4)
        self.n_splits += 1
        return True

    # ------------------------------------------------------------------- stats
    def io_time_s(self) -> float:
        return self._retired[0] + sum(e.io_time_s() for e in self._engines)

    def height(self) -> int:
        return max((e.height() for e in self._engines), default=0)

    def count_live(self) -> int:
        return sum(e.count_live() for e in self._engines)

    def dump_live(self) -> tuple:
        """Key-sorted union of the shard dumps (shards are disjoint)."""
        dumps = [e.dump_live() for e in self._engines]
        if not dumps:
            return (np.zeros(0, KEY_DTYPE), np.zeros(0, VAL_DTYPE))
        rk = np.concatenate([d[0] for d in dumps])
        rv = np.concatenate([d[1] for d in dumps])
        order = np.argsort(rk, kind="stable")
        return rk[order], rv[order]

    def dump_live_range(self, lo: int, hi: int) -> tuple:
        """Range-scoped dump touching only intersecting shards.

        A tenant namespace is one contiguous encoded interval
        (``repro.tenancy``), so per-tenant snapshots and stats read a few
        shards, not the whole ensemble — the scoped counterpart of the
        RANGE fan-out.
        """
        if self.partitioner is None:
            return (np.zeros(0, KEY_DTYPE), np.zeros(0, VAL_DTYPE))
        dumps = [self._engines[s].dump_live_range(lo, hi)
                 for s in self.partitioner.shards_for_range(int(lo), int(hi))]
        if not dumps:
            return (np.zeros(0, KEY_DTYPE), np.zeros(0, VAL_DTYPE))
        rk = np.concatenate([d[0] for d in dumps])
        rv = np.concatenate([d[1] for d in dumps])
        order = np.argsort(rk, kind="stable")
        return rk[order], rv[order]

    def stats(self) -> EngineStats:
        per = [e.stats() for e in self._engines]
        debts = [e.maintain(0) for e in self._engines]
        self._debts = list(debts) if debts else self._debts
        return EngineStats(
            engine=self.name,
            clock=per[0].clock if per else "sim",
            io_time_s=self._retired[0] + sum(s.io_time_s for s in per),
            io_seeks=self._retired[1] + sum(s.io_seeks for s in per),
            io_bytes_read=self._retired[2] + sum(s.io_bytes_read for s in per),
            io_bytes_written=(self._retired[3]
                              + sum(s.io_bytes_written for s in per)),
            height=max((s.height for s in per), default=0),
            total_pairs=sum(s.total_pairs for s in per),
            physical_pairs=sum(s.physical_pairs for s in per),
            pending_debt=sum(debts),
            n_inserts=self._counts[OpKind.INSERT],
            n_deletes=self._counts[OpKind.DELETE],
            n_queries=self._counts[OpKind.QUERY],
            n_ranges=self._counts[OpKind.RANGE],
            shards=len(per) if per else self.n_target,
            shard_debt=list(debts),
            bloom_probes=self._retired[4] + sum(s.bloom_probes for s in per),
            bloom_negative_skips=(self._retired[5]
                                  + sum(s.bloom_negative_skips for s in per)),
            bloom_false_positives=(self._retired[6]
                                   + sum(s.bloom_false_positives
                                         for s in per)),
            # units/wall sum across shards (retired predecessors folded in,
            # keeping the aggregate monotone across rebalances); percentiles
            # take the per-shard max (units run shard-local — a conservative
            # ensemble tail).
            maintain_units=self._retired[7] + sum(s.maintain_units
                                                  for s in per),
            maintain_wall_s=self._retired[8] + sum(s.maintain_wall_s
                                                   for s in per),
            maintain_unit_p50_s=max((s.maintain_unit_p50_s for s in per),
                                    default=0.0),
            maintain_unit_p99_s=max((s.maintain_unit_p99_s for s in per),
                                    default=0.0),
            maintain_unit_p100_s=max((s.maintain_unit_p100_s for s in per),
                                     default=0.0),
            device_dispatches=self._retired[9] + sum(s.device_dispatches
                                                     for s in per),
            applied_lsn=self.applied_lsn)
