"""Range-scan scenario: simulated cost vs selectivity (0.01% → 10%).

Not a paper figure — the paper evaluates point queries only (Figs. 8-9) —
but its LSM baselines (Luo & Carey) are judged on range scans as much as
point lookups, so this scenario extends the harness to that workload class.
Expected shape: the bulk B+-tree is the floor (one descent + one sequential
span); the NB-tree pays one extra span per s-tree level the range
intersects; leveling LSM pays one span per *level*, and none of the three
can use Bloom filters.  Every index also cross-checks the others: they must
return identical hit counts for the same ranges (differential correctness
at benchmark scale).
"""
from __future__ import annotations

import numpy as np

from repro.core.engine_api import OpBatch

from .common import (DEVICES, bulk_btree_engine, insert_all,
                     make_bench_engine, workload)

#: keys are drawn uniformly from [1, 2^48) (see common.workload).
KEYSPACE = 1 << 48
SELECTIVITIES = (1e-4, 1e-3, 1e-2, 1e-1)
INDICES = ("nbtree", "lsm", "blsm")


def run(sizes=(40_000,), n_q: int = 16, seed: int = 2):
    rows = []
    for dev_name, dev in DEVICES.items():
        for n in sizes:
            keys = workload(n)
            sigma = max(1024, n // 64)
            built = []
            for name in INDICES:
                eng = make_bench_engine(name, dev, sigma)
                insert_all(eng, keys)
                eng.drain()
                built.append((name, eng))
            built.append(("btree-bulk", bulk_btree_engine(keys, dev, sigma)))
            rng = np.random.default_rng(seed)
            for s in SELECTIVITIES:
                span = max(1, int(KEYSPACE * s))
                los = rng.integers(1, KEYSPACE - span, n_q).astype(np.uint64)
                his = (los + np.uint64(span)).astype(np.uint64)
                for name, eng in built:
                    res = eng.apply(OpBatch.ranges(los, his))
                    hits = sum(len(rk) for rk, _ in res.range_hits)
                    rows.append(dict(fig="range", device=dev_name, n=n,
                                     index=name, selectivity=s,
                                     avg_range_ms=float(res.latency_s.mean()) * 1e3,
                                     avg_hits=hits / n_q))
    return rows


def check(rows) -> list[str]:
    out = []
    big = max(r["n"] for r in rows)
    for dev in DEVICES:
        sel_rows = [r for r in rows if r["n"] == big and r["device"] == dev]
        # differential: all indexes must return identical hit counts.
        agree = all(
            len({r["avg_hits"] for r in sel_rows if r["selectivity"] == s}) == 1
            for s in SELECTIVITIES)
        tag = "matches paper" if agree else "MISMATCH"
        out.append(f"range {dev}: all indexes agree on hits across "
                   f"selectivities  [{tag}]")
        top = max(SELECTIVITIES)
        by = {r["index"]: r for r in sel_rows if r["selectivity"] == top}
        nb, bulk, lsm = by["nbtree"], by["btree-bulk"], by["lsm"]
        if nb["avg_range_ms"] < 5.0 * bulk["avg_range_ms"]:
            out.append(f"range {dev}: NB scan within 5x of bulk B+-tree "
                       f"({nb['avg_range_ms']:.2f} vs "
                       f"{bulk['avg_range_ms']:.2f} ms)  [matches paper]")
        else:
            out.append(f"range {dev}: NB scan {nb['avg_range_ms']:.2f}ms vs "
                       f"bulk {bulk['avg_range_ms']:.2f}ms  [MISMATCH]")
        if nb["avg_range_ms"] <= 1.5 * lsm["avg_range_ms"]:
            out.append(f"range {dev}: NB scan <= 1.5x LSM "
                       f"({nb['avg_range_ms']:.2f} vs "
                       f"{lsm['avg_range_ms']:.2f} ms)  [matches paper]")
    return out
