"""Long-horizon stability benchmark: windowed timelines under mixed load.

The paper's figures report *aggregate* tails; this scenario reports
*stability over time* — the dimension Luo & Carey single out for
insertion-intensive stores ("On Performance Stability in LSM-based
Storage Systems") and the one the NB-tree's deamortized cascade is built
to win.  A multi-million-op (aggregate across tiers) insert-heavy stream
is timestamped with a **diurnal + MMPP mix**: a sinusoidal baseline
(day/night swing) with superimposed on/off bursts whose on-rate exceeds
every tier's capacity, so each burst transiently saturates the server.
The same trace — identical arrival instants, identical op content — is
served open-loop through the durable ingest frontend on each tier with
the observability layer on (DESIGN.md §11), yielding per-tier windowed
timelines: ops/s, p50/p99/p99.9, queue/debt gauges, shed counts per
fixed sim-clock window.

Expected shape:

* the **NB-tree tier's stall-free %** (share of active windows whose p99
  stays under ``stall_k`` x the trailing-median p99) **beats the LSM
  tier's** — compaction avalanches turn bursts into multi-window queue
  collapses the deamortized cascade simply doesn't have;
* NB-tree's **fluctuation score** (CV of per-window throughput over the
  windows both tiers could serve) is no worse than LSM's saw-tooth;
* the traced tier's span buffer carries >= 5 distinct categories
  (commit, wal_fsync, cascade, checkpoint, shed) and round-trips as
  valid Chrome trace_event JSON (Perfetto-loadable).

Everything runs on the simulated clock, so rows and timelines are
byte-deterministic for a given seed (the determinism contract
``tests/test_obs.py`` checks).

Standalone CLI (CI bench-smoke; seeds BENCH_stability.json)::

    PYTHONPATH=src python -m benchmarks.fig_stability --quick \
        --out runs/fig_stability.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

from repro.core.cost_model import SSD
from repro.core.engine_api import make_engine
from repro.ingest import (DurabilityConfig, FrontendConfig, make_trace,
                          run_open_loop)
from repro.ingest.arrivals import (ArrivalProcess, DiurnalArrivals,
                                   MMPPArrivals)
from repro.obs import ObsConfig, validate_chrome_trace
from repro.workloads import make_workload
from repro.workloads.driver import SCHEMA_VERSION

KEY_SPACE = 1 << 20

#: SSD-testbed configs.  Unlike fig_saturation (tiny memtable so
#: maintenance fires inside a short window), the LSM tier here gets a
#: *large* memtable — the production-realistic shape: flushes are rare
#: and big, so most windows sit at the group-commit floor and the
#: occasional compaction avalanche stands out against that healthy
#: baseline (exactly what the k x trailing-median detector catches).
#: The interval must span well past the detector's trailing-median
#: history: a memtable that flushes every couple of windows keeps the
#: baseline itself elevated and the relative detector goes blind (the
#: uniformly-congested pathology DESIGN.md §11 calls out).
#: ``run(lsm_mem_pairs=...)`` rescales it so the flush interval spans
#: several metric windows at the smoke run's shorter horizon too.
CONFIGS = {
    "nbtree": dict(f=3, sigma=512, device=SSD),
    "lsm": dict(mem_pairs=262144, device=SSD),
    "btree": dict(device=SSD),
    "bepsilon": dict(node_bytes=1 << 16, cached_levels=1, device=SSD),
}

#: queue bound sized so a burst *sheds* (bounded-queue admission doing
#: its job — and the trace's fifth span category) while the worst
#: queueing delay it can add (~queue/capacity ~ 3-4 ms) stays under the
#: stall threshold for a tier whose service is otherwise smooth.  This
#: is what separates the tiers' failure shapes: with queue delay capped
#: below k x baseline, the only way a window can stall is a *service
#: blockage* (a compaction avalanche or snapshot write) — overload alone
#: sheds instead of stalling.
FRONTEND = FrontendConfig(max_queue=256, commit_ops=64, linger_s=2e-4)

#: diurnal baseline: day/night swing inside the trace duration.  Sized
#: against *durable* capacity (WAL fsync charge included): ~85k ops/s for
#: the nbtree/lsm tiers on this mix, so the baseline swing (16k-64k)
#: stays comfortable and only the MMPP bursts overload the server.
BASE_RATE = 40_000.0
AMPLITUDE = 0.6
PERIOD_S = 4.0
#: MMPP bursts: the on-rate sits just above the NB-tree tier's durable
#: capacity, so a burst fills the bounded queue (sheds — the trace's
#: fifth span category) but drains within ~one window, while the same
#: burst landing on an LSM compaction avalanche collapses for several.
BURST_RATE = 130_000.0
MEAN_ON_S = 0.3
MEAN_OFF_S = 1.2

#: windowed-metrics width (sim seconds) and stall threshold.  The width
#: is the discriminator between the two tiers' failure shapes: NB-tree's
#: worst blockage (one bounded cascade step) fits inside a single
#: window, while an LSM flush+compaction avalanche blocks the server for
#: *multiple* windows — so the window must be shorter than the avalanche
#: but longer than the bounded cascade for the timeline to tell them
#: apart.
WINDOW_S = 0.25
STALL_K = 4.0

#: span ring capacity for this figure: large enough to hold the whole
#: horizon's spans (sheds are coalesced per admission poll), so stall
#: attribution sees every cascade/checkpoint span instead of only the
#: tail of the run.
TRACE_CAPACITY = 1 << 18

#: share of ops arriving via the burst process; chosen so the two
#: component processes span roughly the same sim interval (diurnal mean
#: ~40k ops/s vs MMPP effective mean ~26k ops/s), keeping bursts spread
#: across the whole horizon instead of front-loaded.
BURST_FRAC = 0.4

#: one source of truth for the smoke-sized run (--quick here and in
#: benchmarks/run.py must produce comparable artifacts).
QUICK_KWARGS = dict(tiers=("nbtree", "lsm", "btree"), n_ops=80_000,
                    preload=8192, window_s=0.05,
                    checkpoint_every_commits=1000, lsm_mem_pairs=8192)


class DiurnalMMPPArrivals(ArrivalProcess):
    """Superposition of a diurnal baseline and MMPP bursts.

    The union of two independent point processes is itself a point
    process; drawing a deterministic share of the n arrivals from each
    component and merge-sorting gives the "steady day/night load with
    occasional overload bursts" profile the stability literature uses.
    ``burst_frac`` is the share of ops arriving via the burst process.
    """

    name = "diurnal+mmpp"

    def __init__(self, diurnal: DiurnalArrivals, mmpp: MMPPArrivals, *,
                 burst_frac: float = 0.25):
        assert 0.0 < burst_frac < 1.0
        self.diurnal, self.mmpp = diurnal, mmpp
        self.burst_frac = float(burst_frac)

    def times(self, rng, n):
        n_burst = int(n * self.burst_frac)
        base = self.diurnal.times(rng, n - n_burst)
        burst = self.mmpp.times(rng, n_burst)
        return np.sort(np.concatenate([base, burst]))

    def describe(self):
        return {"process": self.name, "burst_frac": self.burst_frac,
                "diurnal": self.diurnal.describe(),
                "mmpp": self.mmpp.describe()}


def _make_process() -> DiurnalMMPPArrivals:
    return DiurnalMMPPArrivals(
        DiurnalArrivals(BASE_RATE, amplitude=AMPLITUDE, period_s=PERIOD_S),
        MMPPArrivals(BURST_RATE, mean_on_s=MEAN_ON_S, mean_off_s=MEAN_OFF_S),
        burst_frac=BURST_FRAC)


def _row(tier: str, rep: dict) -> dict:
    ol = rep["open_loop"]
    ob = ol["obs"]
    ins = ol["per_kind_e2e"].get("insert", {})
    causes = [s.get("cause", "unknown") for s in ob["stalls"]]
    top_cause = (max(sorted(set(causes)), key=causes.count)
                 if causes else "none")
    return dict(
        fig="stability", index=tier, mix="insert-heavy",
        clock=rep["stats"]["clock"],
        utilization=ol["server"]["utilization"],
        n_done=ol["n_done"], n_shed=ol["n_shed"],
        insert_p999_ms=ins.get("p999_s", 0.0) * 1e3,
        debt_max=ol["stalls"]["debt_max"],
        n_windows=ob["n_windows"], n_active_windows=ob["n_active_windows"],
        stall_free_pct=ob["stall_free_pct"],
        fluctuation_score=ob["fluctuation_score"],
        n_stalled_windows=len(ob["stalled_windows"]),
        top_stall_cause=top_cause,
        trace_events=ob["trace"]["events"],
        n_trace_categories=len(ob["trace"]["categories"]))


def run(tiers=("nbtree", "lsm", "btree"), n_ops: int = 1_200_000,
        preload: int = 16384, window_s: float = WINDOW_S, seed: int = 0,
        checkpoint_every_commits: int = 20_000, trace_out: str | None = None,
        lsm_mem_pairs: int | None = None, detail: bool = False):
    """Drive the shared diurnal+MMPP trace through each tier.

    Returns scalar rows (the benchmarks/run.py contract); ``detail=True``
    returns ``(rows, detail)`` where detail carries the per-tier windowed
    timelines + attributed stalls for the BENCH_stability.json artifact.
    ``trace_out`` saves the *first* tier's span buffer as Chrome
    trace_event JSON.
    """
    wl = make_workload("insert-heavy", key_space=KEY_SPACE, n_ops=n_ops,
                       preload=preload, batch_size=256, seed=seed)
    trace = make_trace(wl, _make_process())
    rows, per_tier = [], {}
    for i, tier in enumerate(tiers):
        cfg = dict(CONFIGS[tier])
        if tier == "lsm" and lsm_mem_pairs:
            cfg["mem_pairs"] = lsm_mem_pairs
        engine = make_engine(tier, **cfg)
        obs = ObsConfig(window_s=window_s, stall_k=STALL_K,
                        trace_capacity=TRACE_CAPACITY,
                        trace_path=(trace_out if i == 0 else None))
        with tempfile.TemporaryDirectory(prefix=f"stability_{tier}_") as d:
            dur = DurabilityConfig(
                directory=d,
                checkpoint_every_commits=checkpoint_every_commits)
            rep = run_open_loop(engine, trace, config=FRONTEND,
                                durability=dur, obs=obs)
        rows.append(_row(tier, rep))
        ob = rep["open_loop"]["obs"]
        per_tier[tier] = {
            "timeline": ob["timeline"],
            "stalls": ob["stalls"],
            "trace": ob["trace"],
            "window_s": ob["window_s"],
            "stall_k": ob["stall_k"],
        }
    if detail:
        return rows, {"arrival": dict(trace.arrival),
                      "trace_n_ops": len(trace),
                      "duration_s": trace.duration_s,
                      "tiers": per_tier}
    return rows


def check(rows) -> list[str]:
    out = []
    by = {r["index"]: r for r in rows}
    nb, lsm = by.get("nbtree"), by.get("lsm")

    # headline: the deamortized tier rides out the same bursts with more
    # stall-free windows than the compaction tier.
    if nb and lsm:
        tag = ("matches paper"
               if nb["stall_free_pct"] > lsm["stall_free_pct"]
               else "MISMATCH")
        out.append(f"stability: NB-tree stall-free {nb['stall_free_pct']:.1f}%"
                   f" > LSM {lsm['stall_free_pct']:.1f}% on the same "
                   f"diurnal+MMPP trace  [{tag}]")
        tag = ("matches paper"
               if nb["fluctuation_score"] <= lsm["fluctuation_score"]
               else "MISMATCH")
        out.append(f"stability: NB-tree throughput fluctuation "
                   f"{nb['fluctuation_score']:.3f} <= LSM "
                   f"{lsm['fluctuation_score']:.3f}  [{tag}]")

    # deamortized bound holds across the whole horizon, bursts included.
    if nb:
        tag = "matches paper" if nb["debt_max"] <= 1 else "MISMATCH"
        out.append(f"stability: NB-tree pending debt <= 1 cascade across "
                   f"the whole horizon (worst {nb['debt_max']})  [{tag}]")

    # the traced tier's span buffer covers the serving pipeline.
    traced = rows[0] if rows else None
    if traced:
        tag = "ok" if traced["n_trace_categories"] >= 5 else "MISMATCH"
        out.append(f"stability: traced tier carries "
                   f"{traced['n_trace_categories']} span categories "
                   f"(>= 5 for commit/wal_fsync/cascade/checkpoint/shed)  "
                   f"[{tag}]")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller run (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="runs/fig_stability_trace.json",
                    help="save the first tier's Chrome trace here "
                         "('' disables)")
    ap.add_argument("--out", default="runs/fig_stability.json")
    args = ap.parse_args(argv)
    kwargs = dict(QUICK_KWARGS) if args.quick else {}
    if args.trace_out:
        os.makedirs(os.path.dirname(args.trace_out) or ".", exist_ok=True)
    rows, detail = run(seed=args.seed, detail=True,
                       trace_out=args.trace_out or None, **kwargs)
    checks = check(rows)
    if args.trace_out:
        errs = validate_chrome_trace(json.load(open(args.trace_out)))
        tag = "ok" if not errs else f"INVALID: {errs[:3]}"
        checks.append(f"stability: saved trace {args.trace_out} is valid "
                      f"Chrome trace_event JSON  [{tag}]")
    for r in rows:
        print(r)
    for c in checks:
        print(" ->", c)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "seed": args.seed,
                   "quick": bool(args.quick), "rows": rows,
                   "detail": detail, "checks": checks}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
