"""Crash-point fault injection for the durability subsystem (DESIGN.md §9).

A :class:`FaultInjector` is armed with one :class:`CrashPoint` and an
occurrence count; durability-aware code calls :meth:`FaultInjector.reach`
at every protocol point, and the injector raises :class:`SimulatedCrash`
when its armed point is reached for the N-th time.  The exception
propagates out of the serving loop exactly like a process kill would end
it: whatever the WAL/checkpoint directory holds at that instant is what
recovery gets.

The one place a raised exception is *weaker* than a kill — bytes written
but not yet fsynced may transparently survive in the page cache — is
handled by the ``on_crash`` hook: the WAL passes a callback that tears the
unsynced tail (truncates the segment mid-record) before the crash fires,
simulating the adversarial outcome a real power loss can produce.  The
recovery invariant under test is therefore the strict one: *acked implies
durable* (fsync returned) and *unacked implies absent after recovery*.

Crash points (the full matrix ``tests/test_durability.py`` kills at):

================================  =============================================
point                             state at the kill
================================  =============================================
``BEFORE_WAL_APPEND``             commit formed, nothing logged — ops unacked,
                                  legitimately lost
``AFTER_WAL_APPEND``              record written, **not fsynced** — tail torn;
                                  recovery must truncate it, never resurrect
``AFTER_WAL_FSYNC``               record durable ⇒ ops **acked**, but not yet
                                  applied to the engine — replay must apply
``AFTER_APPLY``                   acked + applied, before maintenance
``MID_CASCADE``                   between emptying-cascade work units inside
                                  ``maintain`` — index mid-restructure
``MID_CHECKPOINT``                snapshot leaves written, manifest not yet —
                                  the half-checkpoint must be ignored
``BEFORE_CHECKPOINT_RENAME``      manifest fsynced, step dir still ``.tmp`` —
                                  recovery rolls the provable step forward
``AFTER_CHECKPOINT``              checkpoint complete, WAL tail not yet
                                  truncated — replay must skip ≤-snapshot LSNs
================================  =============================================
"""
from __future__ import annotations

import enum


class CrashPoint(enum.Enum):
    BEFORE_WAL_APPEND = "before-wal-append"
    AFTER_WAL_APPEND = "after-wal-append"          # written, not fsynced
    AFTER_WAL_FSYNC = "after-wal-fsync"            # durable == acked
    AFTER_APPLY = "after-apply"
    MID_CASCADE = "mid-cascade"
    MID_CHECKPOINT = "mid-checkpoint"              # leaves written, no manifest
    BEFORE_CHECKPOINT_RENAME = "before-checkpoint-rename"
    AFTER_CHECKPOINT = "after-checkpoint"          # before WAL truncation


class SimulatedCrash(RuntimeError):
    """The injected kill: propagates out of the serving loop like SIGKILL."""

    def __init__(self, point: CrashPoint, occurrence: int):
        super().__init__(f"simulated crash at {point.value} "
                         f"(occurrence {occurrence})")
        self.point = point
        self.occurrence = occurrence


class FaultInjector:
    """Raise :class:`SimulatedCrash` the ``at_occurrence``-th time
    ``point`` is reached.

    One injector arms one point; ``fired`` records whether the crash
    actually happened (a test that armed a point the run never reaches can
    tell the difference between "survived" and "never exercised").
    """

    def __init__(self, point: CrashPoint, at_occurrence: int = 1):
        assert at_occurrence >= 1
        self.point = point
        self.at_occurrence = int(at_occurrence)
        self.seen = 0
        self.fired = False

    def reach(self, point: CrashPoint, on_crash=None) -> None:
        """Announce that ``point`` was reached.

        ``on_crash`` (optional callable) runs just before the crash is
        raised — the hook the WAL uses to tear its unsynced tail.
        """
        if point is not self.point:
            return
        self.seen += 1
        if self.seen == self.at_occurrence:
            self.fired = True
            if on_crash is not None:
                on_crash()
            raise SimulatedCrash(point, self.seen)


def reach(injector: FaultInjector | None, point: CrashPoint,
          on_crash=None) -> None:
    """``injector.reach`` that tolerates ``injector=None`` (production)."""
    if injector is not None:
        injector.reach(point, on_crash)
