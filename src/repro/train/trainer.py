"""Training loop: data pipeline -> train step -> checkpoint/restart/FT hooks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint.checkpointer import Checkpointer
from ..distributed.fault_tolerance import HeartbeatMonitor, StragglerDetector
from ..distributed.sharding import param_specs
from ..launch.mesh import mesh_context
from ..models import transformer as T
from ..optim import adamw
from .train_step import make_train_step


class Trainer:
    def __init__(self, cfg, *, mesh=None, opt_cfg=None, ckpt_dir=None,
                 num_microbatches: int = 1, seed: int = 0,
                 grad_compression: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.opt_cfg = opt_cfg or adamw.AdamWConfig()
        self.ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
        self.step_fn = make_train_step(cfg, self.opt_cfg,
                                       num_microbatches=num_microbatches,
                                       grad_compression=grad_compression,
                                       mesh=mesh)
        self.params = T.init_params(jax.random.PRNGKey(seed), cfg)
        self.opt_state = adamw.init(self.params)
        self.step = 0
        self.monitor = HeartbeatMonitor([0])
        self.straggler = StragglerDetector([0])

        if mesh is not None:
            pspecs = param_specs(self.params, mesh)
            psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
            self.params = jax.device_put(self.params, psh)
            osh = {"m": psh, "v": psh,
                   "count": NamedSharding(mesh, P())}
            self.opt_state = jax.device_put(self.opt_state, osh)
            self._jit = jax.jit(self.step_fn, donate_argnums=(0, 1))
        else:
            self._jit = jax.jit(self.step_fn, donate_argnums=(0, 1))

        if self.ckpt is not None:
            last = self.ckpt.latest_step()
            if last is not None:
                self.restore(last)

    # ------------------------------------------------------------------ loop
    def run(self, batches, num_steps: int, *, ckpt_every: int = 0,
            log_every: int = 10) -> list[dict]:
        history = []
        it = iter(batches)
        ctx = mesh_context(self.mesh) if self.mesh is not None else None
        if ctx is not None:
            ctx.__enter__()
        try:
            for _ in range(num_steps):
                batch = next(it)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.time()
                self.params, self.opt_state, metrics = self._jit(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                self.step += 1
                self.straggler.record(0, dt)
                self.monitor.beat(0, self.step)
                history.append({"step": self.step, "loss": loss,
                                "sec": dt,
                                "grad_norm": float(metrics["grad_norm"])})
                if log_every and self.step % log_every == 0:
                    print(f"step {self.step}: loss={loss:.4f} "
                          f"({dt:.2f}s/step)", flush=True)
                if ckpt_every and self.ckpt and self.step % ckpt_every == 0:
                    self.save()
        finally:
            if ctx is not None:
                ctx.__exit__(None, None, None)
        return history

    # ------------------------------------------------------------ checkpoint
    def save(self, blocking: bool = True) -> None:
        assert self.ckpt is not None
        state = {"params": self.params,
                 "opt": {k: self.opt_state[k] for k in ("m", "v", "count")}}
        self.ckpt.save(self.step, state, blocking=blocking)

    def restore(self, step: int) -> None:
        like = {"params": self.params,
                "opt": {k: self.opt_state[k] for k in ("m", "v", "count")}}
        state = self.ckpt.restore(step, like)
        self.params = state["params"]
        self.opt_state.update(state["opt"])
        self.step = step
        print(f"restored checkpoint @ step {step}", flush=True)
