"""Shared splitmix64 finalizer (vectorized, uint64 wraparound arithmetic).

One canonical copy: workload key scattering (``repro.workloads.generator``)
and hash-partition shard placement (``repro.shard.partition``) both depend
on this exact bit pattern — two drifting copies would silently decouple
shard routing from the key-distribution assumptions the workloads encode.
"""
from __future__ import annotations

import numpy as np


def splitmix64(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x).astype(np.uint64)
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))
