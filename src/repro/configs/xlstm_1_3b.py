"""xLSTM 1.3B [arXiv:2405.04517; unverified].

48 blocks, d_model 2048, 4 heads, d_ff 0 (blocks are self-contained),
mLSTM:sLSTM at the paper's 7:1 ratio -> segments of (7 mLSTM, 1 sLSTM) x 6.
Recurrent state decode -> long_500k runs with O(1) state.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    segments=(("mlstm", 7), ("slstm", 1)) * 6,
)
