"""Qwen2-VL 2B [arXiv:2409.12191; hf] — transformer BACKBONE only.

28L, d_model 1536, 12 heads GQA kv 2, d_ff 8960, M-RoPE with (t, h, w)
sections (16, 24, 24) over the 64 rotary pairs of head_dim 128.  The
vision patch frontend is a STUB: input_specs provide patch embeddings.
Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128,
    segments=(("dense", 28),),
    mrope_sections=(16, 24, 24), mlp_kind="swiglu",
    tie_embeddings=True, rope_base=1000000.0,
)
