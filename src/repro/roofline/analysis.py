"""Three-term roofline from compiled dry-run artifacts (no TPU required).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / link_bw   (ICI vs DCN per group span)

FLOPs/bytes come from ``compiled.cost_analysis()`` (the *per-device*
partitioned module).  Collective payloads are not in cost_analysis, so we
parse the HLO: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op's tensor shapes, converted to per-device wire bytes
with ring-algorithm factors:

  all-reduce      2 * s * (g-1)/g      (reduce-scatter + all-gather phases)
  all-gather          r * (g-1)/g      (r = result bytes)
  reduce-scatter      s * (g-1)/g      (s = operand bytes)
  all-to-all          s * (g-1)/g
  collective-permute  s

Groups whose device ids span a pod boundary (stride >= 256 in our meshes)
are charged to DCN instead of ICI.
"""
from __future__ import annotations

import dataclasses
import re

from . import hardware as hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?([a-z0-9\[\],]+\s+)?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_info(line: str, pod_stride: int = 256):
    """(group_size, crosses_pod).  Defaults to (1, False) if unparseable."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, g = int(m.group(1)), int(m.group(2))
        # iota groups [n,g]<=[N]: consecutive ids; crosses pod iff a group
        # spans ids differing by >= pod_stride.
        return g, g > pod_stride
    m = _GROUPS_RE.search(line)
    if not m:
        return 1, False
    first = m.group(1).split("}")[0].strip("{} ")
    ids = [int(x) for x in first.split(",") if x.strip()]
    if not ids:
        return 1, False
    crosses = (max(ids) - min(ids)) >= pod_stride
    return len(ids), crosses


def collective_wire_bytes(hlo_text: str, pod_stride: int = 256) -> dict:
    """Per-device wire bytes, split by fabric and op kind."""
    out = {"ici": 0.0, "dcn": 0.0, "by_kind": {}}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        if "-done(" in line:
            continue  # -start carries the shapes; -done would double count
        kind = m.group(2)
        g, crosses = _group_info(line, pod_stride)
        if g <= 1:
            continue
        lhs, _, rhs = line.partition("=")
        result_b = _shape_bytes(rhs.split("(")[0]) or _shape_bytes(lhs)
        operand_b = _shape_bytes(rhs.split("(", 1)[1]) if "(" in rhs else 0
        frac = (g - 1) / g
        if kind == "all-reduce":
            wire = 2 * operand_b * frac
        elif kind == "all-gather":
            wire = result_b * frac
        elif kind == "collective-permute":
            wire = operand_b
        else:  # reduce-scatter, all-to-all
            wire = operand_b * frac
        fabric = "dcn" if crosses else "ici"
        out[fabric] += wire
        k = out["by_kind"].setdefault(kind, {"count": 0, "bytes": 0.0})
        k["count"] += 1
        k["bytes"] += wire
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    ici_bytes_per_dev: float
    dcn_bytes_per_dev: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_total: float
    useful_flops_ratio: float
    peak_mem_bytes: int
    by_kind: dict

    def as_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg, tokens: int, kind: str) -> float:
    """6*N*D (training) / 2*N*D (inference fwd) with N = *active* params."""
    n_active = active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg) -> float:
    """Parameter count actually touched per token (MoE: top-k + shared)."""
    from ..models import registry  # lazy; avoids cycles
    import jax
    import numpy as np
    from ..models import transformer as T
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for kp, leaf in flat:
        path = ".".join(str(getattr(k, "key", k)) for k in kp)
        n = float(np.prod(leaf.shape))
        if ".moe.w" in path and ".shared." not in path:
            n *= cfg.top_k / max(1, cfg.n_experts)   # routed experts
        total += n
    return total


def analyze_from(*, flops: float, hbm_bytes: float, ici_bytes: float,
                 dcn_bytes: float, peak_mem: int, n_devices: int,
                 model_flops_total: float, by_kind: dict) -> Roofline:
    """Roofline from (possibly trip-count-corrected) per-device totals."""
    t_c = flops / hw.PEAK_FLOPS_BF16
    t_m = hbm_bytes / hw.HBM_BW
    t_x = ici_bytes / hw.ICI_BW_PER_LINK + dcn_bytes / hw.DCN_BW_PER_HOST
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_total / max(1.0, flops * n_devices)
    return Roofline(flops, hbm_bytes, ici_bytes, dcn_bytes,
                    t_c, t_m, t_x, bottleneck, model_flops_total, useful,
                    peak_mem, by_kind)


def measured_kernel_table(dispatch_stats: dict, *,
                          peak_bw: float = hw.HBM_BW) -> list:
    """Measured per-kernel achieved bandwidth from tracer dispatch stats.

    ``dispatch_stats`` is ``NBTreeIndex.dispatch_stats`` — populated when a
    :class:`repro.obs.trace.Tracer` is attached to the device engine —
    mapping kernel name to ``{count, wall_s, bytes}`` where ``bytes`` is
    the argument+result footprint moved per dispatch (a lower bound on
    HBM traffic: internal scratch isn't counted).  Each returned row adds
    the achieved GB/s and its fraction of ``peak_bw``, sorted by total
    wall time — the empirical counterpart of the analytic ``t_memory``
    term, so the dry-run roofline and a real run are directly comparable
    per kernel.
    """
    rows = []
    for name, st in dispatch_stats.items():
        wall = float(st.get("wall_s", 0.0))
        nbytes = float(st.get("bytes", 0.0))
        bw = nbytes / wall if wall > 0 else 0.0
        rows.append({
            "kernel": name,
            "count": int(st.get("count", 0)),
            "wall_s": wall,
            "bytes": int(nbytes),
            "achieved_gb_s": bw / 1e9,
            "peak_frac": bw / peak_bw if peak_bw > 0 else 0.0,
        })
    rows.sort(key=lambda r: r["wall_s"], reverse=True)
    return rows


def analyze(compiled, *, n_devices: int, model_flops_total: float,
            pod_stride: int = 256) -> Roofline:
    """Single-artifact roofline (no scan correction — see dryrun for that)."""
    ca = compiled.cost_analysis()
    wires = collective_wire_bytes(compiled.as_text(), pod_stride)
    mem = compiled.memory_analysis()
    peak = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes + mem.generated_code_size_in_bytes)
    return analyze_from(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        ici_bytes=wires["ici"], dcn_bytes=wires["dcn"], peak_mem=peak,
        n_devices=n_devices, model_flops_total=model_flops_total,
        by_kind=wires["by_kind"])
