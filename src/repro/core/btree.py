"""B+-tree baselines (paper Sec. 6.1, algorithms (5)/(6)).

Two variants, matching the paper's experimental setup:

* ``BPlusTreeBulk`` — bottom-up bulk-loaded, all nodes full; the paper's
  query-performance yardstick (``B+-tree(bulk)``).  Internal levels are
  cached in memory, so a point query costs one seek + one leaf page —
  the optimal disk query the paper says NB-trees approach.
* ``BPlusTree`` — incremental inserts; every insert seeks, reads and
  rewrites a leaf page.  This is the variant the paper *excludes* from the
  large experiments because its average insertion time exceeds 100 us; the
  benchmark harness reproduces that exclusion rule.
"""
from __future__ import annotations

import numpy as np

from .cost_model import PAIR_BYTES, CostModel, Device, HDD
from .sorted_run import KEY_DTYPE, TOMBSTONE, VAL_DTYPE


class BPlusTreeBulk:
    """Bulk-loaded B+-tree over a static sorted array.

    The sorted leaf file is the array; internal nodes are implicit (cached
    in memory).  Point query = 1 seek + 1 page.
    """

    def __init__(self, keys, vals, *, device: Device = HDD, cost: CostModel | None = None):
        order = np.argsort(keys)
        self.keys = np.asarray(keys, KEY_DTYPE)[order]
        self.vals = np.asarray(vals, VAL_DTYPE)[order]
        self.cm = cost or CostModel(device)
        # bulk-load cost: one sequential write of the whole file.
        self.cm.seek()
        self.cm.write_pairs(len(self.keys))

    def get(self, key):
        key = np.uint64(key)
        with self.cm.measure() as t:
            self.cm.page_read()
            i = int(np.searchsorted(self.keys, key))
            found = i < len(self.keys) and self.keys[i] == key
        self._last_query_time = t.seconds
        return self.vals[i] if found else None

    def query(self, key):
        v = self.get(key)
        return v, self._last_query_time

    def range_query(self, lo, hi):
        """Inclusive range scan [lo, hi]: one descent + one sequential leaf
        scan of the matching span — the optimal disk range query every other
        index is measured against.  Returns (keys, vals) numpy arrays."""
        lo, hi = np.uint64(lo), np.uint64(hi)
        with self.cm.measure() as t:
            i0 = int(np.searchsorted(self.keys, lo, side="left"))
            i1 = int(np.searchsorted(self.keys, hi, side="right"))
            self.cm.page_read()                  # locate the first leaf
            if i1 > i0:
                self.cm.read_pairs(i1 - i0)      # sequential span scan
            out = self.keys[i0:i1].copy(), self.vals[i0:i1].copy()
        self._last_query_time = t.seconds
        return out

    def drain(self) -> None:  # API parity with the dynamic engines
        pass

    def total_pairs(self) -> int:
        return len(self.keys)


class BPlusTree:
    """Incremental B+-tree: per-insert leaf read-modify-write.

    Leaf granularity is one page.  Internal levels cached in memory (their
    updates are free); each insert pays seek + page read + page write, each
    query seek + page read.
    """

    def __init__(self, *, device: Device = HDD, cost: CostModel | None = None):
        self.cm = cost or CostModel(device)
        self._store: dict = {}
        self.n_inserted = 0

    def insert(self, key, value) -> float:
        with self.cm.measure() as t:
            self.cm.page_read()                       # fetch the target leaf
            self.cm.seek()
            self.cm.seq_write(self.cm.device.page_bytes)  # rewrite it
            self._store[np.uint64(key)] = np.int64(value)
            self.n_inserted += 1
        return t.seconds

    def delete(self, key) -> float:
        return self.insert(key, TOMBSTONE)

    def get(self, key):
        key = np.uint64(key)
        with self.cm.measure() as t:
            self.cm.page_read()
            v = self._store.get(key)
        self._last_query_time = t.seconds
        return None if v is None or v == TOMBSTONE else v

    def query(self, key):
        v = self.get(key)
        return v, self._last_query_time

    def range_query(self, lo, hi):
        """Inclusive range scan [lo, hi]: descent + sequential leaf-chain
        scan (leaves are sibling-linked).  Returns (keys, vals) arrays."""
        lo, hi = np.uint64(lo), np.uint64(hi)
        with self.cm.measure() as t:
            ks = sorted(int(k) for k, v in self._store.items()
                        if lo <= k <= hi and v != TOMBSTONE)
            self.cm.page_read()                  # locate the first leaf
            if ks:
                self.cm.read_pairs(len(ks))      # sequential leaf-chain scan
            out = (np.asarray(ks, KEY_DTYPE),
                   np.asarray([int(self._store[np.uint64(k)]) for k in ks],
                              VAL_DTYPE))
        self._last_query_time = t.seconds
        return out

    def drain(self) -> None:
        pass

    def total_pairs(self) -> int:
        return len(self._store)
