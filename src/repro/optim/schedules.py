"""LR schedules (cosine with linear warmup — the LM-pretraining standard)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       final_frac: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        t = jnp.clip((step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
