"""Serving-engine bench: decode-step tail with bounded vs eager index upkeep.

The paper's no-stall property at the engine level, driven through the
unified ``StorageEngine`` protocol: with ``maintain(1)`` the per-step index
work is bounded by ONE flush/split unit, so the worst step pays one unit;
the *eager* policy (drain the whole cascade the moment the root fills — the
LSM-compaction analogue) pays the full multi-level cascade in one step.
The p100 gap is the deamortization win and grows with tree depth (log n);
at bench scale the cascade is 2-4 units deep.

Per-unit wall-clock here is inflated by interpret-mode Pallas merges (the
kernel is the TPU target); the *ratio* between policies is the signal.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.engine_api import OpBatch, make_engine


def run(n_steps: int = 110, batch: int = 64, warmup: int = 140):
    # warmup must cover the first leaf split (~step 65 at these parameters)
    # and the first internal split (~step 130) so one-time jit compiles of
    # the structural paths don't pollute the steady-state tail.
    rng = np.random.default_rng(0)
    rows = []
    range_eng = None
    for mode in ("deamortized", "eager"):
        eng = make_engine("jax-nbtree", f=4, sigma=2048, max_nodes=512)
        key_src = iter(rng.choice(np.arange(1, 2**31, dtype=np.uint32),
                                  (n_steps + warmup) * batch * 2, replace=False))
        times = []
        for s in range(n_steps + warmup):
            ks = np.fromiter(key_src, np.uint32, batch)
            step = OpBatch.concat([
                OpBatch.inserts(ks, np.arange(batch, dtype=np.int64)),
                OpBatch.queries(ks[:16])])
            t0 = time.perf_counter()
            eng.apply(step)
            if mode == "deamortized":
                eng.maintain(1)          # bounded: <= 1 unit per step
            else:
                eng.drain()              # eager: full cascade stall
            if s >= warmup:
                times.append(time.perf_counter() - t0)
        times = np.asarray(times) * 1e3
        rows.append(dict(name=f"engine_{mode}",
                         p50_ms=float(np.percentile(times, 50)),
                         p99_ms=float(np.percentile(times, 99)),
                         p100_ms=float(times.max())))
        if mode == "deamortized":
            range_eng = eng

    # ---- range scans on the loaded engine (selectivity sweep) --------------
    # keys above were drawn uniformly from [1, 2^31); a span of s * 2^31
    # therefore matches ~s of the live pairs.
    range_eng.drain()
    for s in (0.001, 0.01):
        span = int((2**31) * s)
        lo = rng.integers(1, 2**31 - span, 32).astype(np.uint64)
        hi = lo + np.uint64(span)
        scan = OpBatch.ranges(lo, hi)
        range_eng.apply(scan)                          # compile/warm
        times = []
        for _ in range(7):
            t0 = time.perf_counter()
            range_eng.apply(scan)
            times.append(time.perf_counter() - t0)
        times = np.asarray(times) * 1e3
        rows.append(dict(name=f"engine_range_b32_sel{s:g}",
                         p50_ms=float(np.percentile(times, 50)),
                         p99_ms=float(np.percentile(times, 99)),
                         p100_ms=float(times.max())))
    return rows


def check(rows):
    de = next(r for r in rows if "deamortized" in r["name"])
    ea = next(r for r in rows if "eager" in r["name"])
    tag = "matches paper" if de["p100_ms"] < ea["p100_ms"] else "MISMATCH"
    out = [f"engine: bounded-budget worst step {de['p100_ms']:.0f}ms vs eager "
           f"cascade {ea['p100_ms']:.0f}ms  [{tag}]"]
    for r in rows:
        if "range" in r["name"]:
            out.append(f"engine: {r['name']} p50={r['p50_ms']:.1f}ms "
                       f"(batched fused descent)")
    return out
