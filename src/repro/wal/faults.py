"""Fault injection for the durability and replication subsystems.

Two harnesses live here:

* **Crash points** (DESIGN.md §9) — a :class:`FaultInjector` armed with one
  :class:`CrashPoint` and an occurrence count kills a *single-process*
  serving loop at an exact protocol point (the crash-matrix tests).
* **Chaos schedule** (DESIGN.md §12) — a :class:`FaultSchedule` is a seeded
  timeline of :class:`ChaosEvent`\\ s (crash, fsync stall, latency spike,
  torn segment, bit-flip corruption) fired *by sim time* against named
  components (a replica node, a shard group, the frontend's own WAL).
  Components register a handler; the serving loop polls
  :meth:`FaultSchedule.fire_due` at commit boundaries, so a whole run under
  chaos stays a pure function of (trace, config, schedule seed).

A :class:`FaultInjector` is armed with one :class:`CrashPoint` and an
occurrence count; durability-aware code calls :meth:`FaultInjector.reach`
at every protocol point, and the injector raises :class:`SimulatedCrash`
when its armed point is reached for the N-th time.  The exception
propagates out of the serving loop exactly like a process kill would end
it: whatever the WAL/checkpoint directory holds at that instant is what
recovery gets.

The one place a raised exception is *weaker* than a kill — bytes written
but not yet fsynced may transparently survive in the page cache — is
handled by the ``on_crash`` hook: the WAL passes a callback that tears the
unsynced tail (truncates the segment mid-record) before the crash fires,
simulating the adversarial outcome a real power loss can produce.  The
recovery invariant under test is therefore the strict one: *acked implies
durable* (fsync returned) and *unacked implies absent after recovery*.

Crash points (the full matrix ``tests/test_durability.py`` kills at):

================================  =============================================
point                             state at the kill
================================  =============================================
``BEFORE_WAL_APPEND``             commit formed, nothing logged — ops unacked,
                                  legitimately lost
``AFTER_WAL_APPEND``              record written, **not fsynced** — tail torn;
                                  recovery must truncate it, never resurrect
``AFTER_WAL_FSYNC``               record durable ⇒ ops **acked**, but not yet
                                  applied to the engine — replay must apply
``AFTER_APPLY``                   acked + applied, before maintenance
``MID_CASCADE``                   between emptying-cascade work units inside
                                  ``maintain`` — index mid-restructure
``MID_CHECKPOINT``                snapshot leaves written, manifest not yet —
                                  the half-checkpoint must be ignored
``BEFORE_CHECKPOINT_RENAME``      manifest fsynced, step dir still ``.tmp`` —
                                  recovery rolls the provable step forward
``AFTER_CHECKPOINT``              checkpoint complete, WAL tail not yet
                                  truncated — replay must skip ≤-snapshot LSNs
================================  =============================================
"""
from __future__ import annotations

import dataclasses
import enum
import os

import numpy as np


class CrashPoint(enum.Enum):
    BEFORE_WAL_APPEND = "before-wal-append"
    AFTER_WAL_APPEND = "after-wal-append"          # written, not fsynced
    AFTER_WAL_FSYNC = "after-wal-fsync"            # durable == acked
    AFTER_APPLY = "after-apply"
    MID_CASCADE = "mid-cascade"
    MID_CHECKPOINT = "mid-checkpoint"              # leaves written, no manifest
    BEFORE_CHECKPOINT_RENAME = "before-checkpoint-rename"
    AFTER_CHECKPOINT = "after-checkpoint"          # before WAL truncation


class SimulatedCrash(RuntimeError):
    """The injected kill: propagates out of the serving loop like SIGKILL."""

    def __init__(self, point: CrashPoint, occurrence: int):
        super().__init__(f"simulated crash at {point.value} "
                         f"(occurrence {occurrence})")
        self.point = point
        self.occurrence = occurrence


class FaultInjector:
    """Raise :class:`SimulatedCrash` the ``at_occurrence``-th time
    ``point`` is reached.

    One injector arms one point; ``fired`` records whether the crash
    actually happened (a test that armed a point the run never reaches can
    tell the difference between "survived" and "never exercised").
    """

    def __init__(self, point: CrashPoint, at_occurrence: int = 1):
        assert at_occurrence >= 1
        self.point = point
        self.at_occurrence = int(at_occurrence)
        self.seen = 0
        self.fired = False

    def reach(self, point: CrashPoint, on_crash=None) -> None:
        """Announce that ``point`` was reached.

        ``on_crash`` (optional callable) runs just before the crash is
        raised — the hook the WAL uses to tear its unsynced tail.
        """
        if point is not self.point:
            return
        self.seen += 1
        if self.seen == self.at_occurrence:
            self.fired = True
            if on_crash is not None:
                on_crash()
            raise SimulatedCrash(point, self.seen)


def reach(injector: FaultInjector | None, point: CrashPoint,
          on_crash=None) -> None:
    """``injector.reach`` that tolerates ``injector=None`` (production)."""
    if injector is not None:
        injector.reach(point, on_crash)


# ============================================================ chaos schedule
class ChaosKind(enum.Enum):
    """Event vocabulary of the seeded chaos harness (DESIGN.md §12)."""

    CRASH = "crash"                  # component dies (node loss, WAL gone)
    FSYNC_STALL = "fsync_stall"      # next fsyncs pay +seconds (arg)
    LATENCY_SPIKE = "latency_spike"  # service multiplied by arg for a window
    TORN_SEGMENT = "torn_segment"    # WAL tail physically torn mid-record
    BIT_FLIP = "bit_flip"            # one byte flipped in the newest segment


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault: fire ``kind`` at sim time ``t`` on ``target``.

    ``target`` names a registered component (e.g. ``"g0/n1"`` for group 0's
    node 1, ``"wal"`` for the single-engine frontend's log); ``arg`` is the
    kind-specific magnitude: stall seconds for ``FSYNC_STALL``, the service
    multiplier for ``LATENCY_SPIKE`` (its window is ``dur_s``), unused
    otherwise.
    """

    t: float
    kind: ChaosKind
    target: str
    arg: float = 0.0
    dur_s: float = 0.0

    def describe(self) -> dict:
        return {"t": self.t, "kind": self.kind.value, "target": self.target,
                "arg": self.arg, "dur_s": self.dur_s}


class FaultSchedule:
    """Seeded, time-ordered chaos timeline with a component registry.

    Components register a handler (``schedule.register(name, fn)``); the
    serving loop calls :meth:`fire_due` at every commit boundary and each
    due event is dispatched to its target's handler exactly once, in time
    order.  Events whose target was never registered are counted
    (``unrouted``) rather than lost silently — a misspelled ``--chaos``
    target should be visible in the report, not a silent no-op.

    Construction: :meth:`parse` for the driver's ``--chaos`` spec DSL,
    :meth:`random` for seeded soak schedules, or pass events directly.
    """

    def __init__(self, events=()):
        self.events = sorted(events, key=lambda e: (e.t, e.target,
                                                    e.kind.value))
        self._next = 0
        self._handlers: dict = {}
        self.fired: list[ChaosEvent] = []
        self.unrouted: list[ChaosEvent] = []

    # ------------------------------------------------------------- building
    @staticmethod
    def parse(spec: str) -> "FaultSchedule":
        """Parse the driver's ``--chaos`` DSL.

        Spec = ``;``-separated events, each ``kind@t[:target[:arg[:dur]]]``
        (target defaults to ``"wal"``, the single-engine frontend's log)::

            crash@0.5:g0/n0
            fsync_stall@1.0:g1/n1:0.02
            latency_spike@2.0:g0:8:0.5
            torn_segment@1.5:g2/n1;bit_flip@1.7:g2/n2

        plus one optional ``random:<n>@<seed>[:t_lo,t_hi]`` element that
        appends a seeded random schedule over the registered targets at
        fire time is **not** supported here — use :meth:`random` (the soak
        tests) for generated schedules; the DSL stays explicit.
        """
        events = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            head, _, rest = part.partition("@")
            kind = ChaosKind(head.strip())
            fields = rest.split(":")
            if not fields or not fields[0]:
                raise ValueError(f"chaos event {part!r} needs a time: "
                                 "kind@t[:target[:arg[:dur]]]")
            t = float(fields[0])
            target = fields[1] if len(fields) > 1 and fields[1] else "wal"
            arg = float(fields[2]) if len(fields) > 2 else 0.0
            dur = float(fields[3]) if len(fields) > 3 else 0.0
            events.append(ChaosEvent(t, kind, target, arg, dur))
        return FaultSchedule(events)

    @staticmethod
    def random(n: int, *, seed: int, t_lo: float, t_hi: float,
               targets, kinds=tuple(ChaosKind),
               stall_s: float = 0.01, spike: float = 8.0,
               spike_dur_s: float = 0.05,
               min_gap_s: float = 0.0) -> "FaultSchedule":
        """Seeded random schedule over ``targets`` (soak harness).

        ``min_gap_s`` spaces *destructive* events (CRASH / TORN_SEGMENT /
        BIT_FLIP) on the same **group** — the prefix of the target name up
        to ``/`` — so a group always gets time to detect, promote, and
        rebuild before it is hit again; without the gap a random schedule
        can destroy every copy of an acked write at once, which no
        replication factor survives (the soak test's invariant would then
        be unsatisfiable, not violated).
        """
        rng = np.random.default_rng(seed)
        targets = list(targets)
        destructive = {ChaosKind.CRASH, ChaosKind.TORN_SEGMENT,
                       ChaosKind.BIT_FLIP}
        last_hit: dict = {}
        events = []
        times = np.sort(rng.uniform(t_lo, t_hi, size=n))
        for t in times:
            kind = kinds[int(rng.integers(len(kinds)))]
            target = targets[int(rng.integers(len(targets)))]
            group = target.split("/")[0]
            if kind in destructive:
                if t - last_hit.get(group, -np.inf) < min_gap_s:
                    kind = ChaosKind.FSYNC_STALL   # demote to a benign fault
                else:
                    last_hit[group] = float(t)
            arg = {ChaosKind.FSYNC_STALL: stall_s,
                   ChaosKind.LATENCY_SPIKE: spike}.get(kind, 0.0)
            dur = spike_dur_s if kind is ChaosKind.LATENCY_SPIKE else 0.0
            events.append(ChaosEvent(float(t), kind, target, arg, dur))
        return FaultSchedule(events)

    # ------------------------------------------------------------ dispatch
    def register(self, target: str, handler) -> None:
        """Route events for ``target`` to ``handler(event)``.  Re-register
        freely (a respawned node reuses its group's target names)."""
        self._handlers[target] = handler

    def unregister(self, target: str) -> None:
        self._handlers.pop(target, None)

    def fire_due(self, now: float) -> list[ChaosEvent]:
        """Dispatch every event with ``t <= now`` not yet fired, in order.

        Returns the events dispatched this call (routed or not), so the
        caller can trace them.
        """
        out = []
        while self._next < len(self.events) and \
                self.events[self._next].t <= now:
            ev = self.events[self._next]
            self._next += 1
            handler = self._handlers.get(ev.target)
            if handler is None:
                self.unrouted.append(ev)
            else:
                handler(ev)
                self.fired.append(ev)
            out.append(ev)
        return out

    @property
    def pending(self) -> int:
        return len(self.events) - self._next

    @property
    def next_time(self) -> float | None:
        """Fire time of the earliest undispatched event (clock-jump hint
        for sim serving loops), or None when the schedule is drained."""
        return self.events[self._next].t if self._next < len(self.events) \
            else None

    def describe(self) -> dict:
        """JSON-ready summary for reports."""
        return {
            "n_events": len(self.events),
            "fired": [e.describe() for e in self.fired],
            "unrouted": [e.describe() for e in self.unrouted],
            "pending": self.pending,
        }


def tear_wal_tail(wal_dir: str, *, frac: float = 0.5) -> int:
    """Physically tear the newest WAL segment mid-record (TORN_SEGMENT).

    Truncates the last ``1 - frac`` of the newest non-empty segment file —
    an adversarial partial write.  Returns bytes removed (0 when there is
    nothing to tear).  The next :class:`~repro.wal.log.WriteAheadLog` open
    (or re-scan) sees a torn record and truncates back to the last valid
    prefix.
    """
    segs = sorted(n for n in os.listdir(wal_dir)
                  if n.startswith("wal_") and n.endswith(".log"))
    for name in reversed(segs):
        path = os.path.join(wal_dir, name)
        size = os.path.getsize(path)
        if size == 0:
            continue
        keep = max(1, int(size * frac))
        with open(path, "r+b") as f:
            f.truncate(keep)
            f.flush()
            os.fsync(f.fileno())
        return size - keep
    return 0


def flip_wal_byte(wal_dir: str, *, offset_frac: float = 0.5) -> int:
    """Flip one byte in the newest non-empty WAL segment (BIT_FLIP).

    The per-record CRC turns the flip into an invalid record on the next
    scan, truncating the segment from that record on — silent bit-rot
    becomes a detectable (and bounded) tail loss.  Returns the absolute
    byte offset flipped, or -1 when there was nothing to corrupt.
    """
    segs = sorted(n for n in os.listdir(wal_dir)
                  if n.startswith("wal_") and n.endswith(".log"))
    for name in reversed(segs):
        path = os.path.join(wal_dir, name)
        size = os.path.getsize(path)
        if size == 0:
            continue
        off = min(size - 1, max(0, int(size * offset_frac)))
        with open(path, "r+b") as f:
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ 0xFF]))
            f.flush()
            os.fsync(f.fileno())
        return off
    return -1
