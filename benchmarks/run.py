"""Benchmark harness entry: one module per paper table/figure.

``python -m benchmarks.run [--quick]`` prints a CSV block per figure
followed by the paper-claim check lines, and writes runs/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from . import (bench_engine, bench_ingest_device, bench_kernels, fig4_fanout,
               fig5_dtree_size, fig67_insertion, fig89_query, fig_failover,
               fig_mixed, fig_range, fig_recovery, fig_saturation,
               fig_scaling, fig_stability, fig_tenancy, table2_theory)

SUITES = [
    ("fig4_fanout (Fig 4a/4b)", fig4_fanout),
    ("fig5_dtree_size (Fig 5a/5b)", fig5_dtree_size),
    ("fig67_insertion (Figs 6,7)", fig67_insertion),
    ("fig89_query (Figs 8,9)", fig89_query),
    ("fig_range (range scans)", fig_range),
    ("fig_mixed (mixed workloads)", fig_mixed),
    ("fig_scaling (sharded scale-out)", fig_scaling),
    ("fig_saturation (open-loop tail latency)", fig_saturation),
    ("fig_recovery (durability / crash recovery)", fig_recovery),
    ("fig_failover (replicated kill-primary)", fig_failover),
    ("fig_stability (long-horizon windowed stability)", fig_stability),
    ("fig_tenancy (multi-tenant isolation)", fig_tenancy),
    ("table2_theory (Table 2)", table2_theory),
    ("bench_kernels (Pallas)", bench_kernels),
    ("bench_engine (serving)", bench_engine),
    ("bench_ingest_device (fused cascade)", bench_ingest_device),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workloads (CI mode)")
    args = ap.parse_args()

    all_rows = {}
    verdicts = []
    for title, mod in SUITES:
        t0 = time.time()
        kwargs = {}
        if args.quick and mod in (fig4_fanout, fig5_dtree_size):
            kwargs = {"n": 40_000}
        elif args.quick and mod is fig67_insertion:
            kwargs = {"sizes": (20_000, 60_000)}
        elif args.quick and mod is fig89_query:
            kwargs = {"sizes": (20_000, 60_000)}
        elif args.quick and mod is fig_range:
            kwargs = {"sizes": (20_000,), "n_q": 8}
        elif args.quick and mod is fig_mixed:
            kwargs = {"mixes": ("ycsb-a",), "n_ops": 1024, "preload": 1024}
        elif args.quick and mod is fig_scaling:
            kwargs = fig_scaling.QUICK_KWARGS
        elif args.quick and mod is fig_saturation:
            kwargs = fig_saturation.QUICK_KWARGS
        elif args.quick and mod is fig_recovery:
            kwargs = fig_recovery.QUICK_KWARGS
        elif args.quick and mod is fig_failover:
            kwargs = fig_failover.QUICK_KWARGS
        elif args.quick and mod is fig_stability:
            kwargs = fig_stability.QUICK_KWARGS
        elif args.quick and mod is fig_tenancy:
            kwargs = fig_tenancy.QUICK_KWARGS
        elif args.quick and mod is table2_theory:
            kwargs = {"sizes": (10_000, 30_000, 90_000)}
        elif args.quick and mod is bench_ingest_device:
            kwargs = bench_ingest_device.QUICK_KWARGS
        rows = mod.run(**kwargs)
        dt = time.time() - t0
        all_rows[title] = rows
        print(f"\n== {title}  ({dt:.1f}s) ==")
        if rows:
            cols = list(rows[0].keys())
            print(",".join(cols))
            for r in rows:
                print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float)
                               else str(r[c]) for c in cols))
        checks = mod.check(rows)
        verdicts.extend(checks)
        for c in checks:
            print("  ->", c)

    print("\n== PAPER-CLAIM SUMMARY ==")
    n_match = sum("matches paper" in v for v in verdicts)
    n_mismatch = sum("MISMATCH" in v for v in verdicts)
    for v in verdicts:
        print(" ", v)
    print(f"\n{n_match} claims reproduced, {n_mismatch} mismatches")

    os.makedirs("runs", exist_ok=True)
    with open("runs/bench_results.json", "w") as f:
        json.dump({"rows": all_rows, "verdicts": verdicts}, f, indent=1)


if __name__ == "__main__":
    main()
