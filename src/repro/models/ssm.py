"""Recurrent blocks: xLSTM (mLSTM, sLSTM) and a Mamba-style selective SSM.

All three are implemented in their *recurrent* form with ``lax.scan`` over
time — shape-faithful to the published configs, compact HLO for 512-way
SPMD compiles, and O(1)-state decode for the long_500k shape (the whole
point of assigning these archs the long-context cells).  The chunkwise-
parallel training formulation is a recorded hillclimb candidate
(EXPERIMENTS.md §Perf).

State conventions (decode carries these instead of a KV cache):
  mLSTM : C (B, H, Dk, Dv), n (B, H, Dk), m (B, H)
  sLSTM : c, n, m, h_prev (B, d) each
  mamba : s (B, d_inner, N), conv window (B, W, d_inner)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dense_init, rms_norm


# ------------------------------------------------------------------- mLSTM
def mlstm_params(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    dk = d // H
    ks = jax.random.split(key, 8)
    return {
        "wq": _dense_init(ks[0], (d, d), dtype),
        "wk": _dense_init(ks[1], (d, d), dtype),
        "wv": _dense_init(ks[2], (d, d), dtype),
        "wi": _dense_init(ks[3], (d, H), dtype),     # input gate (per head)
        "wf": _dense_init(ks[4], (d, H), dtype),     # forget gate
        "wo_gate": _dense_init(ks[5], (d, d), dtype),
        "wo": _dense_init(ks[6], (d, d), dtype),
        "out_norm": jnp.ones((d,), dtype),
    }


def _mlstm_step(state, qkvif, dk):
    """One recurrence step with exponential-gating stabilizer m."""
    C, n, m = state
    q, k, v, i_pre, f_pre = qkvif                     # (B,H,Dk) (B,H,Dk) (B,H,Dv) (B,H) (B,H)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, i_pre.astype(jnp.float32))
    i_g = jnp.exp(i_pre.astype(jnp.float32) - m_new)
    f_g = jnp.exp(logf + m - m_new)
    kf = k.astype(jnp.float32) / np.sqrt(dk)
    C = f_g[..., None, None] * C + i_g[..., None, None] * (
        kf[..., :, None] * v.astype(jnp.float32)[..., None, :])
    n = f_g[..., None] * n + i_g[..., None] * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_block(x, p, cfg, state=None):
    """x (B, S, d) -> (B, S, d); returns (out, final_state)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dk = d // H
    q = (x @ p["wq"]).reshape(B, S, H, dk)
    k = (x @ p["wk"]).reshape(B, S, H, dk)
    v = (x @ p["wv"]).reshape(B, S, H, dk)
    i_pre = x @ p["wi"]
    f_pre = x @ p["wf"]
    if state is None:
        state = (jnp.zeros((B, H, dk, dk), jnp.float32),
                 jnp.zeros((B, H, dk), jnp.float32),
                 jnp.zeros((B, H), jnp.float32))

    def step(carry, t):
        return _mlstm_step(carry, t, dk)

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), i_pre.transpose(1, 0, 2),
          f_pre.transpose(1, 0, 2))
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps)
    gate = jax.nn.silu(x @ p["wo_gate"])
    return (h * gate) @ p["wo"], state


# ------------------------------------------------------------------- sLSTM
def slstm_params(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "wz": _dense_init(ks[0], (d, d), dtype),
        "wi": _dense_init(ks[1], (d, d), dtype),
        "wf": _dense_init(ks[2], (d, d), dtype),
        "wo_gate": _dense_init(ks[3], (d, d), dtype),
        "r": _dense_init(ks[4], (d, d), dtype),      # recurrent mixing
        "wo": _dense_init(ks[5], (d, d), dtype),
        "out_norm": jnp.ones((d,), dtype),
    }


def slstm_block(x, p, cfg, state=None):
    B, S, d = x.shape
    z_pre = x @ p["wz"]
    i_pre = x @ p["wi"]
    f_pre = x @ p["wf"]
    o_pre = x @ p["wo_gate"]
    if state is None:
        state = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))

    def step(carry, t):
        c, n, m, h_prev = carry
        zp, ip, fp, op = t
        rec = (h_prev.astype(x.dtype) @ p["r"]).astype(jnp.float32)
        zt = jnp.tanh(zp.astype(jnp.float32) + rec)
        logf = jax.nn.log_sigmoid(fp.astype(jnp.float32) + rec)
        m_new = jnp.maximum(logf + m, ip.astype(jnp.float32) + rec)
        i_g = jnp.exp(ip.astype(jnp.float32) + rec - m_new)
        f_g = jnp.exp(logf + m - m_new)
        c = f_g * c + i_g * zt
        n = f_g * n + i_g
        h = jax.nn.sigmoid(op.astype(jnp.float32)) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h), h

    xs = tuple(a.transpose(1, 0, 2) for a in (z_pre, i_pre, f_pre, o_pre))
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps)
    return h @ p["wo"], state


# ------------------------------------------------------- mamba-style SSM
def mamba_params(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": _dense_init(ks[0], (d, 2 * di), dtype),
        "conv": (jax.random.normal(ks[1], (cfg.conv_width, di)) * 0.1).astype(dtype),
        "w_bcdt": _dense_init(ks[2], (di, 2 * N + 1), dtype),
        "a_log": jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :]
                 * jnp.ones((di, 1), jnp.float32),
        "d_skip": jnp.ones((di,), jnp.float32),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "w_out": _dense_init(ks[3], (di, d), dtype, fan_in=di),
    }


def mamba_block(x, p, cfg, state=None):
    """Selective SSM; returns (out (B,S,d), (ssm_state, conv_tail))."""
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    W = cfg.conv_width
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                 # (B, S, di) each

    if state is None:
        s0 = jnp.zeros((B, di, N), jnp.float32)
        conv_tail = jnp.zeros((B, W - 1, di), x.dtype)
    else:
        s0, conv_tail = state

    # causal depthwise conv over time (window W)
    xpad = jnp.concatenate([conv_tail, xi], axis=1)   # (B, S+W-1, di)
    xc = sum(xpad[:, i: i + S] * p["conv"][i] for i in range(W))
    xc = jax.nn.silu(xc)
    new_tail = xpad[:, -(W - 1):] if W > 1 else conv_tail

    bcdt = xc @ p["w_bcdt"]                           # (B, S, 2N+1)
    Bm, Cm, dt = bcdt[..., :N], bcdt[..., N:2 * N], bcdt[..., 2 * N:]
    # scalar per-position step size, broadcast per-channel via dt_bias.
    # dt streams at (S, B, di) — kept bf16 on the wire (PERF iteration:
    # halves the mamba scan's HBM traffic; state math stays fp32).
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    dt = dt.astype(x.dtype)
    A = -jnp.exp(p["a_log"])                          # (di, N), negative

    def step(s, t):
        xc_t, b_t, c_t, dt_t = t                      # (B,di) (B,N) (B,N) (B,di)
        dt_f = dt_t.astype(jnp.float32)
        dA = jnp.exp(dt_f[..., None] * A[None])       # (B, di, N)
        dB = dt_f[..., None] * b_t.astype(jnp.float32)[:, None, :]
        s = dA * s + dB * xc_t.astype(jnp.float32)[..., None]
        y = jnp.einsum("bdn,bn->bd", s, c_t.astype(jnp.float32))
        return s, y.astype(xc.dtype)

    xs = (xc.transpose(1, 0, 2), Bm.transpose(1, 0, 2),
          Cm.transpose(1, 0, 2), dt.transpose(1, 0, 2))
    s, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2) + xc.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]
    return y, (s, new_tail)
