"""AdamW with fp32 state over bf16 params, global-norm clipping.

Plain-pytree implementation (no optax in this environment).  Optimizer
state is sharded like the parameters (FSDP over "data", TP over "model"),
so per-device optimizer memory is params_bytes * 4 / (data * model).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> dict:
    f32 = lambda t: jnp.zeros(t.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = cfg.lr(count) if callable(cfg.lr) else cfg.lr

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = lr * (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step = step + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
