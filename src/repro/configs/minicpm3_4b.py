"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B; hf].

62L, d_model 2560, 40 heads, MLA (q_lora 768, kv_lora 256, nope 64,
rope 32, v 64), d_ff 6400.  Full attention -> long_500k skipped.
"""
from .base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab=73448,
    segments=(("mla", 62),),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    mlp_kind="swiglu", tie_embeddings=True,
)
