"""Figs. 6 & 7: average / maximum insertion time vs data size, all indices.

Paper claims reproduced here:
  * NB-tree average insertion <= LSM family, >=10x below B+-tree (Fig. 6);
  * NB-tree maximum insertion ~3 orders of magnitude below LSM engines
    (Fig. 7 — the 453 s RocksDB spike vs NB-tree's ~1e-4 s);
  * the >100 us/insert exclusion rule removes B+-tree (and B^eps on HDD)
    from the large runs, as in the paper's preliminary experiment.
"""
from __future__ import annotations

import numpy as np

from .common import DEVICES, insert_all, make_bench_engine, workload

INDICES = ("nbtree", "nbtree-basic", "lsm", "blsm", "bepsilon", "btree")


def run(sizes=(40_000, 120_000, 360_000)):
    rows = []
    for dev_name, dev in DEVICES.items():
        for n in sizes:
            keys = workload(n)
            sigma = max(1024, n // 64)
            for name in INDICES:
                if name == "btree" and n > 40_000:
                    continue  # excluded by the paper's 100us rule (see check)
                eng = make_bench_engine(name, dev, sigma)
                avg, mx = insert_all(eng, keys)
                eng.drain()
                rows.append(dict(fig="6/7", device=dev_name, n=n, index=name,
                                 avg_insert_us=avg * 1e6, max_insert_ms=mx * 1e3))
    return rows


def check(rows) -> list[str]:
    out = []
    big = max(r["n"] for r in rows)
    for dev in DEVICES:
        sel = {r["index"]: r for r in rows if r["n"] == big and r["device"] == dev}
        nb, lsm = sel["nbtree"], sel["lsm"]
        ratio = lsm["max_insert_ms"] / max(nb["max_insert_ms"], 1e-9)
        tag = "matches paper" if ratio > 100 else "MISMATCH"
        out.append(f"fig7 {dev}: NB max-insert {ratio:.0f}x below LSM  [{tag}]")
        if nb["avg_insert_us"] <= lsm["avg_insert_us"] * 1.5:
            out.append(f"fig6 {dev}: NB avg-insert competitive with LSM  [matches paper]")
        else:
            out.append(f"fig6 {dev}: NB avg-insert worse than LSM  [MISMATCH]")
    # exclusion rule (paper Sec. 6.1): B+-tree average insert > 100us
    btree = [r for r in rows if r["index"] == "btree"]
    if btree and all(r["avg_insert_us"] > 100 for r in btree):
        out.append("fig6: B+-tree exceeds the 100us exclusion threshold  [matches paper]")
    return out
