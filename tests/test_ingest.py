"""Open-loop ingest subsystem tests (DESIGN.md §7).

Covers the arrival processes (determinism, monotonicity, distributional
shape), trace construction against the workload generator, the frontend's
conformance to closed-loop semantics (same ops applied => same final
engine state), admission control under overload, group-commit bounds,
byte-identical reproducibility of reports, the deamortized-debt bound
under saturation, SLO percentile/stall accounting, and the Bloom
effectiveness counters surfaced through ``EngineStats``.
"""
import json

import numpy as np
import pytest

from repro.core.engine_api import OpBatch, OpKind, make_engine
from repro.ingest import (DiurnalArrivals, FrontendConfig, IngestFrontend,
                          MMPPArrivals, PoissonArrivals, make_arrivals,
                          make_trace, run_open_loop)
from repro.ingest.slo import SLOTracker, _tail_summary
from repro.workloads import make_workload


def _wl(mix="insert-heavy", **kw):
    kw.setdefault("key_space", 1 << 16)
    kw.setdefault("n_ops", 512)
    kw.setdefault("preload", 256)
    kw.setdefault("batch_size", 128)
    return make_workload(mix, **kw)


def _rng(seed=0):
    return np.random.default_rng(seed)


# ------------------------------------------------------------------- arrivals
@pytest.mark.parametrize("proc", [
    PoissonArrivals(5000.0),
    MMPPArrivals(20000.0, 100.0, mean_on_s=0.01, mean_off_s=0.03),
    DiurnalArrivals(5000.0, amplitude=0.8, period_s=0.25),
])
def test_arrivals_deterministic_and_monotone(proc):
    a = proc.times(_rng(7), 2000)
    b = proc.times(_rng(7), 2000)
    assert np.array_equal(a, b), "same seed must give the same trace"
    assert len(a) == 2000 and np.all(np.diff(a) >= 0.0)
    assert json.dumps(proc.describe())        # JSON-ready description


def test_poisson_mean_rate():
    rate = 10_000.0
    t = PoissonArrivals(rate).times(_rng(1), 20_000)
    assert abs(len(t) / t[-1] - rate) / rate < 0.05


def test_mmpp_burstier_than_poisson():
    """On/off modulation must fatten inter-arrival dispersion (CV > 1)."""
    mmpp = MMPPArrivals(50_000.0, 100.0, mean_on_s=0.005, mean_off_s=0.02)
    gaps = np.diff(mmpp.times(_rng(3), 20_000))
    cv_mmpp = gaps.std() / gaps.mean()
    gaps_p = np.diff(PoissonArrivals(mmpp.mean_rate).times(_rng(3), 20_000))
    cv_poisson = gaps_p.std() / gaps_p.mean()
    assert cv_poisson < 1.2 < cv_mmpp
    assert mmpp.mean_rate < mmpp.rate_on


def test_diurnal_rate_modulates():
    """More arrivals land in the peak half-period than in the trough."""
    d = DiurnalArrivals(10_000.0, amplitude=0.9, period_s=1.0)
    t = d.times(_rng(5), 50_000)
    phase = np.mod(t, d.period_s)
    peak = int(np.sum(phase < 0.5))           # sin > 0 half
    trough = int(np.sum(phase >= 0.5))
    assert peak > 2 * trough


def test_make_arrivals_factory():
    assert isinstance(make_arrivals("poisson", 10.0), PoissonArrivals)
    with pytest.raises(KeyError):
        make_arrivals("no-such-process", 1.0)


def test_trace_matches_workload_stream():
    wl = _wl(seed=11)
    trace = make_trace(wl, PoissonArrivals(1000.0))
    ref = OpBatch.concat(list(_wl(seed=11).batches()))
    assert np.array_equal(trace.ops.kinds, ref.kinds)
    assert np.array_equal(trace.ops.keys, ref.keys)
    assert np.array_equal(trace.ops.vals, ref.vals)
    assert len(trace.t_arrive) == len(trace.ops) == wl.spec.n_ops
    assert len(trace.preload) == len(wl.preload_batch())


def test_trace_duration_truncates():
    wl = _wl(seed=2, n_ops=1024)
    full = make_trace(wl, PoissonArrivals(1000.0))
    half = make_trace(_wl(seed=2, n_ops=1024), PoissonArrivals(1000.0),
                      duration_s=full.duration_s / 2)
    assert 0 < len(half) < len(full)
    assert half.t_arrive[-1] <= full.duration_s / 2
    # the truncated trace is a prefix of the full one
    assert np.array_equal(half.ops.keys, full.ops.keys[: len(half)])


# ------------------------------------------------------------------- frontend
_CFG = FrontendConfig(max_queue=1024, commit_ops=32, linger_s=5e-4)


def test_open_loop_matches_closed_loop_state():
    """No shedding => the frontend applies exactly the closed-loop stream."""
    wl = _wl(mix="delete-churn", seed=4)
    trace = make_trace(wl, PoissonArrivals(5000.0))
    open_eng = make_engine("nbtree", f=3, sigma=128)
    rep = IngestFrontend(open_eng, _CFG).run(trace)
    assert rep["n_shed"] == 0 and rep["n_done"] == wl.spec.n_ops

    closed = make_engine("nbtree", f=3, sigma=128)
    closed.apply(wl.preload_batch())
    for b in _wl(mix="delete-churn", seed=4).batches():
        closed.apply(b)
        closed.maintain(1)
    closed.drain()
    assert open_eng.count_live() == closed.count_live()


def test_open_loop_report_deterministic():
    def one():
        wl = _wl(seed=9)
        trace = make_trace(wl, MMPPArrivals(50_000.0, 100.0,
                                            mean_on_s=0.002,
                                            mean_off_s=0.004))
        eng = make_engine("lsm", mem_pairs=128)
        return json.dumps(run_open_loop(eng, trace, config=_CFG),
                          sort_keys=True)
    assert one() == one()


def test_admission_control_sheds_under_overload():
    cfg = FrontendConfig(max_queue=32, commit_ops=16, linger_s=1e-4)
    wl = _wl(seed=6, n_ops=768)
    trace = make_trace(wl, PoissonArrivals(200_000.0))   # far past capacity
    eng = make_engine("btree")                           # slow random-I/O tier
    rep = IngestFrontend(eng, cfg).run(trace)
    assert rep["n_shed"] > 0
    assert rep["n_done"] + rep["n_shed"] == len(trace)
    assert rep["queue"]["max_depth"] <= cfg.max_queue
    st = eng.stats()
    applied = st.n_inserts + st.n_deletes + st.n_queries + st.n_ranges
    assert applied == rep["n_done"] + len(trace.preload), \
        "shed ops must never reach the engine"
    assert rep["shed_rate"] == pytest.approx(
        rep["n_shed"] / (rep["n_shed"] + rep["n_done"]))


def test_group_commit_bounds():
    wl = _wl(seed=3)
    # saturating arrivals: commits fill to the cap
    fast = IngestFrontend(make_engine("lsm", mem_pairs=128), _CFG).run(
        make_trace(_wl(seed=3), PoissonArrivals(500_000.0)))
    assert fast["server"]["mean_commit_ops"] <= _CFG.commit_ops
    assert fast["server"]["mean_commit_ops"] > 4
    # sparse arrivals (mean gap >> linger): commits are near-singletons
    slow = IngestFrontend(make_engine("lsm", mem_pairs=128), _CFG).run(
        make_trace(wl, PoissonArrivals(100.0)))
    assert slow["server"]["mean_commit_ops"] < 2.0
    assert slow["server"]["utilization"] < 0.2


def test_e2e_latency_decomposition():
    """End-to-end >= queueing delay, utilization <= 1, makespan sane."""
    wl = _wl(seed=8)
    trace = make_trace(wl, PoissonArrivals(20_000.0))
    rep = IngestFrontend(make_engine("lsm", mem_pairs=128), _CFG).run(trace)
    e2e = rep["per_kind_e2e"]["insert"]
    assert e2e["p100_s"] >= rep["queue_delay"]["p100_s"] >= 0.0
    assert 0.0 < rep["server"]["utilization"] <= 1.0 + 1e-9
    assert rep["duration_s"] >= trace.duration_s * 0.5


def test_nbtree_debt_bounded_at_saturation():
    """The deamortized bound survives overload: debt <= one cascade."""
    cfg = FrontendConfig(max_queue=256, commit_ops=32, linger_s=1e-4)
    trace = make_trace(_wl(seed=10, n_ops=1024),
                       PoissonArrivals(2_000_000.0))
    rep = IngestFrontend(make_engine("nbtree", f=3, sigma=128), cfg).run(trace)
    assert rep["stalls"]["debt_max"] <= 1
    assert rep["pending_debt_at_end"] <= 1


def test_frontend_config_validation():
    with pytest.raises(AssertionError):
        FrontendConfig(max_queue=8, commit_ops=16)    # commit > queue bound
    with pytest.raises(AssertionError):
        FrontendConfig(linger_s=-1.0)


# ------------------------------------------------------------------------ slo
def test_tail_summary_exact_percentiles():
    s = _tail_summary(np.array([1e-3] * 99 + [1.0]))
    assert s["count"] == 100
    assert s["p50_s"] == pytest.approx(1e-3)
    assert s["p100_s"] == pytest.approx(1.0)
    assert sum(s["bucket_counts"]) == 100
    empty = _tail_summary(np.empty(0))
    assert empty["count"] == 0 and empty["p999_s"] == 0.0


def test_stall_attribution():
    tr = SLOTracker()
    for i in range(20):
        tr.record_commit(t_commit=float(i), kinds=["insert"], e2e_s=[1e-4],
                         queue_delay_s=[0.0], qdepth_after=0,
                         service_s=1e-4, maintain_s=0.0, debt=0)
    tr.record_commit(t_commit=21.0, kinds=["insert"], e2e_s=[0.5],
                     queue_delay_s=[0.4], qdepth_after=37,
                     service_s=0.5, maintain_s=0.0, debt=3)
    rep = tr.report(offered={"insert": 21}, t_end=22.0)
    st = rep["stalls"]
    assert st["n_stall_commits"] == 1
    assert st["ops_queued_behind_stalls"] == 37
    assert st["debt_max"] == 3
    assert rep["per_kind_e2e"]["insert"]["p100_s"] == pytest.approx(0.5)


# -------------------------------------------------------------- bloom counters
def test_bloom_counters_refimpl():
    present = np.arange(1, 1001, dtype=np.uint64)
    absent = np.arange(10**6, 10**6 + 1000, dtype=np.uint64)

    def drive(name):
        eng = make_engine(name, f=3, sigma=128)
        eng.apply(OpBatch.inserts(present, present.astype(np.int64)))
        eng.drain()
        res = eng.apply(OpBatch.queries(np.concatenate([present, absent])))
        return eng.stats(), res

    st, res = drive("nbtree")
    st0, res0 = drive("nbtree-nobloom")
    # the LSM baseline consults per-level filters too — its counters must
    # be real, not the no-filter zeros of btree/bepsilon.
    lsm = make_engine("lsm", mem_pairs=128)
    lsm.apply(OpBatch.inserts(present, present.astype(np.int64)))
    lsm.apply(OpBatch.queries(absent))
    assert lsm.stats().bloom_probes > 0
    assert lsm.stats().bloom_negative_skips > 0
    # identical visible results — the filter only changes cost, never answers
    assert np.array_equal(res.found, res0.found)
    assert np.array_equal(res.values, res0.values)
    assert st.bloom_probes > 0
    assert st.bloom_negative_skips > 0
    # paper Sec. 5.2 sizes the filter for <5% FP per probe; lazy removal
    # (Sec. 5.1) inflates the *observed* rate, because a node's filter is
    # rebuilt on flush-in but its watermark advances on flush-out — keys
    # that moved down stay in the parent's stale filter (extra false
    # positives, never false negatives).  Bound the combined effect.
    fp_rate = st.bloom_false_positives / max(1, st.bloom_negative_skips
                                             + st.bloom_false_positives)
    assert 0.0 < fp_rate < 0.12
    assert (st0.bloom_probes, st0.bloom_negative_skips,
            st0.bloom_false_positives) == (0, 0, 0)
    # the filter must skip real I/O: fewer seeks than the unfiltered tree
    assert st.io_seeks < st0.io_seeks


def test_bloom_counters_device_and_sharded():
    dev = make_engine("jax-nbtree", f=4, sigma=64, max_nodes=64)
    keys = np.arange(1, 257, dtype=np.uint64)
    dev.apply(OpBatch.inserts(keys, keys.astype(np.int64)))
    dev.drain()
    q = np.concatenate([keys[:64],
                        np.arange(10**5, 10**5 + 64, dtype=np.uint64)])
    res = dev.apply(OpBatch.queries(q))
    assert res.found[:64].all() and not res.found[64:].any()
    st = dev.stats()
    assert st.bloom_probes > 0
    assert st.bloom_negative_skips > 0
    assert st.bloom_false_positives <= st.bloom_probes

    sh = make_engine("sharded:nbtree", shards=2, f=3, sigma=128)
    sh.apply(OpBatch.inserts(keys, keys.astype(np.int64)))
    sh.drain()
    sh.apply(OpBatch.queries(q))
    agg = sh.stats()
    assert agg.bloom_probes > 0, "sharded stats must sum shard bloom counters"


# --------------------------------------------------------------------- driver
def test_driver_open_loop_report_shape():
    from repro.workloads.driver import SCHEMA_VERSION, run_open_workload
    eng = make_engine("lsm", mem_pairs=128)
    rep = run_open_workload(eng, _wl(seed=1), arrival="poisson", rate=5000.0,
                            maintain_budget=4)
    assert rep["schema_version"] == SCHEMA_VERSION
    assert rep["arrival"]["process"] == "poisson"
    assert rep["open_loop"]["n_done"] == 512
    assert "insert" in rep["open_loop"]["per_kind_e2e"]
    # the CLI's deamortization knob must reach the frontend config
    assert rep["open_loop"]["config"]["maintain_budget"] == 4
    json.dumps(rep)                                  # JSON-ready end to end


def test_driver_cli_listings_and_errors(capsys):
    from repro.workloads.driver import main
    main(["--list-engines"])
    out = capsys.readouterr().out
    assert "nbtree" in out and "sharded:<base>" in out
    main(["--list-mixes"])
    out = capsys.readouterr().out
    assert "ycsb-a" in out and "insert" in out
    with pytest.raises(SystemExit) as exc:
        main(["--engines", "definitely-not-an-engine", "--ops", "8"])
    assert exc.value.code == 2                       # argparse clean error
    capsys.readouterr()
