"""Serving stack: paged KV cache + NB-tree block index + engine equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import transformer as T
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import MAX_BLOCKS_PER_SEQ, PagedKVCache, pack_key


def test_pack_key_roundtrip():
    keys = pack_key(np.asarray([0, 5, 1000]), np.asarray([0, 7, 123]))
    assert keys.dtype == np.uint32
    assert len(set(keys.tolist())) == 3


def test_kv_cache_alloc_free_cycle():
    c = PagedKVCache(n_layers=2, n_kv_heads=2, head_dim=16, n_pages=32,
                     page_size=4, dtype=jnp.float32)
    free0 = len(c.free)
    c.add_sequence(1)
    c.extend(1, 10)                  # 3 pages
    c.add_sequence(2)
    c.extend(2, 4)                   # 1 page
    assert len(c.free) == free0 - 4
    t = np.asarray(c.block_tables([1, 2], 3))
    assert (t[0] > 0).sum() == 3 and (t[1] > 0).sum() == 1
    c.free_sequence(1)
    c.maintain(8)
    assert len(c.free) == free0 - 1
    c.free_sequence(2)
    assert len(c.free) == free0


def test_kv_cache_write_read():
    c = PagedKVCache(n_layers=1, n_kv_heads=2, head_dim=8, n_pages=16,
                     page_size=4, dtype=jnp.float32)
    c.add_sequence(0)
    c.extend(0, 6)
    k = jnp.arange(2 * 8, dtype=jnp.float32).reshape(1, 2, 8)
    c.write_token(0, [0], [5], k, k * 2)
    kp, vp = c.layer_pages(0)
    table = np.asarray(c.block_tables([0], 2))
    page, slot = table[0, 5 // 4], 5 % 4
    np.testing.assert_allclose(np.asarray(kp)[:, page, slot], np.asarray(k)[0])
    np.testing.assert_allclose(np.asarray(vp)[:, page, slot], np.asarray(k)[0] * 2)


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(registry.get_config("qwen3-8b").reduced(),
                              dtype="float32", remat="none")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_matches_contiguous_decode(served):
    cfg, params = served
    prompt = list(range(1, 9))
    # reference: contiguous cache decode
    cache = T.init_cache(cfg, 1, 64)
    for i, t in enumerate(prompt):
        lg, cache = T.decode_step(params, cfg, jnp.asarray([t], jnp.int32),
                                  cache, jnp.int32(i))
    ref = [int(jnp.argmax(lg[0]))]
    for s in range(4):
        lg, cache = T.decode_step(params, cfg,
                                  jnp.asarray([ref[-1]], jnp.int32), cache,
                                  jnp.int32(len(prompt) + s))
        ref.append(int(jnp.argmax(lg[0])))

    eng = Engine(cfg, params, max_batch=2, n_pages=128, page_size=8)
    reqs = [Request(0, prompt, max_new_tokens=5),
            Request(1, prompt, max_new_tokens=5)]
    out = eng.run(reqs)
    assert out[0].out == ref, (out[0].out, ref)
    assert out[1].out == ref
    assert len(eng.cache.free) == 127      # all pages reclaimed (page 0 reserved)
