"""Device-tier ingest microbenchmark: fused vs eager emptying cascade.

The paper's headline claim is a *consistently high insertion rate*; on the
device tier that rate is decided by the maintenance path — every flush of
the emptying cascade used to issue ~25 eager dispatches with blocking host
syncs in the middle, and every insert batch rebuilt the root Bloom filter
over the full run.  This benchmark measures the write path both ways
(``NBTreeIndex(fused=...)``) on the *same* key stream and records the first
wall-clock entries in the perf trajectory:

* **insert ops/s** — end-to-end ingest wall-clock (insert + interleaved
  ``maintain`` + final drain) over the measured window,
* **dispatches per flush unit** — counted through the per-instance
  ``NBTreeIndex.dispatch_count`` (every dispatch flows through the
  ``jax_nbtree._device_call`` funnel), split into insert-path and
  maintenance-path budgets,
* **maintain-unit latency** — p50/p99/p100 wall-clock of individual
  ``maintain(1)`` work units (the deamortized stall quantum).

Absolute numbers on CPU are interpret-mode Pallas (the kernel target is
TPU) and are NOT byte-reproducible — the fused/eager *ratios* are the
signal, and the dispatch counts are exact.  ``check`` enforces the PR's
acceptance floor: >= 5x fewer dispatches per flush unit and a higher
insert rate on the fused path.

Standalone CLI (CI bench-smoke; ``BENCH_device_ingest.json`` at the repo
root is the full-run trajectory seed)::

    PYTHONPATH=src python -m benchmarks.bench_ingest_device --quick \
        --out runs/bench_ingest_device.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

import repro.core.jax_nbtree as jnb
from repro.core.jax_nbtree import NBTreeIndex
from repro.kernels import ops
from repro.workloads.driver import SCHEMA_VERSION

#: one source of truth for the smoke-sized run (--quick here and in
#: benchmarks/run.py must produce comparable artifacts).
QUICK_KWARGS = dict(n_batches=48, warmup=24, batch=256, sigma=512)


def _precompile_fused(idx: NBTreeIndex) -> None:
    """Compile every fused maintenance variant against the live table shapes.

    The fused impls are shape-specialized jits keyed on (child count, leaf
    level, split mode); variants appear as the tree grows — an internal
    node's first 4th child can arrive mid-measurement and would charge its
    multi-second first compile to one unlucky unit.  Warming them on dummy
    tables of identical shape keeps the measured window compile-free.  The
    eager path needs no equivalent: its helpers are per-table-shape only
    and all appear within the first few warmup flushes.
    """
    import jax.numpy as jnp

    dummy = lambda: (jnp.zeros_like(idx.run_keys),
                     jnp.zeros_like(idx.run_vals),
                     jnp.zeros_like(idx.run_count),
                     jnp.zeros_like(idx.bloom))
    for nc in range(2, idx.f + 1):
        for leaf in (True, False):
            jax.block_until_ready(jnb._flush_impl(
                *dummy(), jnp.int32(0), jnp.zeros(nc, jnp.int32),
                jnp.zeros(max(nc - 1, 1), jnp.uint32)[: nc - 1],
                jnp.int32(idx.sigma + 1), nc=nc, leaf=leaf, sigma=idx.sigma,
                sigma_pad=idx.sigma_pad, run_cap=idx.run_cap,
                nbits=idx.nbits, h=idx.h, interpret=ops._interpret()))
    for has_key in (False, True):
        jax.block_until_ready(jnb._split_impl(
            *dummy(), jnp.int32(0), jnp.int32(1), jnp.int32(2),
            jnp.int32(idx.sigma + 1), jnp.uint32(0), has_key=has_key,
            run_cap=idx.run_cap, nbits=idx.nbits, h=idx.h))
    jax.block_until_ready(jnb._clear_impl(*dummy(), jnp.int32(0)))
    jax.block_until_ready(jnb._sync_impl(
        jnp.zeros_like(idx.pivots), jnp.zeros_like(idx.children),
        jnp.zeros_like(idx.nchild), jnp.int32(0),
        jnp.zeros(idx.f - 1, jnp.uint32), jnp.zeros(idx.f, jnp.int32),
        jnp.int32(0)))


def _ingest(fused: bool, *, n_batches: int, warmup: int, batch: int,
            sigma: int, f: int, max_nodes: int, budget: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    keys = rng.choice(np.arange(1, 2**31, dtype=np.uint32),
                      (n_batches + warmup) * batch, replace=False)
    idx = NBTreeIndex(f=f, sigma=sigma, max_nodes=max_nodes, fused=fused)

    units = {"flush": 0, "split": 0}
    orig_handle = idx._handle_full

    def counted(node):
        units["split" if node.is_leaf else "flush"] += 1
        return orig_handle(node)

    idx._handle_full = counted

    def one_batch(b, unit_times, disp):
        """Insert one batch then pay maintenance one timed unit at a time."""
        ks = keys[b * batch:(b + 1) * batch]
        d0 = idx.dispatch_count
        t0 = time.perf_counter()
        idx.insert_batch(ks, np.arange(batch, dtype=np.int32))
        jax.block_until_ready(idx.run_keys)
        disp["insert"] += idx.dispatch_count - d0
        disp["insert_batches"] += 1
        for _ in range(budget):
            if not idx._pending:
                break
            u0 = units["flush"] + units["split"]
            d1 = idx.dispatch_count
            t1 = time.perf_counter()
            idx.maintain(1)
            jax.block_until_ready(idx.run_keys)
            dt = time.perf_counter() - t1
            if units["flush"] + units["split"] > u0:
                unit_times.append(dt)
                disp["maintain"] += idx.dispatch_count - d1
        return time.perf_counter() - t0

    # ---- warmup: compile every maintenance variant + steady the tree -------
    if fused:
        _precompile_fused(idx)
    sink_times: list = []
    sink_disp = {"insert": 0, "insert_batches": 0, "maintain": 0}
    for b in range(warmup):
        one_batch(b, sink_times, sink_disp)

    # ---- measured window ---------------------------------------------------
    units["flush"] = units["split"] = 0
    unit_times: list = []
    disp = {"insert": 0, "insert_batches": 0, "maintain": 0}
    wall = 0.0
    for b in range(warmup, warmup + n_batches):
        wall += one_batch(b, unit_times, disp)
    t0 = time.perf_counter()
    n_drain_units0 = units["flush"] + units["split"]
    d0 = idx.dispatch_count
    idx.drain()
    jax.block_until_ready(idx.run_keys)
    drain_s = time.perf_counter() - t0
    disp["maintain"] += idx.dispatch_count - d0
    wall += drain_s

    n_units = units["flush"] + units["split"]
    ut = np.asarray(unit_times) * 1e3
    return dict(
        name=f"device_ingest_{'fused' if fused else 'eager'}",
        insert_ops_s=float(n_batches * batch / wall),
        wall_s=float(wall),
        dispatches_per_flush_unit=float(disp["maintain"] / max(n_units, 1)),
        dispatches_per_insert_batch=float(disp["insert"]
                                          / max(disp["insert_batches"], 1)),
        maintain_units=int(n_units),
        flush_units=int(units["flush"]),
        split_units=int(units["split"]),
        drain_units=int(n_units - n_drain_units0),
        maintain_p50_ms=float(np.percentile(ut, 50)) if ut.size else 0.0,
        maintain_p99_ms=float(np.percentile(ut, 99)) if ut.size else 0.0,
        maintain_p100_ms=float(ut.max()) if ut.size else 0.0,
        drain_ms=float(drain_s * 1e3),
    )


def run(n_batches: int = 160, warmup: int = 40, batch: int = 512,
        sigma: int = 1024, f: int = 4, max_nodes: int = 512,
        budget: int = 2, seed: int = 0):
    rows = []
    for fused in (True, False):
        rows.append(_ingest(fused, n_batches=n_batches, warmup=warmup,
                            batch=batch, sigma=sigma, f=f,
                            max_nodes=max_nodes, budget=budget, seed=seed))
    return rows


def check(rows) -> list[str]:
    fu = next(r for r in rows if r["name"].endswith("fused"))
    ea = next(r for r in rows if r["name"].endswith("eager"))
    out = []
    dr = ea["dispatches_per_flush_unit"] / max(fu["dispatches_per_flush_unit"],
                                              1e-9)
    tag = "matches paper" if dr >= 5.0 else "MISMATCH"
    out.append(f"device_ingest: {ea['dispatches_per_flush_unit']:.1f} -> "
               f"{fu['dispatches_per_flush_unit']:.1f} dispatches per flush "
               f"unit ({dr:.1f}x fewer, fused cascade)  [{tag}]")
    ir = fu["insert_ops_s"] / max(ea["insert_ops_s"], 1e-9)
    tag = "matches paper" if ir > 1.0 else "MISMATCH"
    out.append(f"device_ingest: insert rate {ea['insert_ops_s']:.0f} -> "
               f"{fu['insert_ops_s']:.0f} ops/s ({ir:.2f}x, one-dispatch "
               f"flush + incremental Blooms)  [{tag}]")
    br = ea["dispatches_per_insert_batch"] / max(
        fu["dispatches_per_insert_batch"], 1e-9)
    out.append(f"device_ingest: {ea['dispatches_per_insert_batch']:.1f} -> "
               f"{fu['dispatches_per_insert_batch']:.1f} dispatches per "
               f"insert batch ({br:.1f}x fewer)")
    out.append(f"device_ingest: fused maintain-unit p100 "
               f"{fu['maintain_p100_ms']:.1f}ms (p50 "
               f"{fu['maintain_p50_ms']:.1f}ms) over {fu['maintain_units']} "
               f"units")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller run (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/bench_ingest_device.json")
    args = ap.parse_args(argv)
    kwargs = dict(QUICK_KWARGS) if args.quick else {}
    rows = run(seed=args.seed, **kwargs)
    checks = check(rows)
    for r in rows:
        print(r)
    for c in checks:
        print(" ->", c)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "seed": args.seed,
                   "quick": bool(args.quick),
                   "backend": jax.default_backend(),
                   "clock": "wall", "rows": rows, "checks": checks}, f,
                  indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
