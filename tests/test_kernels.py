"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # tier-1 must collect (and run) without hypothesis installed
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref


def _sorted_run(rng, n):
    return np.sort(rng.choice(2**31, n, replace=False)).astype(np.uint32)


# ------------------------------------------------------------------- merge
@pytest.mark.parametrize("n,m", [(1, 1), (128, 128), (100, 57), (1024, 1024),
                                 (3000, 17), (5000, 2500), (8192, 8192)])
def test_merge_matches_ref(rng, n, m):
    ak, bk = _sorted_run(rng, n), _sorted_run(rng, m)
    av = rng.integers(0, 2**31, n).astype(np.int32)
    bv = rng.integers(0, 2**31, m).astype(np.int32)
    ok, ov = ops.merge_sorted(jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv))
    rk, rv = ref.merge_sorted_ref(jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv))
    assert np.array_equal(np.array(ok)[: n + m], np.array(rk)[: n + m])
    assert np.array_equal(np.array(ov)[: n + m], np.array(rv)[: n + m])


def test_merge_tiebreak_a_first():
    ak = np.array([5, 10, 20], np.uint32); av = np.array([1, 2, 3], np.int32)
    bk = np.array([10, 20, 30], np.uint32); bv = np.array([-1, -2, -3], np.int32)
    ok, ov = ops.merge_sorted(jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv))
    assert list(np.array(ok)[:6]) == [5, 10, 10, 20, 20, 30]
    assert list(np.array(ov)[:6]) == [1, 2, -1, 3, -2, -3]


@pytest.mark.parametrize("R,n,m", [(1, 64, 64), (3, 100, 57), (5, 700, 1500),
                                   (8, 1024, 1024)])
def test_merge_batch_matches_per_row(rng, R, n, m):
    """merge_sorted_batch row r == merge_sorted(a[r], b[r]), bit for bit."""
    aks = [_sorted_run(rng, n) for _ in range(R)]
    bks = [_sorted_run(rng, m) for _ in range(R)]
    avs = [rng.integers(0, 2**31, n).astype(np.int32) for _ in range(R)]
    bvs = [rng.integers(0, 2**31, m).astype(np.int32) for _ in range(R)]
    ok, ov = ops.merge_sorted_batch(
        jnp.asarray(np.stack(aks)), jnp.asarray(np.stack(avs)),
        jnp.asarray(np.stack(bks)), jnp.asarray(np.stack(bvs)))
    for r in range(R):
        sk, sv = ops.merge_sorted(jnp.array(aks[r]), jnp.array(avs[r]),
                                  jnp.array(bks[r]), jnp.array(bvs[r]))
        assert np.array_equal(np.array(ok[r])[: n + m], np.array(sk)[: n + m])
        assert np.array_equal(np.array(ov[r])[: n + m], np.array(sv)[: n + m])


def test_merge_batch_empty_run_identity(rng):
    """Merging an all-KEY_MAX (empty) a-run returns b unchanged per row —
    the fused flush relies on this for untouched children."""
    m = 512
    bk = np.stack([_sorted_run(rng, m) for _ in range(3)])
    bv = rng.integers(0, 2**31, (3, m)).astype(np.int32)
    ak = np.full((3, 128), 0xFFFFFFFF, np.uint32)
    av = np.zeros((3, 128), np.int32)
    ok, ov = ops.merge_sorted_batch(jnp.asarray(ak), jnp.asarray(av),
                                    jnp.asarray(bk), jnp.asarray(bv))
    assert np.array_equal(np.array(ok)[:, :m], bk)
    assert np.array_equal(np.array(ov)[:, :m], bv)


def _check_merge_property(n, m, seed):
    rng = np.random.default_rng(seed)
    ak, bk = _sorted_run(rng, n), _sorted_run(rng, m)
    av = np.arange(n, dtype=np.int32); bv = np.arange(m, dtype=np.int32)
    ok, _ = ops.merge_sorted(jnp.array(ak), jnp.array(av), jnp.array(bk), jnp.array(bv))
    ok = np.array(ok)[: n + m]
    assert np.all(ok[:-1] <= ok[1:]), "merge output not sorted"
    assert sorted(ok.tolist()) == sorted(np.concatenate([ak, bk]).tolist())


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 600), m=st.integers(1, 600), seed=st.integers(0, 999))
    def test_merge_property(n, m, seed):
        _check_merge_property(n, m, seed)
else:  # degraded sweep: fixed examples instead of hypothesis search
    @pytest.mark.parametrize("n,m,seed", [
        (1, 1, 0), (37, 256, 1), (600, 599, 2), (128, 128, 3), (512, 1, 4)])
    def test_merge_property(n, m, seed):
        _check_merge_property(n, m, seed)


# ------------------------------------------------------------------ search
@pytest.mark.parametrize("n,q", [(16, 8), (1000, 300), (5000, 2048), (65536, 100)])
def test_search_matches_ref(rng, n, q):
    run = _sorted_run(rng, n)
    vals = np.arange(n, dtype=np.int32)
    queries = np.concatenate([
        rng.choice(run, q // 2), rng.integers(2**31, 2**32 - 2, q - q // 2).astype(np.uint32)])
    f, v, i = ops.sorted_search(jnp.array(run), jnp.array(vals), jnp.array(queries))
    rf, rv, ri = ref.sorted_search_ref(jnp.array(run), jnp.array(vals), jnp.array(queries))
    assert np.array_equal(np.array(f).astype(bool), np.array(rf))
    sel = np.array(f) == 1
    assert np.array_equal(np.array(v)[sel], np.array(rv)[np.array(rf)])


# ------------------------------------------------------------------- bloom
@pytest.mark.parametrize("n,bpk", [(100, 10), (4000, 10), (4000, 16), (20000, 8)])
def test_bloom_no_false_negatives(rng, n, bpk):
    keys = rng.choice(2**31, n, replace=False).astype(np.uint32)
    nbits = -(-n * bpk // (32 * 128)) * 32 * 128
    words = ops.bloom_build(jnp.array(keys), nbits)
    assert np.all(np.array(ops.bloom_probe(words, jnp.array(keys), nbits=nbits)) == 1)


def test_bloom_fp_rate_and_ref_equivalence(rng):
    keys = rng.choice(2**31, 5000, replace=False).astype(np.uint32)
    nbits = -(-5000 * 10 // (32 * 128)) * 32 * 128
    words = ops.bloom_build(jnp.array(keys), nbits)
    neg = rng.integers(2**31, 2**32 - 2, 4000).astype(np.uint32)
    probe = np.array(ops.bloom_probe(words, jnp.array(neg), nbits=nbits))
    assert probe.mean() < 0.05, f"FP rate {probe.mean()}"
    rp = np.array(ref.bloom_probe_ref(words, jnp.array(neg), nbits))
    assert np.array_equal(probe.astype(bool), rp)


# --------------------------------------------------------- paged attention
@pytest.mark.parametrize("B,KVH,G,D,S,MP", [
    (2, 1, 8, 128, 16, 4), (4, 2, 8, 128, 16, 6),
    (1, 4, 4, 64, 8, 3), (3, 2, 16, 256, 32, 2),
])
def test_paged_attention_matches_ref(rng, B, KVH, G, D, S, MP):
    P = MP * 4
    q = jnp.array(rng.normal(size=(B, KVH, G, D)), jnp.float32)
    kp = jnp.array(rng.normal(size=(KVH, P, S, D)), jnp.float32)
    vp = jnp.array(rng.normal(size=(KVH, P, S, D)), jnp.float32)
    bt = jnp.array(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.array(rng.integers(1, MP * S + 1, (B,)), jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, lens)
    rout = ref.paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.array(out), np.array(rout), atol=3e-5, rtol=3e-5)


def test_paged_attention_bf16(rng):
    B, KVH, G, D, S, MP, P = 2, 2, 4, 128, 16, 4, 16
    q = jnp.array(rng.normal(size=(B, KVH, G, D)), jnp.bfloat16)
    kp = jnp.array(rng.normal(size=(KVH, P, S, D)), jnp.bfloat16)
    vp = jnp.array(rng.normal(size=(KVH, P, S, D)), jnp.bfloat16)
    bt = jnp.array(rng.integers(0, P, (B, MP)), jnp.int32)
    lens = jnp.array([64, 17], jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, lens)
    rout = ref.paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.array(out, np.float32), np.array(rout, np.float32),
                               atol=3e-2, rtol=3e-2)
