"""Pallas TPU kernel: Bloom-filter probe (paper Sec. 5.2, query fast path).

The average-query-time guarantee of the NB-tree rests on Bloom probes being
nearly free relative to run searches.  On TPU the probe is a handful of VPU
ops: h rounds of 32-bit multiply-xorshift mixing, one dynamic gather from the
VMEM-resident bit-array, one bit test — all batched over a query tile.

Filter maintenance is two-speed and stays in XLA (kernels/ref.py holds the
production paths as well as the oracles): a from-scratch *build*
(``bloom_build_ref``, OR-scatter over a whole run) runs only when a run row
is rewritten — inside the fused emptying cascade, once per touched child —
while per-insert-batch maintenance is the O(batch) incremental *update*
(``bloom_update_ref``), which ORs only the new keys' bits into the root
filter and is bit-identical to rebuilding over the grown run (the
incremental-Bloom invariant of DESIGN.md §8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BLOOM_MULTS, KEY_MAX32

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES


def _probe_kernel(words_ref, q_ref, out_ref, *, nbits: int, h: int):
    words = words_ref[...].reshape(-1)
    q = q_ref[...]
    hit = jnp.ones(q.shape, jnp.int32)
    for r in range(h):
        x = q.astype(jnp.uint32) * jnp.uint32(BLOOM_MULTS[r])
        x = x ^ (x >> 15)
        x = x * jnp.uint32(0x2C1B3C6D)
        x = x ^ (x >> 12)
        x = x * jnp.uint32(0x297A2D39)
        x = x ^ (x >> 15)
        pos = (x % jnp.uint32(nbits)).astype(jnp.int32)
        w = jnp.take(words, pos // 32, mode="clip")
        bit = (w >> (pos % 32).astype(jnp.uint32)) & jnp.uint32(1)
        hit = hit & bit.astype(jnp.int32)
    out_ref[...] = hit


@functools.partial(jax.jit, static_argnames=("nbits", "h", "interpret"))
def bloom_probe(words, queries, *, nbits: int, h: int = 3, interpret: bool = True):
    """Membership mask (int32, 0/1) for ``queries`` against the bit array."""
    q_raw = queries.shape[0]
    qn = max(TILE, -(-q_raw // TILE) * TILE)
    queries = jnp.pad(queries, (0, qn - q_raw), constant_values=KEY_MAX32)

    nw_raw = words.shape[0]
    nw = max(LANES, -(-nw_raw // LANES) * LANES)
    words = jnp.pad(words, (0, nw - nw_raw))

    kernel = functools.partial(_probe_kernel, nbits=nbits, h=h)
    full = pl.BlockSpec((nw // LANES, LANES), lambda t: (0, 0))
    qspec = pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0))
    out = pl.pallas_call(
        kernel,
        grid=(qn // TILE,),
        in_specs=[full, qspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((qn // LANES, LANES), jnp.int32),
        interpret=interpret,
    )(words.reshape(nw // LANES, LANES), queries.reshape(qn // LANES, LANES))
    return out.reshape(-1)[:q_raw]
