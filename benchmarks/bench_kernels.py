"""Pallas kernel microbench: wall-clock per call (interpret on CPU) vs oracle.

On-TPU numbers are the real target; interpret-mode wall-clock only checks
the kernels aren't pathological and tracks relative regressions.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)
    rows = []
    n = 16384
    ak = jnp.array(np.sort(rng.choice(2**31, n, replace=False)).astype(np.uint32))
    bk = jnp.array(np.sort(rng.choice(2**31, n, replace=False)).astype(np.uint32))
    av = jnp.array(np.arange(n, dtype=np.int32))
    rows.append(dict(name="merge_sorted_16k", us_per_call=_time(ops.merge_sorted, ak, av, bk, av),
                     ref_us=_time(jax.jit(ref.merge_sorted_ref), ak, av, bk, av)))
    q = jnp.array(rng.choice(np.asarray(ak), 4096).astype(np.uint32))
    rows.append(dict(name="sorted_search_16k_q4k",
                     us_per_call=_time(ops.sorted_search, ak, av, q),
                     ref_us=_time(jax.jit(ref.sorted_search_ref), ak, av, q)))
    span = jnp.uint32(2**31 // 64)                     # ~1.5% selectivity
    lo = jnp.array(rng.integers(1, 2**31 - int(span), 512).astype(np.uint32))
    hi = lo + span
    rs = lambda a, b, c, d: ops.range_scan(a, b, c, d, max_results=256)
    rs_ref = jax.jit(lambda a, b, c, d: ref.range_scan_ref(a, b, c, d, 256))
    rows.append(dict(name="range_scan_16k_q512",
                     us_per_call=_time(rs, ak, av, lo, hi),
                     ref_us=_time(rs_ref, ak, av, lo, hi)))
    nbits = -(-n * 10 // (32 * 128)) * 32 * 128
    words = ops.bloom_build(ak, nbits)
    rows.append(dict(name="bloom_probe_4k",
                     us_per_call=_time(lambda w, qq: ops.bloom_probe(w, qq, nbits=nbits), words, q),
                     ref_us=_time(jax.jit(lambda w, qq: ref.bloom_probe_ref(w, qq, nbits)), words, q)))
    B, KVH, G, D, S, MP, P = 4, 2, 8, 128, 16, 8, 64
    qq = jnp.array(rng.normal(size=(B, KVH, G, D)), jnp.float32)
    kp = jnp.array(rng.normal(size=(KVH, P, S, D)), jnp.float32)
    vp = jnp.array(rng.normal(size=(KVH, P, S, D)), jnp.float32)
    bt = jnp.array(rng.integers(0, P, (B, MP)), jnp.int32)
    sl = jnp.full((B,), MP * S, jnp.int32)
    rows.append(dict(name="paged_attention_b4",
                     us_per_call=_time(ops.paged_attention, qq, kp, vp, bt, sl),
                     ref_us=_time(jax.jit(ref.paged_attention_ref), qq, kp, vp, bt, sl)))
    return rows


def check(rows):
    return [f"{r['name']}: kernel(interp)={r['us_per_call']:.0f}us "
            f"oracle={r['ref_us']:.0f}us" for r in rows]
