"""HuBERT X-Large [arXiv:2106.07447; unverified].

48L encoder-only, d_model 1280, 16 heads, d_ff 5120, LayerNorm, gelu.
The conv waveform frontend is a STUB: input_specs provide precomputed
frame embeddings (B, S, d_model).  No decode step (encoder-only) ->
decode_32k / long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504,
    segments=(("encoder", 48),),
    encoder_only=True, mlp_kind="gelu", norm_kind="layer",
)
