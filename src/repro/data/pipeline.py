"""Streaming data pipeline with an NB-tree ingest index.

The training-side application of the paper: examples arrive at a high,
sustained rate (log streams, user events — the paper's Facebook/Nasdaq
motivation) and must be (a) ingested with bounded per-record latency,
(b) deduplicated, (c) queryable for batch assembly — exactly the
insert-intensive + point-query profile the NB-tree targets.

``StreamingIngest`` indexes sample-hash -> store offset in a host NB-tree
(refimpl, zero-cost instance); duplicates are dropped via index queries
before they reach the store.  ``PackedBatches`` draws indexed samples into
fixed (B, S) token batches for the trainer.  Synthetic deterministic data
keeps everything reproducible offline.
"""
from __future__ import annotations

import numpy as np

from ..core.cost_model import CostModel, Device
from ..core.refimpl import NBTree

_NULL = Device("null", 4096, 0.0, 1e18, 1e18)


def _mix64(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(33))) * np.uint64(0xFF51AFD7ED558CCD)
        x = (x ^ (x >> np.uint64(33))) * np.uint64(0xC4CEB9FE1A85EC53)
        return x ^ (x >> np.uint64(33))


def synthetic_documents(n_docs: int, doc_len: int, vocab: int, seed: int = 0):
    """Deterministic token documents (hash-chain PRNG, no RNG state)."""
    base = _mix64(np.arange(n_docs, dtype=np.uint64) + np.uint64(seed * 1_000_003))
    pos = np.arange(doc_len, dtype=np.uint64)
    toks = _mix64(base[:, None] * np.uint64(0x9E3779B97F4A7C15) + pos[None, :])
    return (toks % np.uint64(max(2, vocab - 2))).astype(np.int32) + 1


class StreamingIngest:
    """High-rate ingest with dedup; bounded per-record index latency."""

    def __init__(self, sigma: int = 4096, f: int = 4):
        self.index = NBTree(f=f, sigma=sigma, cost=CostModel(_NULL))
        self.store: list[np.ndarray] = []
        self.dups = 0

    def ingest(self, doc: np.ndarray) -> bool:
        """Returns True if stored, False if deduplicated."""
        key = np.uint64(_mix64(np.asarray(doc[: 32], np.uint64)).sum())
        if self.index.get(key) is not None:
            self.dups += 1
            return False
        self.index.insert(key, len(self.store))
        self.store.append(doc)
        return True

    def __len__(self):
        return len(self.store)

    def get_by_hash(self, key) -> np.ndarray | None:
        off = self.index.get(key)
        return None if off is None else self.store[int(off)]


class PackedBatches:
    """Iterator of {tokens: (B, S)} batches packed from the ingest store."""

    def __init__(self, ingest: StreamingIngest, batch: int, seq_len: int,
                 seed: int = 0):
        self.ingest, self.B, self.S = ingest, batch, seq_len
        self.rng = np.random.default_rng(seed)

    def __iter__(self):
        return self

    def __next__(self):
        docs = self.ingest.store
        if not docs:
            raise StopIteration
        rows = []
        for _ in range(self.B):
            buf = np.empty(0, np.int32)
            while len(buf) < self.S + 1:
                d = docs[int(self.rng.integers(len(docs)))]
                buf = np.concatenate([buf, d])
            rows.append(buf[: self.S + 1])
        toks = np.stack(rows)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
