"""Sharded, async, restartable checkpointing with an NB-tree manifest.

Layout (one directory per run):
  step_<N>/<flat.param.path>.npy       one file per pytree leaf
  manifest.npz + manifest.json          NB-tree-indexed shard manifest

The manifest is a *paper-native* application: checkpoint writes are
insertion-intensive (every step inserts (step, leaf) -> file records,
incremental checkpoints insert only changed leaves) and restores are point
queries/range scans — so the manifest is a host-tier NB-tree
(core/refimpl.NBTree, zero-I/O-cost instance) serialized alongside the data.
Restore at a *different* mesh/topology is supported because leaves are saved
unsharded (test scale) or per-shard with the shard grid recorded; load
re-shards via jax.device_put with the target NamedSharding — this is the
elastic-resize path (distributed/fault_tolerance.py).

Async: ``save(..., blocking=False)`` snapshots to host *and mutates the
manifest* synchronously, then writes files on a daemon thread; ``wait()``
joins, and every reader (``restore``/``latest_step``) waits first, so the
background writer never races a reader.

Atomicity protocol (crash-safe at every point; the crash matrix in
``tests/test_durability.py`` kills inside it):

1. leaves land in ``.tmp_step_<N>/``, each file fsynced, then the dir;
2. the manifest (which proves exactly which leaves step N owns) is written
   to temp names, fsynced, and atomically renamed into place;
3. the step dir is renamed ``.tmp_step_<N>`` -> ``step_<N>`` *after* the
   manifest fsync, and the parent dir is fsynced.

A crash between 2 and 3 leaves a ``.tmp_step_<N>`` the manifest fully
proves: ``__init__`` *rolls it forward* (finishes the rename).  A crash
before 2 leaves an unprovable temp dir: ``__init__`` deletes it.  Either
way ``latest_step()`` — which answers from the manifest, not a directory
listing — only ever names steps that can actually be restored.

``EngineCheckpointer`` keys snapshots by *commit LSN* instead of train
step: the durability subsystem (``repro.wal``, DESIGN.md §9) checkpoints a
storage engine's live table through it and truncates the WAL past the
snapshot LSN.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time as _time
import zlib

import numpy as np

from ..core.cost_model import CostModel, Device
from ..core.refimpl import NBTree
from ..wal.faults import CrashPoint, FaultInjector, reach as _reach

_NULL_DEVICE = Device("null", page_bytes=4096, seek_s=0.0, read_bw=1e18, write_bw=1e18)

#: leaf index occupies the low bits of a manifest key; step the high bits.
_LEAF_BITS = 20


class CheckpointError(RuntimeError):
    """A restore/validation failure (never a bare ``assert`` — those vanish
    under ``python -O`` and turn a corrupt restore into silent bad state)."""


def _flatten(tree):
    import jax
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        path = ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[path] = leaf
    return out


def _key_of(step: int, leaf_idx: int) -> int:
    return (step << _LEAF_BITS) | leaf_idx


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    _fsync_file(path)       # on POSIX a directory fd fsyncs its entries


class Checkpointer:
    def __init__(self, directory: str, *,
                 injector: FaultInjector | None = None, tracer=None):
        self.dir = directory
        self.injector = injector
        # optional repro.obs tracer: wall-clock "checkpoint" spans around
        # each synchronous save (async saves span the snapshot phase only);
        # the sim-clock frontend charges its own spans and passes None.
        self.tracer = tracer
        self._t_origin = _time.perf_counter()
        os.makedirs(directory, exist_ok=True)
        # zero-cost NB-tree (manifest ops are host metadata, not disk sim).
        self.manifest = NBTree(f=4, sigma=1024, cost=CostModel(_NULL_DEVICE),
                               use_bloom=False)
        self.leaf_names: list[str] = []
        self._leaf_idx: dict[str, int] = {}     # O(1) path -> index
        self.known_steps: set[int] = set()      # steps the manifest proves
        #: per-step payload checksums: {step: {leaf_path: crc32}} — WAL
        #: records always had CRCs; these give checkpoint payload files the
        #: same bit-rot detection (verified on restore, audited by scrub()).
        self._crcs: dict[int, dict[str, int]] = {}
        self._thread: threading.Thread | None = None
        self._load_manifest()
        self._cleanup_tmp()

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, *, blocking: bool = True) -> None:
        t_span0 = _time.perf_counter()
        self.wait()
        import jax
        flat = _flatten(tree)

        def to_host(l):
            a = np.asarray(l)
            if a.dtype.kind == "V":  # bf16 etc: store as lossless f32
                a = np.asarray(jax.numpy.asarray(l).astype(jax.numpy.float32))
            return a

        host = {p: to_host(l) for p, l in flat.items()}  # device->host snap

        # manifest mutation happens HERE, in the synchronous snapshot phase:
        # the daemon thread only writes files, so restore()/latest_step()
        # never observe a half-mutated manifest (they also wait() first).
        for path in host:
            if path not in self._leaf_idx:
                self._leaf_idx[path] = len(self.leaf_names)
                self.leaf_names.append(path)
            self.manifest.insert(_key_of(step, self._leaf_idx[path]), step)
        self.known_steps.add(step)
        mkeys, mvals = self._manifest_arrays()
        names = list(self.leaf_names)

        def write():
            self._write(step, host, mkeys, mvals, names)

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        if self.tracer is not None:
            self.tracer.complete("checkpoint", "save",
                                 t_span0 - self._t_origin,
                                 _time.perf_counter() - t_span0,
                                 step=int(step), leaves=len(host),
                                 blocking=bool(blocking))

    def _write(self, step: int, host: dict, mkeys, mvals, names) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        crcs = {}
        for path, arr in host.items():
            fp = os.path.join(tmp, path + ".npy")
            np.save(fp, arr)
            _fsync_file(fp)
            with open(fp, "rb") as f:
                crcs[path] = zlib.crc32(f.read())
        _fsync_dir(tmp)
        _reach(self.injector, CrashPoint.MID_CHECKPOINT)
        self._crcs[step] = crcs
        self._write_manifest_files(step, mkeys, mvals, names)
        _reach(self.injector, CrashPoint.BEFORE_CHECKPOINT_RENAME)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # rename AFTER the manifest fsync
        _fsync_dir(self.dir)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        """Newest step the manifest proves *and* whose data dir exists."""
        self.wait()
        steps = [s for s in self.known_steps
                 if os.path.isdir(os.path.join(self.dir, f"step_{s}"))]
        if not steps:
            # manifest-less legacy layout: fall back to a directory listing.
            steps = [int(d.split("_")[1]) for d in os.listdir(self.dir)
                     if d.startswith("step_") and d.split("_")[1].isdigit()]
        return max(steps) if steps else None

    def restore(self, step: int, like, shardings=None):
        """Rebuild the pytree of ``like`` (shapes/dtypes) from step files.

        ``shardings``: optional pytree of NamedSharding for a (possibly
        different) target mesh — the elastic-resize entry point.

        Raises :class:`CheckpointError` on any validation failure (missing
        manifest record, missing file, shape mismatch) — real exceptions,
        not ``assert``, so ``python -O`` cannot silence a corrupt restore.
        """
        import jax
        self.wait()
        d = os.path.join(self.dir, f"step_{step}")
        flat = _flatten(like)
        host = {}
        for path, leaf in flat.items():
            # manifest point query proves the leaf belongs to this step.
            idx = self._leaf_idx.get(path)
            if idx is None or self.manifest.get(_key_of(step, idx)) is None:
                raise CheckpointError(
                    f"manifest missing {path!r} @ step {step}")
            fp = os.path.join(d, path + ".npy")
            if not os.path.exists(fp):
                raise CheckpointError(f"leaf file missing: {fp}")
            self._verify_leaf(step, path, fp)
            arr = np.load(fp)
            if arr.shape != tuple(leaf.shape):
                raise CheckpointError(
                    f"shape mismatch for {path!r} @ step {step}: "
                    f"saved {arr.shape}, expected {tuple(leaf.shape)}")
            host[path] = arr

        def rebuild(tree, sh_tree):
            flat_kp = jax.tree_util.tree_flatten_with_path(tree)[0]
            leaves = []
            sh_flat = (jax.tree_util.tree_leaves(sh_tree)
                       if sh_tree is not None else [None] * len(flat_kp))
            for (kp, leaf), sh in zip(flat_kp, sh_flat):
                path = ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                                for k in kp)
                arr = host[path]
                if arr.dtype != leaf.dtype:  # bf16 round-trips through f32
                    arr = np.asarray(
                        jax.numpy.asarray(arr).astype(leaf.dtype))
                leaves.append(jax.device_put(arr, sh) if sh is not None
                              else jax.numpy.asarray(arr))
            treedef = jax.tree_util.tree_structure(tree)
            return jax.tree_util.tree_unflatten(treedef, leaves)

        return rebuild(like, shardings)

    # ------------------------------------------------------------ integrity
    def _verify_leaf(self, step: int, path: str, fp: str) -> None:
        """Check ``fp`` against the manifest's recorded CRC32.

        Raises :class:`CheckpointError` naming the offending file on a
        mismatch.  Steps saved before checksums existed have no recorded
        CRC and pass unverified.
        """
        recorded = self._crcs.get(step, {}).get(path)
        if recorded is None:
            return
        with open(fp, "rb") as f:
            actual = zlib.crc32(f.read())
        if actual != recorded:
            raise CheckpointError(
                f"checksum mismatch in {fp} @ step {step}: "
                f"recorded {recorded:#010x}, found {actual:#010x}")

    def scrub(self) -> dict:
        """Verify every payload file of every provable step.

        Returns a JSON-ready audit: per step, the files checked and the
        list of corrupt/missing ones (empty = clean).  Never raises — a
        scrub is an audit, not a restore; callers decide what to do with
        a dirty step (typically: rely on restore's fallback to the
        previous provable step).
        """
        self.wait()
        out = {"steps": {}, "clean": True}
        for step in sorted(self.known_steps):
            d = os.path.join(self.dir, f"step_{step}")
            if not os.path.isdir(d):
                continue
            bad, checked = [], 0
            for path in self._step_leaves(step):
                fp = os.path.join(d, path + ".npy")
                checked += 1
                try:
                    if not os.path.exists(fp):
                        raise CheckpointError(f"leaf file missing: {fp}")
                    self._verify_leaf(step, path, fp)
                except CheckpointError as e:
                    bad.append(str(e))
            out["steps"][str(step)] = {"files": checked, "bad": bad}
            if bad:
                out["clean"] = False
        return out

    # ------------------------------------------------------------- manifest
    def _manifest_arrays(self):
        keys, vals = [], []
        stack = [self.manifest.root]
        while stack:
            n = stack.pop()
            keys.extend(int(k) for k in n.run.live_keys)
            vals.extend(int(v) for v in n.run.live_vals)
            stack.extend(n.children)
        keys.extend(int(k) for k in self.manifest._buf.keys())
        vals.extend(int(v) for v in self.manifest._buf.values())
        return (np.asarray(keys, np.uint64), np.asarray(vals, np.int64))

    def _write_manifest_files(self, step, mkeys, mvals, names) -> None:
        """Atomically replace manifest.npz/.json, fsyncing each."""
        npz, jsn = (os.path.join(self.dir, "manifest.npz"),
                    os.path.join(self.dir, "manifest.json"))
        np.savez(npz + ".tmp.npz", keys=mkeys, vals=mvals)
        # np.savez appends .npz when missing — our temp name keeps it.
        _fsync_file(npz + ".tmp.npz")
        os.replace(npz + ".tmp.npz", npz)
        with open(jsn + ".tmp", "w") as f:
            json.dump({"leaf_names": names, "last_step": step,
                       "crc": {str(s): m
                               for s, m in sorted(self._crcs.items())}}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(jsn + ".tmp", jsn)
        _fsync_dir(self.dir)

    def _load_manifest(self) -> None:
        j = os.path.join(self.dir, "manifest.json")
        z = os.path.join(self.dir, "manifest.npz")
        if not (os.path.exists(j) and os.path.exists(z)):
            return
        meta = json.load(open(j))
        self.leaf_names = list(meta["leaf_names"])
        self._leaf_idx = {p: i for i, p in enumerate(self.leaf_names)}
        # pre-CRC manifests simply have no "crc" block: their files load
        # unverified (legacy), new saves start recording checksums.
        self._crcs = {int(s): {p: int(c) for p, c in m.items()}
                      for s, m in meta.get("crc", {}).items()}
        data = np.load(z)
        for k, v in zip(data["keys"], data["vals"]):
            self.manifest.insert(k, v)
            self.known_steps.add(int(k) >> _LEAF_BITS)
        self.manifest.drain()

    # -------------------------------------------------------------- cleanup
    def _step_leaves(self, step: int) -> list[str]:
        """Leaf names the manifest records for ``step`` (range scan)."""
        lo, hi = _key_of(step, 0), _key_of(step + 1, 0) - 1
        rk, _ = self.manifest.range_query(lo, hi)
        return [self.leaf_names[int(k) & ((1 << _LEAF_BITS) - 1)]
                for k in rk]

    def _cleanup_tmp(self) -> None:
        """Resolve stale ``.tmp_step_*`` dirs left by a crash mid-save.

        A temp dir the manifest fully proves (crash after manifest fsync,
        before rename) is rolled *forward*; anything else is deleted.
        """
        for d in os.listdir(self.dir):
            if not d.startswith(".tmp_step_"):
                continue
            tmp = os.path.join(self.dir, d)
            suffix = d[len(".tmp_step_"):]
            step = int(suffix) if suffix.isdigit() else None
            final = (os.path.join(self.dir, f"step_{step}")
                     if step is not None else None)
            provable = (
                step in self.known_steps and not os.path.exists(final)
                and all(os.path.exists(os.path.join(tmp, n + ".npy"))
                        for n in self._step_leaves(step)))
            if provable:
                os.rename(tmp, final)       # roll forward
            else:
                shutil.rmtree(tmp)          # unprovable half-write
        _fsync_dir(self.dir)


class EngineCheckpointer(Checkpointer):
    """Engine-table snapshots keyed by commit LSN (DESIGN.md §9).

    A snapshot is the engine's *logical* live table
    (:meth:`~repro.core.engine_api.StorageEngine.dump_live`) as two array
    leaves under step ``lsn``; recovery bulk-inserts it into a fresh engine
    and replays the WAL tail (``repro.wal.recovery``).  Inherits the full
    atomicity protocol, so a crash mid-checkpoint can never strand a
    snapshot recovery would half-trust.
    """

    def save_snapshot(self, lsn: int, keys, vals, *,
                      blocking: bool = True) -> None:
        keys = np.ascontiguousarray(keys, np.uint64)
        vals = np.ascontiguousarray(vals, np.int64)
        if keys.shape != vals.shape:
            raise CheckpointError("snapshot keys/vals must be parallel")
        self.save(int(lsn), {"keys": keys, "vals": vals}, blocking=blocking)

    def _load_snapshot(self, lsn: int):
        d = os.path.join(self.dir, f"step_{lsn}")
        out = []
        for name in ("keys", "vals"):
            idx = self._leaf_idx.get(name)
            if idx is None or self.manifest.get(_key_of(lsn, idx)) is None:
                raise CheckpointError(
                    f"snapshot manifest missing {name!r} @ lsn {lsn}")
            fp = os.path.join(d, name + ".npy")
            if not os.path.exists(fp):
                raise CheckpointError(f"snapshot leaf missing: {fp}")
            self._verify_leaf(lsn, name, fp)
            out.append(np.load(fp))
        keys, vals = out
        if keys.shape != vals.shape:
            raise CheckpointError(
                f"snapshot @ lsn {lsn} has mismatched leaves: "
                f"{keys.shape} vs {vals.shape}")
        return int(lsn), keys, vals

    def load_latest_snapshot(self):
        """``(lsn, keys, vals)`` of the newest *valid* snapshot, or None.

        A snapshot that fails validation (bit-rot caught by the CRC, a
        missing leaf) is skipped and the previous provable step is tried —
        replaying a longer WAL tail from an older good snapshot beats
        trusting a corrupt newer one.  Raises the newest step's
        :class:`CheckpointError` only when corruption left *no* loadable
        snapshot at all (silently returning None there would amputate the
        pre-corruption history the caller believes is checkpointed).
        """
        self.wait()
        steps = sorted((s for s in self.known_steps
                        if os.path.isdir(os.path.join(self.dir,
                                                      f"step_{s}"))),
                       reverse=True)
        if not steps:
            return None
        first_err: CheckpointError | None = None
        for lsn in steps:
            try:
                return self._load_snapshot(lsn)
            except CheckpointError as e:
                if first_err is None:
                    first_err = e
        raise first_err
