"""Batched serving example: continuous batching over the NB-tree paged KV.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

sys.argv = ["serve", "--arch", "qwen3-8b", "--reduced", "--requests", "6",
            "--prompt-len", "12", "--max-new", "8", "--max-batch", "3"]
main()
