"""TPU v5e hardware constants for the roofline model (task-specified)."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link
HBM_BYTES = 16 * 2**30        # 16 GiB per chip
# DCN (cross-pod) egress per host is far thinner; used for the "pod" axis.
DCN_BW_PER_HOST = 25e9 / 8    # ~25 Gbit/s -> bytes/s, conservative
