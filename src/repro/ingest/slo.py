"""Per-kind SLO accounting for the open-loop ingest frontend.

End-to-end latency (queueing + service), queue occupancy, shed (admission
rejection) counters and stall attribution are recorded per op kind while
:class:`~repro.ingest.frontend.IngestFrontend` runs, then summarized into a
JSON-ready report.

Stall attribution: the frontend snapshots the engine's pending maintenance
debt (``maintain(0)``) at every commit.  A commit whose *service* time
exceeds ``stall_factor`` times the run's typical commit service time (the
larger of the median and the mean — buffered writes make the median
degenerate to ~0 between avalanches) is a *stall* — the open-loop
signature of a compaction avalanche — and the ops
queued behind it at that moment are the ops whose latency it explains.
The factor is a per-run knob (``FrontendConfig.stall_factor``; module
default :data:`STALL_FACTOR`) and the value used is recorded in the
report's ``stalls`` section, so sweeps run at different thresholds stay
self-describing.
``debt_max`` over the same timeline is the deamortization ledger: a
deamortized engine's debt stays at its per-step bound (0/1 for the refimpl
NB-tree) no matter the offered load, while its queue may still grow; an
amortized engine shows no debt at all because it pays the whole avalanche
synchronously inside one service time.

Percentiles are exact (computed from retained raw samples, not bucket
edges); p99.9 is included because open-loop tails are the whole point.
"""
from __future__ import annotations

import numpy as np

#: default stall threshold: a commit is a "stall" when its service time
#: exceeds this multiple of the run's typical commit service time —
#: max(median, mean), post-hoc, so the threshold is deterministic and
#: scale-free across tiers/devices.  Per-run override:
#: ``FrontendConfig.stall_factor`` -> ``SLOTracker(stall_factor=...)``.
STALL_FACTOR = 8.0

#: log-spaced bucket edges, 1 ns .. ~1000 s, 4 buckets/decade (JSON-sized).
BUCKET_EDGES_S = np.logspace(-9, 3, 49)


def _tail_summary(samples: np.ndarray) -> dict:
    """Exact mean/p50/p99/p99.9/p100 + log-bucket histogram of seconds."""
    a = np.asarray(samples, np.float64)
    if a.size == 0:
        counts = np.zeros(len(BUCKET_EDGES_S) - 1, int)
        pct = {q: 0.0 for q in (50.0, 99.0, 99.9, 100.0)}
        mean = 0.0
    else:
        counts = np.histogram(
            np.clip(a, BUCKET_EDGES_S[0], BUCKET_EDGES_S[-1]),
            BUCKET_EDGES_S)[0]
        pct = {q: float(np.percentile(a, q)) for q in (50.0, 99.0, 99.9, 100.0)}
        mean = float(a.mean())
    return {
        "count": int(a.size),
        "mean_s": mean,
        "p50_s": pct[50.0],
        "p99_s": pct[99.0],
        "p999_s": pct[99.9],
        "p100_s": pct[100.0],
        "bucket_edges_s": [float(e) for e in BUCKET_EDGES_S],
        "bucket_counts": [int(c) for c in counts],
    }


class SLOTracker:
    """Accumulates open-loop measurements; one instance per serving stream.

    The single-stream frontend runs one tracker; the multi-tenant frontend
    (``repro.tenancy``) runs one per tenant plus an aggregate, all sharing
    the run's ``stall_factor``.
    """

    def __init__(self, kinds: tuple = ("insert", "delete", "query", "range"),
                 *, stall_factor: float = STALL_FACTOR):
        assert stall_factor > 1.0
        self.stall_factor = float(stall_factor)
        self._kinds = kinds
        self._e2e: dict = {k: [] for k in kinds}      # end-to-end seconds
        self._queue_delay: list = []                  # admission -> commit
        self._shed: dict = {k: 0 for k in kinds}
        self._commits: list = []   # (t, n_ops, qdepth, service_s, maintain_s, debt)
        self.max_queue_depth = 0

    # ------------------------------------------------------------- recording
    def record_shed(self, kind: str, n: int = 1) -> None:
        self._shed[kind] += int(n)

    def record_queue_depth(self, depth: int) -> None:
        if depth > self.max_queue_depth:
            self.max_queue_depth = int(depth)

    def record_commit(self, *, t_commit: float, kinds, e2e_s, queue_delay_s,
                      qdepth_after: int, service_s: float, maintain_s: float,
                      debt: int) -> None:
        """One group commit: per-op latencies plus the server-side snapshot."""
        for k, lat in zip(kinds, np.asarray(e2e_s, np.float64)):
            self._e2e[k].append(float(lat))
        self._queue_delay.extend(np.asarray(queue_delay_s, np.float64).tolist())
        self._commits.append((float(t_commit), len(kinds), int(qdepth_after),
                              float(service_s), float(maintain_s), int(debt)))

    # ------------------------------------------------------------- reporting
    def report(self, *, offered: dict, t_end: float) -> dict:
        """JSON-ready summary.  ``offered`` maps kind -> ops offered
        (admitted + shed); ``t_end`` is the simulated completion time of the
        last commit (the run's makespan on the open-loop clock)."""
        com = np.asarray(self._commits, np.float64).reshape(-1, 6)
        service_s = com[:, 3] if len(com) else np.zeros(0)
        maintain_s = com[:, 4] if len(com) else np.zeros(0)
        debts = com[:, 5] if len(com) else np.zeros(0)
        qdepths = com[:, 2] if len(com) else np.zeros(0)

        # ---- stall attribution (see module docstring) ---------------------
        med = float(np.median(service_s)) if len(service_s) else 0.0
        typical = max(med, float(service_s.mean())) if len(service_s) else 0.0
        stall_mask = (service_s > self.stall_factor * typical) \
            if typical > 0.0 else np.zeros(len(service_s), bool)
        n_done = int(sum(len(v) for v in self._e2e.values()))
        n_shed = int(sum(self._shed.values()))
        total_busy = float(service_s.sum() + maintain_s.sum())
        return {
            "duration_s": float(t_end),
            "n_offered": int(sum(offered.values())),
            "n_done": n_done,
            "n_shed": n_shed,
            "shed_rate": n_shed / max(1, n_shed + n_done),
            "shed_per_kind": dict(self._shed),
            "offered_per_kind": {k: int(v) for k, v in offered.items()},
            "per_kind_e2e": {k: _tail_summary(v)
                             for k, v in self._e2e.items() if v},
            "queue_delay": _tail_summary(self._queue_delay),
            "queue": {
                "max_depth": int(self.max_queue_depth),
                "mean_depth_at_commit": (float(qdepths.mean())
                                         if len(qdepths) else 0.0),
            },
            "server": {
                "n_commits": int(len(com)),
                "mean_commit_ops": (float(com[:, 1].mean())
                                    if len(com) else 0.0),
                "service_s": float(service_s.sum()),
                "maintain_s": float(maintain_s.sum()),
                "utilization": total_busy / max(t_end, 1e-12),
            },
            "stalls": {
                "stall_factor": self.stall_factor,
                "median_commit_service_s": med,
                "typical_commit_service_s": typical,
                "n_stall_commits": int(stall_mask.sum()),
                "stall_service_s": float(service_s[stall_mask].sum()),
                # ops that were sitting in queue behind a stalled commit —
                # the population whose tail latency the stall explains.
                "ops_queued_behind_stalls": int(qdepths[stall_mask].sum()),
                "debt_max": int(debts.max()) if len(debts) else 0,
                "debt_mean": float(debts.mean()) if len(debts) else 0.0,
            },
        }
