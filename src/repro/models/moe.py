"""Mixture-of-Experts MLP with capacity-based sort dispatch.

Mixtral-style (few large experts) and DeepSeek-MoE-style (fine-grained
routed experts + always-on shared experts) are both expressed here.

Dispatch is the static-shape *capacity* formulation: token->expert
assignments are grouped by a stable sort on expert id, truncated to
``capacity = ceil(tokens * top_k / E * capacity_factor)`` per expert
(overflow tokens drop, standard at scale), and the grouped activations hit
the expert weights as one batched einsum ``ecd,edf->ecf`` — so compiled
FLOPs are tokens x top_k x expert-FFN (the MoE roofline is honest, no
dense-all-experts shortcut).

Sharding: expert-major weights (E, d, d_ff) shard E over the "model" axis
(EP); grouped activations (E, C, d) shard the same way, and GSPMD inserts
the token all-to-all at the gather/scatter boundaries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .layers import _dense_init


def moe_params(key, cfg, dtype):
    d = cfg.d_model
    dff = cfg.d_expert or cfg.d_ff
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(kr, (d, cfg.n_experts), jnp.float32),
        "wi": _dense_init(k1, (cfg.n_experts, d, dff), dtype),
        "wg": _dense_init(k2, (cfg.n_experts, d, dff), dtype),
        "wo": _dense_init(k3, (cfg.n_experts, dff, d), dtype, fan_in=dff),
    }
    if cfg.n_shared_experts:
        dsh = dff * cfg.n_shared_experts
        ka, kb, kc = jax.random.split(ks, 3)
        p["shared"] = {
            "wi": _dense_init(ka, (d, dsh), dtype),
            "wg": _dense_init(kb, (d, dsh), dtype),
            "wo": _dense_init(kc, (dsh, d), dtype, fan_in=dsh),
        }
    return p


def _dp_groups() -> int:
    """Number of data-parallel shards (dispatch groups) on the active mesh."""
    from ..distributed.sharding import mesh_axis_size
    return max(1, mesh_axis_size("data") * mesh_axis_size("pod"))


def moe_mlp(x, p, cfg):
    """x (B, S, d) -> (B, S, d); top-k routing with *grouped* capacity dispatch.

    Tokens are reshaped to (G, N_loc, d) with G = data-parallel shard count,
    and the whole sort/grid/scatter pipeline is batched over G.  With G
    sharded over ("pod","data"), every grouping op is device-local under
    GSPMD (batched gathers/scatters with a sharded batch dim insert no
    collectives), dispatch tensors shrink from (E, N*K/E, d) *global* to
    (G, E, N_loc*K/E, d) *local*, and the only cross-device traffic left is
    the EP/TP partial-sum all-reduce over "model" of the local expert
    outputs — the 423 s -> ~10 s mixtral collective fix of EXPERIMENTS.md
    §Perf iteration 4.
    """
    B, S, d = x.shape
    N = B * S
    E, K = cfg.n_experts, cfg.top_k
    G = _dp_groups()
    if N % G != 0 or (N // G) * cfg.capacity_factor < E:
        G = 1
    NL = N // G                                              # tokens per group
    xf = x.reshape(G, NL, d)
    xf = constrain(xf, "batch", None, None)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (G, NL, E)
    gate, eidx = jax.lax.top_k(logits, K)                    # (G, NL, K)
    gate = jax.nn.softmax(gate, axis=-1)                     # renorm over top-k

    # ---- group (token, k) slots by expert, per data shard ------------------
    flat_e = eidx.reshape(G, NL * K)
    order = jnp.argsort(flat_e, axis=1, stable=True)         # token order kept
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    cap = int(max(1, round(NL * K / E * cfg.capacity_factor)))
    first = jax.vmap(lambda s: jnp.searchsorted(s, s, side="left"))(sorted_e)
    within = jnp.arange(NL * K)[None, :] - first             # rank in group
    keep = within < cap
    dest = sorted_e * cap + jnp.clip(within, 0, cap - 1)
    slot_token = order // K                                  # (G, NL*K)

    grid_token = jnp.full((G, E * cap), NL, jnp.int32)       # NL = padding row
    # dropped slots scatter out-of-bounds and are discarded by mode="drop".
    grid_token = jax.vmap(
        lambda gt, dst, st: gt.at[dst].set(st, mode="drop"))(
        grid_token, jnp.where(keep, dest, E * cap), slot_token.astype(jnp.int32))
    xpad = jnp.concatenate([xf, jnp.zeros((G, 1, d), xf.dtype)], axis=1)
    xg = jnp.take_along_axis(
        xpad, grid_token[..., None], axis=1).reshape(G, E, cap, d)
    xg = constrain(xg, "batch", "experts", None, None)

    # ---- expert FFN (EP shards E over "model" when divisible; otherwise
    # the wi/wo fallback rule shards the FFN hidden dim, DESIGN.md §5) -----
    h = jnp.einsum("gecd,edf->gecf", xg, p["wi"])
    g = jnp.einsum("gecd,edf->gecf", xg, p["wg"])
    yg = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * h, p["wo"])
    yg = constrain(yg, "batch", "experts", None, None).astype(x.dtype)
    yg = yg.reshape(G, E * cap, d)

    # ---- combine back with gate weights, per group --------------------------
    slot_gate = jnp.take_along_axis(gate.reshape(G, NL * K), order, axis=1)
    contrib = jnp.where(keep, slot_gate, 0.0)

    def combine(yg_g, dest_g, keep_g, tok_g, w_g):
        y = jnp.zeros((NL + 1, d), jnp.float32)
        vals = yg_g[jnp.where(keep_g, dest_g, 0)].astype(jnp.float32)
        return y.at[jnp.where(keep_g, tok_g, NL)].add(vals * w_g[:, None])

    y = jax.vmap(combine)(yg, dest, keep, slot_token, contrib)
    out = y[:, :NL].astype(x.dtype)

    if cfg.n_shared_experts:
        sp = p["shared"]
        out = out + (jax.nn.silu(xf @ sp["wg"]) * (xf @ sp["wi"])) @ sp["wo"]
    return out.reshape(B, S, d)


def aux_load_balance_loss(x, p, cfg):
    """Switch-style load-balancing auxiliary loss (returned by train_step)."""
    N = x.shape[0] * x.shape[1]
    logits = (x.reshape(N, -1).astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, -1)
    _, eidx = jax.lax.top_k(logits, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(eidx, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * jnp.mean(probs, 0))
