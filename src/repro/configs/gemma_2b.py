"""Gemma 2B [arXiv:2403.08295; hf].

18L, d_model 2048, 8 heads MQA (kv 1), head_dim 256, GeGLU d_ff 16384,
vocab 256000, tied embeddings.  Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=256000, head_dim=256,
    segments=(("dense", 18),),
    mlp_kind="geglu", tie_embeddings=True,
)
