"""Offered-load saturation sweep: throughput vs tail latency, open loop.

This scenario operationalizes the paper's headline claim — worst-case
insertion delays orders of magnitude below the LSM family — in the only
setting where worst-case delay *matters operationally*: open-loop load,
where every request arrives on its own schedule and a compaction stall
turns into queueing delay for everything behind it (Luo & Carey, "On
Performance Stability in LSM-based Storage Systems").

One Poisson arrival trace per offered rate (same seed, same op content for
every tier — the cross-tier differential) is served through the ingest
frontend (`repro.ingest`, DESIGN.md §7): bounded queue, group commit,
admission control, maintenance interleaved per commit, everything on the
simulated clock, so the emitted JSON is byte-identical across runs.

Expected shape, rising offered load:

* the **LSM tier diverges at its stall point** — end-to-end p99.9/p100
  jump to the compaction-avalanche scale well before mean-throughput
  saturation, then the queue pins at the admission bound and ops shed;
* the **NB-tree tier stays at the deamortized bound** — pending debt never
  exceeds one cascade (the paper's per-step quantum), tails stay near the
  group-commit floor until genuine capacity saturation;
* at some shared offered load NB-tree's insert p99.9 is >= 10x below the
  LSM tier's (the `check` headline);
* the incremental B+-tree saturates earliest (its per-insert random I/O
  bounds capacity — Fig. 6's story in open loop).

The device tier (`jax-nbtree`) runs the same protocol under the
deterministic *virtual* service model (wall-clock measurement cannot be
byte-reproducible; see `repro.ingest.frontend`), so its rows exercise
queueing/admission correctness, not device speed.

Standalone CLI (CI bench-smoke; seed trajectory record at the repo root)::

    PYTHONPATH=src python -m benchmarks.fig_saturation --quick \
        --out runs/fig_saturation.json
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core.cost_model import SSD
from repro.core.engine_api import make_engine
from repro.ingest import FrontendConfig, PoissonArrivals, make_trace, \
    run_open_loop
from repro.workloads import make_workload
from repro.workloads.driver import SCHEMA_VERSION

KEY_SPACE = 1 << 20

#: per-tier configs on the paper's SSD testbed constants; buffers sized so
#: maintenance fires many times inside the measured window.
CONFIGS = {
    "nbtree": dict(f=3, sigma=512, device=SSD),
    "lsm": dict(mem_pairs=512, device=SSD),
    "btree": dict(device=SSD),
    "bepsilon": dict(node_bytes=1 << 16, cached_levels=1, device=SSD),
    # sigma sized for the 16k-pair preload (RUN_CAP must absorb a full
    # flush at the tree's deepest fanout); the device tier runs under the
    # virtual service model, so sigma does not shape its latency rows.
    "jax-nbtree": dict(f=4, sigma=1024, max_nodes=256),
}

#: offered insert-heavy load, ops/second (shared across tiers per point).
RATES = (20_000, 50_000, 100_000, 200_000, 400_000)

#: the wall-clock device tier runs under the virtual service model; one
#: mid-sweep point demonstrates protocol + debt bounds, not device speed.
_DEVICE_RATES = (100_000,)

#: serving-node knobs: queue bound, group-commit size, linger deadline.
FRONTEND = FrontendConfig(max_queue=2048, commit_ops=64, linger_s=2e-4)

#: one source of truth for the smoke-sized sweep (--quick here and in
#: benchmarks/run.py must produce comparable artifacts).
QUICK_KWARGS = dict(tiers=("nbtree", "lsm"), rates=(20_000, 200_000),
                    n_ops=4500, preload=16384)


def _row(tier: str, rate: float, rep: dict) -> dict:
    ol = rep["open_loop"]
    ins = ol["per_kind_e2e"].get("insert", {})
    st = rep["stats"]
    return dict(
        fig="saturation", index=tier, rate=rate, mix="insert-heavy",
        clock=st["clock"], service_model=ol["service_model"],
        utilization=ol["server"]["utilization"],
        n_done=ol["n_done"], n_shed=ol["n_shed"],
        shed_rate=ol["shed_rate"],
        insert_p50_ms=ins.get("p50_s", 0.0) * 1e3,
        insert_p99_ms=ins.get("p99_s", 0.0) * 1e3,
        insert_p999_ms=ins.get("p999_s", 0.0) * 1e3,
        insert_p100_ms=ins.get("p100_s", 0.0) * 1e3,
        max_queue_depth=ol["queue"]["max_depth"],
        n_stall_commits=ol["stalls"]["n_stall_commits"],
        ops_queued_behind_stalls=ol["stalls"]["ops_queued_behind_stalls"],
        debt_max=ol["stalls"]["debt_max"],
        live_pairs=st["total_pairs"],
        bloom_probes=st["bloom_probes"],
        bloom_negative_skips=st["bloom_negative_skips"],
        bloom_false_positives=st["bloom_false_positives"])


def run(tiers=("nbtree", "lsm", "btree", "bepsilon", "jax-nbtree"),
        rates=RATES, n_ops: int = 6000, preload: int = 16384,
        mix: str = "insert-heavy", seed: int = 0):
    rows = []
    for rate in rates:
        wl = make_workload(mix, key_space=KEY_SPACE, n_ops=n_ops,
                           preload=preload, batch_size=256, seed=seed)
        trace = make_trace(wl, PoissonArrivals(rate))
        for tier in tiers:
            if tier == "jax-nbtree" and rate not in _DEVICE_RATES:
                continue
            engine = make_engine(tier, **CONFIGS[tier])
            rep = run_open_loop(engine, trace, config=FRONTEND)
            rows.append(_row(tier, rate, rep))
    return rows


def check(rows) -> list[str]:
    out = []
    nb = {r["rate"]: r for r in rows if r["index"] == "nbtree"}
    lsm = {r["rate"]: r for r in rows if r["index"] == "lsm"}
    shared = sorted(set(nb) & set(lsm))

    # headline: at some offered load NB-tree's p99.9 end-to-end insert
    # latency is >= 10x below the LSM tier's while NB-tree debt stays at
    # the single-engine deamortized bound (one pending cascade).
    hits = [r for r in shared
            if nb[r]["insert_p999_ms"] * 10.0 <= lsm[r]["insert_p999_ms"]
            and nb[r]["debt_max"] <= 1]
    if hits:
        r = hits[0]
        ratio = lsm[r]["insert_p999_ms"] / max(nb[r]["insert_p999_ms"], 1e-12)
        out.append(f"saturation: at {r/1e3:.0f}k ops/s NB-tree p99.9 "
                   f"end-to-end is {ratio:.0f}x below LSM with debt_max="
                   f"{nb[r]['debt_max']} (deamortized bound)  [matches paper]")
    else:
        out.append("saturation: no offered load with NB-tree p99.9 >= 10x "
                   "below LSM at bounded debt  [MISMATCH]")

    # the deamortized bound holds at *every* offered load, saturation included.
    worst_debt = max((r["debt_max"] for r in nb.values()), default=0)
    tag = "matches paper" if worst_debt <= 1 else "MISMATCH"
    out.append(f"saturation: NB-tree pending debt <= 1 cascade at every "
               f"offered load (worst {worst_debt})  [{tag}]")

    # LSM hits its admission wall (sheds) at an offered load NB-tree still
    # serves in full — the stall point arrives first for the LSM tier.
    div = [r for r in shared
           if lsm[r]["n_shed"] > 0 and nb[r]["n_shed"] == 0]
    tag = "matches paper" if div else "MISMATCH"
    at = f"{div[0]/1e3:.0f}k ops/s" if div else "none"
    out.append(f"saturation: LSM sheds load while NB-tree serves every op "
               f"(first at {at})  [{tag}]")

    # differential: tiers that shed nothing applied the same op stream, so
    # they must agree on final live pairs at every shared rate.
    for rate in sorted({r["rate"] for r in rows}):
        full = [r for r in rows if r["rate"] == rate and r["n_shed"] == 0]
        pairs = {r["live_pairs"] for r in full}
        if len(full) >= 2:
            tag = "matches paper" if len(pairs) == 1 else "MISMATCH"
            out.append(f"saturation: no-shed tiers agree on live pairs at "
                       f"{rate/1e3:.0f}k ops/s ({sorted(pairs)})  [{tag}]")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/fig_saturation.json")
    args = ap.parse_args(argv)
    kwargs = dict(QUICK_KWARGS) if args.quick else {}
    rows = run(seed=args.seed, **kwargs)
    checks = check(rows)
    for r in rows:
        print(r)
    for c in checks:
        print(" ->", c)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "seed": args.seed,
                   "quick": bool(args.quick), "rows": rows,
                   "checks": checks}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
