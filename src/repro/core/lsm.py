"""Leveling LSM-tree baseline (paper Secs. 1.2, 7; benchmarked in Figs. 6-9).

Models the LevelDB/RocksDB family: an in-memory memtable of ``mem_pairs``
pairs, on-disk levels of geometrically growing capacity (``ratio`` T), full
level-rewrite merges (leveling policy), and optional per-level Bloom
filters.  ``max_levels`` caps the number of levels to emulate bLSM [42]
(better queries, unbounded component-size ratio => worse inserts).

The worst-case insertion behaviour the paper highlights — a single insert
triggering a cascade that rewrites nearly the whole database, linear in n —
emerges naturally from this implementation and is what Fig. 7 measures
against NB-tree's deamortized logarithmic bound.
"""
from __future__ import annotations

import numpy as np

from .bloom import BloomFilter
from .cost_model import PAIR_BYTES, CostModel, Device, HDD
from .sorted_run import (KEY_DTYPE, TOMBSTONE, VAL_DTYPE, drop_tombstones,
                         merge_runs)


class _Level:
    __slots__ = ("keys", "vals", "bloom")

    def __init__(self):
        self.keys = np.empty(0, KEY_DTYPE)
        self.vals = np.empty(0, VAL_DTYPE)
        self.bloom: BloomFilter | None = None

    def __len__(self):
        return len(self.keys)


class LSMTree:
    def __init__(
        self,
        mem_pairs: int = 4096,
        ratio: int = 10,
        *,
        device: Device = HDD,
        use_bloom: bool = True,
        bits_per_key: int = 10,
        max_levels: int | None = None,
        cost: CostModel | None = None,
    ):
        self.mem_pairs, self.ratio = mem_pairs, ratio
        self.use_bloom, self.bits_per_key = use_bloom, bits_per_key
        self.max_levels = max_levels
        self.cm = cost or CostModel(device)
        self._buf: dict = {}
        self.levels: list[_Level] = []
        self.n_inserted = 0
        # per-level Bloom effectiveness (probes / negative skips / misses
        # after a positive), surfaced through EngineStats like the NB-tree's.
        self.bloom_probes = 0
        self.bloom_negative_skips = 0
        self.bloom_false_positives = 0

    # ---------------------------------------------------------------- inserts
    def insert(self, key, value) -> float:
        with self.cm.measure() as t:
            self._buf[np.uint64(key)] = np.int64(value)
            self.n_inserted += 1
            if len(self._buf) >= self.mem_pairs:
                self._compact()
        return t.seconds

    def delete(self, key) -> float:
        return self.insert(key, TOMBSTONE)

    def _capacity(self, i: int) -> int:
        if self.max_levels is not None and i == self.max_levels - 1:
            return 1 << 62  # bLSM-style last level: unbounded
        return self.mem_pairs * self.ratio ** (i + 1)

    def _compact(self) -> None:
        """Memtable -> L0; cascade full levels downward (leveling merge)."""
        keys = np.fromiter(self._buf.keys(), KEY_DTYPE, len(self._buf))
        vals = np.fromiter(self._buf.values(), VAL_DTYPE, len(self._buf))
        order = np.argsort(keys)
        keys, vals = keys[order], vals[order]
        self._buf = {}

        i = 0
        while True:
            if i >= len(self.levels):
                self.levels.append(_Level())
            lvl = self.levels[i]
            # leveling: read the whole target level, rewrite the merged run.
            self.cm.seek()
            self.cm.read_pairs(len(lvl))
            last = i == len(self.levels) - 1 and (
                self.max_levels is None or i == self.max_levels - 1
            )
            keys, vals = merge_runs(keys, vals, lvl.keys, lvl.vals)
            if last:
                keys, vals = drop_tombstones(keys, vals)
            self.cm.seek()
            self.cm.write_pairs(len(keys))
            lvl.keys, lvl.vals = keys, vals
            if self.use_bloom:
                lvl.bloom = BloomFilter.build(lvl.keys, self.bits_per_key)
            if len(lvl) <= self._capacity(i):
                break
            # level overflows: push its entire contents one level down.
            keys, vals = lvl.keys, lvl.vals
            self.cm.seek()
            self.cm.read_pairs(len(lvl))
            lvl.keys = np.empty(0, KEY_DTYPE)
            lvl.vals = np.empty(0, VAL_DTYPE)
            lvl.bloom = None
            i += 1
            if self.max_levels is not None and i >= self.max_levels:
                i = self.max_levels - 1

    # ---------------------------------------------------------------- queries
    def get(self, key):
        key = np.uint64(key)
        with self.cm.measure() as t:
            val = self._get(key)
        self._last_query_time = t.seconds
        return val

    def query(self, key):
        v = self.get(key)
        return v, self._last_query_time

    def _get(self, key):
        if key in self._buf:
            v = self._buf[key]
            return None if v == TOMBSTONE else v
        for lvl in self.levels:
            if len(lvl) == 0:
                continue
            positive = True
            if self.use_bloom and lvl.bloom is not None:
                self.bloom_probes += 1
                positive = bool(lvl.bloom.contains(np.asarray([key]))[0])
                if not positive:
                    self.bloom_negative_skips += 1
            if positive:
                # fence pointers cached in memory: one seek + one leaf page.
                self.cm.page_read()
                i = int(np.searchsorted(lvl.keys, key))
                if i < len(lvl.keys) and lvl.keys[i] == key:
                    v = lvl.vals[i]
                    return None if v == TOMBSTONE else v
                if self.use_bloom and lvl.bloom is not None:
                    self.bloom_false_positives += 1
        return None

    def range_query(self, lo, hi):
        """Inclusive range scan [lo, hi]; returns (keys, vals) numpy arrays.

        Every level must be scanned (newest first — freshest copy wins, the
        LSM range-query sort-merge): per non-empty overlapping level one
        seek + the sequential transfer of its matching span.  Fence pointers
        are cached in memory, so levels with no overlap cost nothing.
        Bloom filters cannot prune range scans — the LSM read amplification
        the paper's baselines pay on this workload class.
        """
        lo, hi = np.uint64(lo), np.uint64(hi)
        with self.cm.measure() as t:
            result: dict = {}
            if lo <= hi:
                for k, v in self._buf.items():      # keys unique: no order dep
                    if lo <= k <= hi:
                        result[int(k)] = int(v)
                for lvl in self.levels:          # level 0 first = newest
                    if len(lvl) == 0:
                        continue
                    i0 = int(np.searchsorted(lvl.keys, lo, side="left"))
                    i1 = int(np.searchsorted(lvl.keys, hi, side="right"))
                    if i1 <= i0:
                        continue
                    self.cm.seek()
                    self.cm.read_pairs(i1 - i0)
                    for k, v in zip(lvl.keys[i0:i1].tolist(),
                                    lvl.vals[i0:i1].tolist()):
                        if k not in result:
                            result[k] = v
            ks = sorted(k for k, v in result.items() if v != TOMBSTONE)
            out = (np.asarray(ks, KEY_DTYPE),
                   np.asarray([result[k] for k in ks], VAL_DTYPE))
        self._last_query_time = t.seconds
        return out

    def drain(self) -> None:  # API parity with NBTree
        pass

    def total_pairs(self) -> int:
        return len(self._buf) + sum(len(l) for l in self.levels)
