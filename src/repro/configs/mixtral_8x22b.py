"""Mixtral 8x22B [arXiv:2401.04088; hf].

56L, d_model 6144, 48 heads GQA kv 8, 8 experts top-2 (d_expert 16384),
sliding-window attention (window 4096 per the pool spec) -> SWA rolling
ring-cache makes long_500k decode runnable.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768,
    segments=(("moe_swa", 56),),
    n_experts=8, top_k=2, d_expert=16384,
    swa_window=4096, mlp_kind="swiglu", rope_base=1000000.0,
)
