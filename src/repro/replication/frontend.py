"""Replicated open-loop frontend: partitioned serving with failover.

The replicated sibling of :class:`repro.ingest.frontend.IngestFrontend`
(DESIGN.md §12): the key space is range-partitioned into replica groups
(:class:`~repro.replication.replica.ReplicaGroup`), each a primary +
R−1 replicas kept in sync by WAL shipping.  The serving loop runs the
same deterministic sim clock, group commit, and admission control as the
single-engine frontend, plus the failure machinery:

* **Heartbeats** — every live node beats the shared
  :class:`~repro.distributed.fault_tolerance.HeartbeatMonitor` at each
  loop tick (sim time, float).  A node silent past the timeout is
  declared dead exactly once; a dead primary triggers promotion, a dead
  replica a rebuild.
* **Graceful degradation** — ops routed to a group that cannot currently
  commit (dead primary awaiting detection, quorum short a replica,
  promotion replay in flight) are *parked*: retried with exponential
  backoff and shed at a deadline, while every other group keeps serving
  untouched — an unavailable range never head-of-line-blocks the rest.
* **Chaos** — a :class:`~repro.wal.faults.FaultSchedule` fires between
  commits against stable slot addresses (``g0/primary``, ``g1/r0``,
  ``g2`` for group-wide latency spikes), so runs under chaos stay a pure
  function of (trace, config, schedule seed).

Per-group :class:`~repro.obs.metrics.WindowedMetrics` timelines are
always on (they are the availability measurement: the failover benchmark
reads windowed p99.9 through a kill), and the report carries every
failover's RTO decomposition: crash → detected (heartbeat timeout) →
promoted (tail replay) → writes restored (quorum whole again).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.engine_api import OpBatch, OpKind
from repro.distributed.fault_tolerance import HeartbeatMonitor
from repro.ingest.arrivals import ArrivalTrace
from repro.ingest.frontend import FrontendConfig
from repro.ingest.slo import SLOTracker
from repro.obs.metrics import ObsConfig, WindowedMetrics
from repro.obs.trace import Tracer
from repro.shard.partition import RangePartitioner
from repro.wal.faults import FaultSchedule

from .replica import ReplicaGroup, ReplicationConfig

_KIND_NAMES = {int(k): k.name.lower() for k in OpKind}
_WRITE_KINDS = (int(OpKind.INSERT), int(OpKind.DELETE))
_RANGE = int(OpKind.RANGE)


class ReplicatedFrontend:
    """Open-loop serving over replicated range partitions; see module doc."""

    def __init__(self, engine_factory, directory: str, *, groups: int = 4,
                 replication: ReplicationConfig | None = None,
                 config: FrontendConfig | None = None,
                 chaos: FaultSchedule | None = None,
                 obs: ObsConfig | None = None,
                 window_s: float = 0.05, key_hi: int = 1 << 20):
        self._factory = engine_factory
        self.dir = directory
        self.n_groups_requested = int(groups)
        self.rep = replication or ReplicationConfig()
        self.config = config or FrontendConfig()
        self.chaos = chaos
        self.obs = obs if (obs is not None and obs.enabled) else None
        self.tracer = Tracer(capacity=self.obs.trace_capacity) \
            if self.obs is not None else None
        self.window_s = float(window_s)
        self.key_hi = int(key_hi)
        self.monitor = HeartbeatMonitor(
            timeout=self.rep.heartbeat_timeout_s)
        self.partitioner: RangePartitioner | None = None
        self.groups: list[ReplicaGroup] = []
        self._node_of: dict = {}       # node_id -> (group, node)
        #: every acked group commit as ``(gid, lsn, kinds, keys, vals)`` in
        #: ack order — the chaos soak test's oracle feed (a write row is in
        #: here iff its quorum fsync returned, i.e. iff it was acked).
        self.acked: list = []
        self.shed_unavailable = 0

    # -------------------------------------------------------------- topology
    def _bootstrap(self, trace: ArrivalTrace) -> None:
        """Fix the routing table and spawn every group's initial nodes."""
        if len(trace.preload):
            self.partitioner = RangePartitioner.from_sample(
                trace.preload.keys, self.n_groups_requested)
        else:
            ins = trace.ops.keys[np.asarray(trace.ops.kinds)
                                 == int(OpKind.INSERT)]
            if len(ins) >= 2 * self.n_groups_requested:
                self.partitioner = RangePartitioner.from_sample(
                    ins[:4096], self.n_groups_requested)
            else:
                self.partitioner = RangePartitioner.even(
                    self.n_groups_requested, self.key_hi)
        for gid in range(self.partitioner.n_shards):
            lo, hi = self.partitioner.interval(gid)
            g = ReplicaGroup(gid, os.path.join(self.dir, f"g{gid}"),
                             self._factory, self.rep, key_lo=lo, key_hi=hi)
            self.groups.append(g)
            for node in g.nodes:
                self._register_node(g, node)
            if self.chaos is not None:
                for slot in ([f"g{gid}", f"g{gid}/primary"]
                             + [f"g{gid}/r{k}"
                                for k in range(self.rep.replicas - 1)]):
                    self.chaos.register(
                        slot, lambda ev, g=g, s=slot: g.handle_event(ev, s))
        if len(trace.preload):
            gids = self.partitioner.shard_of(trace.preload.keys)
            for gid, g in enumerate(self.groups):
                m = gids == gid
                if not m.any():
                    continue
                sub = OpBatch.inserts(trace.preload.keys[m],
                                      trace.preload.vals[m])
                for node in g.nodes:
                    node.engine.apply(sub)
                    node.engine.drain()

    def _register_node(self, group: ReplicaGroup, node) -> None:
        self._node_of[node.node_id] = (group, node)
        self.monitor.add_host(node.node_id)

    # ------------------------------------------------------------ event pump
    def _tick(self, now: float) -> None:
        """Advance all failure machinery to ``now`` (between commits)."""
        if self.chaos is not None:
            for ev in self.chaos.fire_due(now):
                if self.tracer is not None:
                    self.tracer.instant("chaos", ev.kind.value, ev.t,
                                        target=ev.target, arg=ev.arg)
        for g in self.groups:
            for node in g.nodes:
                if node.alive:
                    self.monitor.beat(node.node_id, now)
        for host in self.monitor.advance(now):
            entry = self._node_of.get(host)
            if entry is None:
                continue
            g, node = entry
            if g.failed or node not in g.nodes:
                continue
            if node is g.primary:
                g.promote(now)
            else:
                g.replace_replica(node, now)
        for g in self.groups:
            # corruption-diverged replicas (alive, out of sync): replace.
            for r in list(g.replicas()):
                if r.alive and not r.synced:
                    g.replace_replica(r, now)
            for rb in g.poll_rebuilds(now):
                self._register_node(g, rb["node"])
                self.monitor.revive(rb["node"].node_id, now)
                if self.tracer is not None:
                    self.tracer.complete(
                        "catchup", "rebuild", rb["t_start"],
                        now - rb["t_start"], gid=g.gid,
                        node=rb["node"].node_id,
                        snapshot_pairs=rb["snapshot_pairs"])
        # write-availability transitions close out failover RTOs.
        for g in self.groups:
            wa = g.write_available(now)
            if not wa and g.pending_down_t is None and not g.failed \
                    and (g.primary is None or not g.primary.alive):
                g.pending_down_t = now
            if wa and g.pending_down_t is not None:
                t0 = g.pending_down_t
                g.pending_down_t = None
                g.downtime_s += now - t0
                for ev in reversed(g.failovers):
                    if ev["t_write_restored"] is None:
                        ev["t_write_restored"] = float(now)
                        ev["rto_s"] = float(now - ev["t_crash"])
                        if self.tracer is not None:
                            self.tracer.complete(
                                "failover", "primary_failover",
                                ev["t_crash"], ev["rto_s"], gid=g.gid,
                                new_primary=ev["new_primary"],
                                replayed_ops=ev["replayed_ops"])
                    break

    def _next_event_time(self, now: float, parked, t_arr, n) -> float | None:
        """Earliest instant anything can change while the queue is empty."""
        cands = []
        if self._i < n:
            cands.append(float(t_arr[self._i]))
        cands.extend(p[1] for p in parked)
        if self.chaos is not None and self.chaos.next_time is not None:
            cands.append(self.chaos.next_time)
        for g in self.groups:
            cands.extend(rb["ready_at"] for rb in g.rebuilds)
            if g.write_blocked_until > now:
                cands.append(g.write_blocked_until)
            for node in g.nodes:
                if not node.alive and node.node_id not in self.monitor.dead:
                    beat = self.monitor.last_beat.get(node.node_id, 0.0)
                    cands.append(beat + self.monitor.timeout)
        future = [c for c in cands if c > now]
        return min(future) if future else None

    # --------------------------------------------------------------- routing
    def _gids_of(self, i: int, kinds, keys, his) -> list[int]:
        if int(kinds[i]) == _RANGE:
            return list(self.partitioner.shards_for_range(int(keys[i]),
                                                          int(his[i])))
        return [int(self._point_gid[i])]

    def _admissible(self, i: int, now: float, kinds, keys, his) -> bool:
        write = int(kinds[i]) in _WRITE_KINDS
        for gid in self._gids_of(i, kinds, keys, his):
            g = self.groups[gid]
            ok = g.write_available(now) if write else g.read_available(now)
            if not ok:
                return False
        return True

    def _doomed(self, i: int, kinds, keys, his) -> bool:
        """True when the op targets a permanently failed group."""
        return any(self.groups[gid].failed
                   for gid in self._gids_of(i, kinds, keys, his))

    # ----------------------------------------------------------------- serve
    def run(self, trace: ArrivalTrace, *, drain: bool = True) -> dict:
        cfg, rep = self.config, self.rep
        self._bootstrap(trace)
        tracker = SLOTracker(stall_factor=cfg.stall_factor)
        gwm = [WindowedMetrics(self.window_s) for _ in self.groups]
        wm = WindowedMetrics(self.obs.window_s, stall_k=self.obs.stall_k,
                             stall_trailing=self.obs.stall_trailing) \
            if self.obs is not None else None

        kinds = np.asarray(trace.ops.kinds)
        keys_a, vals_a, his_a = (trace.ops.keys, trace.ops.vals,
                                 trace.ops.his)
        t_arr = np.asarray(trace.t_arrive, np.float64)
        n = len(kinds)
        self._point_gid = self.partitioner.shard_of(keys_a)
        queue: list[int] = []
        parked: list[list] = []     # [idx, next_t, backoff, park_deadline]
        self._i = 0
        t_free = 0.0

        def admit_until(t: float) -> None:
            i = self._i
            while i < n and t_arr[i] <= t:
                if len(queue) < cfg.max_queue:
                    queue.append(i)
                    tracker.record_queue_depth(len(queue))
                else:
                    tracker.record_shed(_KIND_NAMES[int(kinds[i])])
                i += 1
            self._i = i

        def park(i: int, now: float) -> None:
            parked.append([i, now + rep.retry_backoff_s,
                           rep.retry_backoff_s,
                           now + rep.retry_deadline_s])

        def shed_parked(i: int, now: float) -> None:
            kname = _KIND_NAMES[int(kinds[i])]
            tracker.record_shed(kname)
            self.shed_unavailable += 1
            for gid in self._gids_of(i, kinds, keys_a, his_a):
                gwm[gid].record_shed(now)
            if self.tracer is not None:
                self.tracer.instant("shed", f"unavailable_{kname}", now)

        def retry_parked(now: float) -> None:
            for p in list(parked):
                i, next_t, backoff, deadline = p
                if self._doomed(i, kinds, keys_a, his_a) or \
                        (next_t <= now and now >= deadline):
                    parked.remove(p)
                    shed_parked(i, now)
                elif next_t <= now:
                    if self._admissible(i, now, kinds, keys_a, his_a):
                        parked.remove(p)
                        queue.append(i)
                    else:
                        p[2] = min(backoff * 2, rep.retry_backoff_max_s)
                        p[1] = min(now + p[2], deadline)

        while queue or parked or self._i < n:
            now = t_free
            self._tick(now)
            admit_until(now)
            retry_parked(now)
            if not queue:
                nxt = self._next_event_time(now, parked, t_arr, n)
                if nxt is None:
                    break               # nothing left can ever happen
                t_free = nxt
                continue
            t0 = max(t_free, t_arr[queue[0]])

            # group commit: size or linger deadline, whichever first.
            if len(queue) >= cfg.commit_ops or self._i >= n:
                t_commit = t0
            else:
                deadline = t0 + cfg.linger_s
                need = cfg.commit_ops - len(queue)
                j, got = self._i, 0
                while j < n and t_arr[j] <= deadline and got < need:
                    j, got = j + 1, got + 1
                t_commit = max(t0, t_arr[j - 1]) if got == need else deadline
            admit_until(t_commit)
            self._tick(t_commit)

            # take admissible ops in order; park the rest (their range is
            # down — the queue must not head-of-line-block other ranges).
            take: list[int] = []
            for i in list(queue):
                if len(take) >= cfg.commit_ops:
                    break
                queue.remove(i)
                if self._doomed(i, kinds, keys_a, his_a):
                    shed_parked(i, t_commit)
                elif self._admissible(i, t_commit, kinds, keys_a, his_a):
                    take.append(i)
                else:
                    park(i, t_commit)
            if not take:
                t_free = max(t_commit, self._next_event_time(
                    t_commit, parked, t_arr, n) or t_commit)
                if t_free == t_commit:
                    t_free = t_commit + cfg.linger_s  # no event: idle-spin guard
                continue

            idx = np.asarray(take, np.int64)
            legs: dict[int, list[int]] = {}
            for pos, i in enumerate(take):
                for gid in self._gids_of(i, kinds, keys_a, his_a):
                    legs.setdefault(gid, []).append(pos)
            done = np.full(len(take), t_commit)
            leg_totals, debt_max = [], 0
            for gid, members in legs.items():
                g = self.groups[gid]
                sub_idx = idx[members]
                sub = OpBatch(kinds[sub_idx], keys_a[sub_idx],
                              vals_a[sub_idx], his_a[sub_idx])
                wmask = np.isin(np.asarray(sub.kinds), _WRITE_KINDS)
                wal_s = 0.0
                if wmask.any():
                    lsn, wal_s = g.commit(sub.kinds[wmask],
                                          sub.keys[wmask], sub.vals[wmask])
                    self.acked.append((gid, lsn, sub.kinds[wmask].copy(),
                                       sub.keys[wmask].copy(),
                                       sub.vals[wmask].copy()))
                res = g.apply_primary(sub)
                spike = g.spike(t_commit)
                op_service = np.asarray(res.latency_s, np.float64)
                leg_done = t_commit + spike * (wal_s + np.cumsum(op_service))
                for pos, d in zip(members, leg_done):
                    done[pos] = max(done[pos], d)
                io0 = g.primary.engine.io_time_s()
                debt = g.primary.engine.maintain(cfg.maintain_budget)
                maintain_s = g.primary.engine.io_time_s() - io0
                leg_totals.append(spike * (wal_s + float(op_service.sum()))
                                  + maintain_s)
                debt_max = max(debt_max, int(debt))
                gwm[gid].record(t_commit, done[members] - t_arr[sub_idx],
                                ops=len(members), queue_depth=len(queue),
                                debt=int(debt))
            service_s = max(leg_totals)
            e2e = done - t_arr[idx]
            tracker.record_commit(
                t_commit=t_commit,
                kinds=[_KIND_NAMES[int(k)] for k in kinds[idx]],
                e2e_s=e2e, queue_delay_s=t_commit - t_arr[idx],
                qdepth_after=len(queue), service_s=service_s,
                maintain_s=0.0, debt=debt_max)
            if self.obs is not None:
                self.tracer.complete("commit", "group_commit", t_commit,
                                     service_s, ops=len(idx),
                                     legs=len(legs))
                wm.record(t_commit, e2e, ops=len(idx),
                          queue_depth=len(queue), debt=debt_max)
            t_free = t_commit + service_s

        t_end = t_free
        self._tick(t_end)
        if drain:
            for g in self.groups:
                if g.primary is not None and g.primary.alive:
                    g.primary.engine.drain()

        offered = {name: int((kinds == k).sum())
                   for k, name in _KIND_NAMES.items()}
        report = tracker.report(offered=offered, t_end=t_end)
        report["service_model"] = "charged"
        report["config"] = dataclasses.asdict(cfg)
        failovers = [ev for g in self.groups for ev in g.failovers]
        report["replication"] = {
            "config": dataclasses.asdict(rep),
            "n_groups": len(self.groups),
            "acked_commits": len(self.acked),
            "acked_rows": int(sum(g.acked_rows for g in self.groups)),
            "shed_unavailable": int(self.shed_unavailable),
            "failovers": failovers,
            "failed_groups": [g.gid for g in self.groups if g.failed],
            "lost_acked_rows_failed_groups": int(sum(
                g.acked_rows for g in self.groups if g.failed)),
            "groups": [g.describe() for g in self.groups],
            "availability": [
                {"gid": g.gid, "downtime_s": float(g.downtime_s),
                 "timeline": gwm[g.gid].finish(t_end)}
                for g in self.groups],
        }
        if self.chaos is not None:
            report["replication"]["chaos"] = self.chaos.describe()
        if self.obs is not None:
            block = wm.finish(t_end)
            block["trace"] = {"events": len(self.tracer),
                              "dropped_events": self.tracer.dropped_events,
                              "categories": sorted(
                                  self.tracer.categories())}
            if self.obs.trace_path:
                self.tracer.save(self.obs.trace_path)
                block["trace"]["path"] = self.obs.trace_path
            report["obs"] = block
        return report


def run_replicated(engine_factory, trace: ArrivalTrace, directory: str, *,
                   groups: int = 4,
                   replication: ReplicationConfig | None = None,
                   config: FrontendConfig | None = None,
                   chaos: FaultSchedule | None = None,
                   obs: ObsConfig | None = None,
                   window_s: float = 0.05) -> dict:
    """One-call harness: serve ``trace`` on a replicated ensemble."""
    fe = ReplicatedFrontend(engine_factory, directory, groups=groups,
                            replication=replication, config=config,
                            chaos=chaos, obs=obs, window_s=window_s)
    return fe.run(trace)
