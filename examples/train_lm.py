"""End-to-end LM training example (reduced gemma-2b, NB-tree data ingest).

  PYTHONPATH=src python examples/train_lm.py
Equivalent CLI: python -m repro.launch.train --arch gemma-2b --reduced ...
"""
import sys

from repro.launch.train import main

sys.argv = ["train", "--arch", "gemma-2b", "--reduced", "--steps", "30",
            "--batch", "4", "--seq", "48", "--ckpt-dir", "runs/example_ckpt",
            "--ckpt-every", "10"]
main()
