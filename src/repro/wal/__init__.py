"""Durability subsystem: group-commit WAL, crash points, recovery.

DESIGN.md §9.  Every acked write survives a crash: the ingest frontend
(``repro.ingest.frontend``) appends each group commit's write ops to a
segment-based write-ahead log (:mod:`.log`) and acks only after fsync;
periodic engine-table snapshots (``repro.checkpoint.EngineCheckpointer``)
keyed by commit LSN bound the replay tail; :func:`~.recovery.recover`
rebuilds an engine as snapshot + WAL-tail replay.  :mod:`.faults` is the
crash-point injection harness the fault-injection test matrix kills with.
"""
from .faults import (ChaosEvent, ChaosKind, CrashPoint, FaultInjector,
                     FaultSchedule, SimulatedCrash, flip_wal_byte,
                     tear_wal_tail)
from .log import WalRecord, WriteAheadLog
from .recovery import (CHECKPOINT_SUBDIR, WAL_SUBDIR, RecoveryResult,
                       recover)

__all__ = [
    "ChaosEvent", "ChaosKind", "CrashPoint", "FaultInjector",
    "FaultSchedule", "SimulatedCrash", "flip_wal_byte", "tear_wal_tail",
    "WalRecord", "WriteAheadLog",
    "CHECKPOINT_SUBDIR", "WAL_SUBDIR", "RecoveryResult", "recover",
]
