"""Crash recovery: latest snapshot + WAL-tail replay (DESIGN.md §9).

A durable ingest directory has the layout the durable frontend writes::

    <dir>/wal/wal_<first_lsn>.log          redo log segments
    <dir>/checkpoints/step_<lsn>/...       engine-table snapshots + manifest

:func:`recover` rebuilds a storage engine from it:

1. load the newest *provable* snapshot (``EngineCheckpointer``
   atomicity means a half-written one is invisible) and bulk-insert its
   live table into a fresh engine;
2. open the WAL — which truncates any garbage tail (torn, never-acked
   group commits) as a side effect of validation;
3. replay every record with LSN > snapshot LSN, in LSN order, through the
   normal ``apply`` path (replay is idempotent against the snapshot
   because inserts are blind newest-wins writes and deletes of absent
   keys are no-ops on every tier).

The recovered engine's live table then equals exactly the acked prefix of
the ingest history — zero lost acked writes, zero resurrected unacked
ones — which ``tests/test_durability.py`` checks against a sorted-dict
oracle at every crash point.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core.engine_api import OpBatch, StorageEngine
from repro.core.sorted_run import KEY_DTYPE

from .log import WriteAheadLog

#: subdirectory names the durable frontend and recover() agree on.
WAL_SUBDIR = "wal"
CHECKPOINT_SUBDIR = "checkpoints"


@dataclasses.dataclass
class RecoveryResult:
    """What :func:`recover` rebuilt and how much work it took."""

    engine: StorageEngine
    last_lsn: int               # highest durable commit LSN after recovery
    snapshot_lsn: int           # 0 = recovered from WAL alone
    snapshot_pairs: int
    replayed_commits: int
    replayed_ops: int
    truncated_tail_bytes: int   # torn garbage discarded while opening
    recover_wall_s: float
    #: inclusive encoded-key interval this recovery was scoped to
    #: (None = the whole keyspace; see :func:`recover`'s ``key_range``).
    key_range: tuple | None = None


def recover(directory: str, engine_factory, *,
            key_range: tuple | None = None,
            tracer=None) -> RecoveryResult:
    """Rebuild an engine from ``directory``; see module docstring.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`) records one wall-clock
    ``recovery`` span covering the whole rebuild — snapshot load + WAL
    replay — with the replay counts in its args.

    ``engine_factory`` must build a *fresh, empty* engine configured like
    the one that crashed (same tier/knobs — recovery restores logical
    content, not physical layout).

    ``key_range = (lo, hi)`` (inclusive) scopes recovery to one encoded-key
    interval: the snapshot's live table is filtered to it and WAL replay
    skips every op outside it.  A tenant namespace (``repro.tenancy``) is
    exactly such an interval, so this is single-namespace recovery — one
    tenant's data rebuilt from the shared log without paying for its
    co-tenants' history.  ``last_lsn`` still reports the *global* durable
    watermark (the LSN chain is shared).
    """
    # imported here, not at module top: checkpointer itself imports
    # repro.wal.faults, and a module-level import would close the cycle.
    from repro.checkpoint.checkpointer import EngineCheckpointer

    t0 = time.perf_counter()
    lo = hi = None
    if key_range is not None:
        lo, hi = (int(key_range[0]), int(key_range[1]))
        assert 0 <= lo <= hi
    ckpt = EngineCheckpointer(os.path.join(directory, CHECKPOINT_SUBDIR))
    snap = ckpt.load_latest_snapshot()
    engine = engine_factory()
    snap_lsn, snap_pairs = 0, 0
    if snap is not None:
        snap_lsn, keys, vals = snap
        if key_range is not None:
            m = (keys >= np.uint64(lo)) & (keys <= np.uint64(hi))
            keys, vals = keys[m], vals[m]
        snap_pairs = len(keys)
        if snap_pairs:
            engine.apply(OpBatch.inserts(keys, vals))
            engine.drain()
    wal = WriteAheadLog(os.path.join(directory, WAL_SUBDIR))
    n_commits = n_ops = 0
    for rec in wal.replay(after_lsn=snap_lsn, key_lo=lo, key_hi=hi):
        batch = OpBatch(rec.kinds, rec.keys, rec.vals,
                        np.zeros(len(rec), KEY_DTYPE))
        engine.apply(batch)
        engine.note_applied(rec.lsn)
        n_commits += 1
        n_ops += len(rec)
    engine.note_applied(max(snap_lsn, wal.last_lsn))
    torn = wal.truncated_tail_bytes
    last = max(snap_lsn, wal.last_lsn)
    wal.close()
    wall = time.perf_counter() - t0
    if tracer is not None:
        tracer.complete("recovery", "recover", 0.0, wall,
                        snapshot_lsn=int(snap_lsn), last_lsn=int(last),
                        replayed_commits=int(n_commits),
                        replayed_ops=int(n_ops),
                        truncated_tail_bytes=int(torn))
    return RecoveryResult(
        engine=engine, last_lsn=last, snapshot_lsn=snap_lsn,
        snapshot_pairs=snap_pairs, replayed_commits=n_commits,
        replayed_ops=n_ops, truncated_tail_bytes=torn,
        recover_wall_s=wall, key_range=key_range)
