"""StarCoder2-3B [arXiv:2402.19173; hf].

30L, d_model 3072, 24 heads GQA kv 2, d_ff 12288 (gelu MLP), RoPE.
Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152,
    segments=(("dense", 30),),
    mlp_kind="gelu", rope_base=100000.0, norm_kind="layer",
)
