"""Sharded storage layer: partitioned ensembles of any registered engine.

See DESIGN.md §6.  ``make_engine("sharded:<base>", shards=N)`` (registry
prefix handled by ``repro.core.engine_api``) or construct
:class:`ShardedEngine` directly.
"""
from .engine import ShardedEngine
from .partition import HashPartitioner, RangePartitioner
from .scheduler import DebtScheduler

__all__ = ["ShardedEngine", "RangePartitioner", "HashPartitioner",
           "DebtScheduler"]
