"""Render dry-run JSONL records as the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report runs/dryrun_baseline.jsonl [--mesh single]

``--measure`` switches from analytic (dry-run artifact) mode to the
*empirical* side of the roofline: it drives the device NB-tree with a
tracer attached (DESIGN.md §11), collects per-kernel dispatch wall
timings + argument/result byte footprints, and prints measured achieved
bandwidth per kernel against the peak-HBM line::

  PYTHONPATH=src python -m benchmarks.roofline_report --measure --ops 4096

With a positional path, ``--measure`` instead reads ``dispatch_stats``
from that JSON report (any file carrying a ``dispatch_stats`` block).
"""
from __future__ import annotations

import argparse
import json


def load(path, mesh=None):
    recs = [json.loads(l) for l in open(path)]
    if mesh:
        recs = [r for r in recs if r.get("mesh_kind") == mesh]
    return recs


MOVE_HINT = {
    "compute": "raise arithmetic intensity (fuse, larger tiles/microbatch)",
    "memory": "cut HBM traffic (blockwise attn, bf16 streams, in-place cache)",
    "collective": "cut wire bytes (local dispatch, sharded weights, int8 DCN)",
}


def table(recs):
    lines = [
        "| mesh | arch | shape | peak GiB | t_comp s | t_mem s | t_coll s "
        "| bottleneck | MODEL_FLOPs/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        tmax = max(ro["t_compute"], ro["t_memory"], ro["t_collective"], 1e-12)
        frac = ro["t_compute"] / tmax
        lines.append(
            f"| {r['mesh_kind']} | {r['arch']} | {r['shape']} "
            f"| {r['memory_analysis']['peak_gib']:.2f} "
            f"| {ro['t_compute']:.4f} | {ro['t_memory']:.4f} "
            f"| {ro['t_collective']:.4f} | {ro['bottleneck']} "
            f"| {min(ro['useful_flops_ratio'], 9.99):.3f} | {frac*100:.1f}% |")
    skips = [r for r in recs if r["status"].startswith("skip")]
    if skips:
        lines.append("")
        lines.append("Skipped cells (per assignment rules):")
        for r in sorted({(r["arch"], r["shape"], r["status"]) for r in skips}):
            lines.append(f"* {r[0]} x {r[1]} — {r[2]}")
    return "\n".join(lines)


def bottleneck_summary(recs):
    out = []
    for r in recs:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        out.append(f"* {r['arch']} x {r['shape']} [{r['mesh_kind']}]: "
                   f"{ro['bottleneck']}-bound -> {MOVE_HINT[ro['bottleneck']]}")
    return "\n".join(out)


def _find_dispatch_stats(obj):
    """Depth-first search for a ``dispatch_stats`` block in a report."""
    if isinstance(obj, dict):
        ds = obj.get("dispatch_stats")
        if isinstance(ds, dict) and ds:
            return ds
        for v in obj.values():
            found = _find_dispatch_stats(v)
            if found:
                return found
    elif isinstance(obj, list):
        for v in obj:
            found = _find_dispatch_stats(v)
            if found:
                return found
    return None


def measure(path=None, *, ops=4096, batch=256, trace_out=None):
    """Measured per-kernel table: live device run, or a saved report."""
    from repro.obs.trace import Tracer
    from repro.roofline.analysis import measured_kernel_table
    from repro.roofline import hardware as hw

    if path is not None:
        stats = _find_dispatch_stats(json.load(open(path)))
        if not stats:
            raise SystemExit(f"{path}: no dispatch_stats block found "
                             "(run with a tracer attached)")
    else:
        import numpy as np
        from repro.core.engine_api import make_engine

        eng = make_engine("jax-nbtree", f=4, sigma=512, max_nodes=4096)
        tracer = Tracer()
        eng.attach_tracer(tracer)
        rng = np.random.default_rng(0)
        from repro.core.engine_api import OpBatch
        for i in range(0, ops, batch):
            keys = rng.integers(1, 1 << 40, size=batch, dtype=np.uint64)
            eng.apply(OpBatch.inserts(keys, keys))
            eng.maintain(4)
        eng.drain()
        stats = eng.idx.dispatch_stats
        if trace_out:
            tracer.save(trace_out)
            print(f"wrote {trace_out}")

    rows = measured_kernel_table(stats)
    print(f"Measured kernel bandwidth (peak HBM {hw.HBM_BW/1e9:.0f} GB/s):")
    print("| kernel | dispatches | wall s | MiB moved | achieved GB/s "
          "| % of peak |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['kernel']} | {r['count']} | {r['wall_s']:.4f} "
              f"| {r['bytes']/2**20:.2f} | {r['achieved_gb_s']:.3f} "
              f"| {r['peak_frac']*100:.2f}% |")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default=None)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--hints", action="store_true")
    ap.add_argument("--measure", action="store_true",
                    help="measured per-kernel bandwidth from tracer "
                         "dispatch stats (live device run, or a report "
                         "file carrying dispatch_stats)")
    ap.add_argument("--ops", type=int, default=4096,
                    help="--measure live mode: inserts to drive")
    ap.add_argument("--trace-out", default=None,
                    help="--measure live mode: also save the dispatch "
                         "span trace here (Chrome trace_event JSON)")
    args = ap.parse_args()
    if args.measure:
        measure(args.path, ops=args.ops, trace_out=args.trace_out)
        return
    if args.path is None:
        ap.error("path required unless --measure")
    recs = load(args.path, args.mesh)
    print(table(recs))
    if args.hints:
        print()
        print(bottleneck_summary(recs))


if __name__ == "__main__":
    main()
