"""Fig. 4 (a)/(b): average query / insertion time vs s-tree fanout f.

Paper finding: small sigma -> larger f improves queries (shorter tree,
fewer Bloom probes); large sigma -> f has little query benefit; insertion
time grows with f (flush fans out to f children).
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import HDD
from repro.core.engine_api import make_engine

from .common import insert_all, query_sample, scaled_device, workload


def run(n: int = 120_000):
    keys = workload(n)
    rows = []
    for sigma in (1024, 8192):                 # "small" vs "large" sigma
        for f in (3, 5, 9, 15):
            nb = make_engine("nbtree", f=f, sigma=sigma,
                             device=scaled_device(HDD, sigma))
            avg_ins, _ = insert_all(nb, keys)
            nb.drain()
            avg_q, _ = query_sample(nb, keys)
            rows.append(dict(fig="4", sigma=sigma, f=f,
                             avg_insert_us=avg_ins * 1e6,
                             avg_query_ms=avg_q * 1e3,
                             height=nb.height()))
    return rows


def check(rows) -> list[str]:
    """Assertions mirroring the paper's Fig. 4 findings."""
    out = []
    small = {r["f"]: r for r in rows if r["sigma"] == 1024}
    if small[15]["avg_query_ms"] < small[3]["avg_query_ms"]:
        out.append("fig4a: small-sigma query improves with f  [matches paper]")
    else:
        out.append("fig4a: small-sigma query did NOT improve with f  [MISMATCH]")
    for sigma in (1024, 8192):
        sel = {r["f"]: r for r in rows if r["sigma"] == sigma}
        if sel[15]["avg_insert_us"] > sel[3]["avg_insert_us"]:
            out.append(f"fig4b sigma={sigma}: insertion worsens with f  [matches paper]")
        else:
            out.append(f"fig4b sigma={sigma}: insertion did not worsen with f  [MISMATCH]")
    return out
