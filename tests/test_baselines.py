"""LSM / B+-tree / B^eps baselines: correctness + the paper's comparative claims."""
import numpy as np
import pytest

from repro.core.bepsilon import BEpsilonTree
from repro.core.btree import BPlusTree, BPlusTreeBulk
from repro.core.lsm import LSMTree
from repro.core.refimpl import NBTree


def _keys(rng, n):
    return rng.choice(np.arange(1, 10_000_000, dtype=np.uint64), n, replace=False)


@pytest.mark.parametrize("cls,kw", [
    (LSMTree, dict(mem_pairs=256)),
    (BPlusTree, {}),
    (BEpsilonTree, dict(node_bytes=1 << 14, cached_levels=1)),
])
def test_baseline_roundtrip(rng, cls, kw):
    keys = _keys(rng, 4000)
    idx = cls(**kw)
    for i, k in enumerate(keys):
        idx.insert(k, i)
    for i in [0, 99, 1234, 3999]:
        assert idx.get(keys[i]) == i, cls.__name__
    for k in rng.integers(10_000_001, 2**63, 50).astype(np.uint64):
        assert idx.get(k) is None


def test_lsm_delete(rng):
    keys = _keys(rng, 2000)
    lsm = LSMTree(mem_pairs=256)
    for i, k in enumerate(keys):
        lsm.insert(k, i)
    for k in keys[:50]:
        lsm.delete(k)
    assert all(lsm.get(k) is None for k in keys[:50])
    assert lsm.get(keys[60]) == 60


def test_bulk_btree_query(rng):
    keys = _keys(rng, 5000)
    bt = BPlusTreeBulk(keys, np.arange(5000, dtype=np.int64))
    for i in [0, 4999, 777]:
        assert bt.get(keys[i]) == i


@pytest.mark.parametrize("seed", range(3))
def test_bepsilon_range_query_oracle(seed):
    """Differential: B^eps inclusive range scans vs a sorted-dict oracle.

    Random insert/delete interleavings at a node size small enough to force
    multi-level flushes and splits, checked at several interleaving points
    so in-buffer, in-flight and in-leaf copies (and tombstones at every
    level) are all exercised; includes empty, inverted and point ranges.
    """
    rng = np.random.default_rng(seed)
    be = BEpsilonTree(node_bytes=1 << 12, cached_levels=1, fanout=4)
    model: dict = {}
    keyspace = 20_000
    for step in range(6):
        ins = rng.integers(1, keyspace, 400).astype(np.uint64)
        for i, k in enumerate(ins):
            be.insert(k, step * 1000 + i)
            model[int(k)] = step * 1000 + i
        if model and step % 2:
            dels = rng.choice(sorted(model), 60)
            for k in dels:
                be.delete(np.uint64(k))
                model.pop(int(k), None)
        ranges = [(1, keyspace), (keyspace // 2, keyspace // 3)]  # full, empty
        if model:
            p = int(rng.choice(sorted(model)))
            ranges.append((p, p))                                 # point hit
        for _ in range(4):
            lo = int(rng.integers(1, keyspace))
            ranges.append((lo, lo + int(rng.integers(0, keyspace // 3))))
        for lo, hi in ranges:
            rk, rv = be.range_query(lo, hi)
            ek = sorted(k for k in model if lo <= k <= hi)
            assert rk.tolist() == ek, (step, lo, hi)
            assert rv.tolist() == [model[k] for k in ek], (step, lo, hi)


def test_bepsilon_range_query_charges_io():
    """Range scans below the cached levels must charge seeks + transfers."""
    be = BEpsilonTree(node_bytes=1 << 12, cached_levels=0, fanout=4)
    for i in range(2000):
        be.insert(np.uint64(i * 7 + 1), i)
    before = be.cm.time
    rk, _ = be.range_query(1, 7 * 2000)
    assert len(rk) == 2000
    assert be.cm.time > before
    assert be._last_query_time > 0.0


def test_paper_claim_nb_worst_case_far_below_lsm(rng):
    """Fig. 7: NB-tree max insertion time orders of magnitude below LSM."""
    keys = _keys(rng, 40_000)
    nb = NBTree(f=3, sigma=1024)
    lsm = LSMTree(mem_pairs=1024)
    t_nb = max(nb.insert(k, i) for i, k in enumerate(keys))
    t_lsm = max(lsm.insert(k, i) for i, k in enumerate(keys))
    assert t_nb * 100 < t_lsm, (t_nb, t_lsm)


def test_paper_claim_nb_avg_insert_below_btree(rng):
    """Table 2 : NB-tree amortized insertion far below B+-tree's."""
    keys = _keys(rng, 20_000)
    nb = NBTree(f=3, sigma=1024)
    bt = BPlusTree()
    for i, k in enumerate(keys):
        nb.insert(k, i)
        bt.insert(k, i)
    nb.drain()
    nb_avg = nb.cm.time / len(keys)
    bt_avg = bt.cm.time / len(keys)
    assert nb_avg * 10 < bt_avg, (nb_avg, bt_avg)


def test_paper_claim_nb_query_near_bulk_btree(rng):
    """Fig. 8: NB-tree average query within ~2x of bulk-loaded B+-tree."""
    keys = _keys(rng, 30_000)
    nb = NBTree(f=3, sigma=2048)
    for i, k in enumerate(keys):
        nb.insert(k, i)
    nb.drain()
    bt = BPlusTreeBulk(keys, np.arange(len(keys), dtype=np.int64))
    q = rng.choice(keys, 400, replace=False)
    nb_t = np.mean([nb.query(k)[1] for k in q])
    bt_t = np.mean([bt.query(k)[1] for k in q])
    assert nb_t < 2.0 * bt_t, (nb_t, bt_t)
