"""Jittable serving steps (prefill and decode) used by the engine and dryrun."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import transformer as T


def make_prefill_step(cfg, cache_len: int):
    """(params, tokens|embeds) -> (last_logits (B,1,V), cache)."""
    def prefill(params, batch):
        kw = {"embeds": batch["embeds"]} if cfg.encoder_only else {"tokens": batch["tokens"]}
        logits, _aux, cache = T.forward(params, cfg, build_cache_len=cache_len,
                                        last_logit_only=True, **kw)
        return logits, cache
    return prefill


def make_encode_step(cfg):
    """Encoder-only archs: full-sequence forward (no cache, no decode)."""
    def encode(params, batch):
        logits, _aux = T.forward(params, cfg, embeds=batch["embeds"])
        return logits
    return encode


def make_serve_step(cfg):
    """One decode step: (params, cache, tokens (B,), index) -> (next, cache).

    Greedy argmax here; the engine layer samples (serve/engine.py).
    """
    def serve_step(params, cache, tokens, index):
        logits, new_cache = T.decode_step(params, cfg, tokens, cache, index)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return serve_step
