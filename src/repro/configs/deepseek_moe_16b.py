"""DeepSeek-MoE 16B [arXiv:2401.06066; hf].

28L, d_model 2048, 16 heads (kv 16 = MHA), fine-grained MoE: 64 routed
experts (d_expert 1408) top-6 + 2 shared experts; layer 0 is a dense MLP
(d_ff 10944) per the released config.  Full attention -> long_500k skipped.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab=102400,
    segments=(("dense", 1), ("moe", 27)),
    n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
    mlp_kind="swiglu", rope_base=10000.0,
)
