"""Consistent snapshot reads across shards mid-cascade (DESIGN.md §10).

A snapshot is a read view frozen at a *commit-LSN watermark*: every write
acked at or below the watermark is visible, nothing later is, no matter
how many group commits, emptying cascades, or hot-shard splits happen
while the snapshot is held.

Why this is cheap on this stack: ``dump_live()`` is maintenance-invariant
— cascades, merges and shard splits move pairs between physical levels
but never change the logical live table, which always equals the applied
prefix of the commit history.  So a snapshot pinned on the group-commit
boundary (after ``apply``, before the next commit) is exactly the prefix
``<= watermark`` — *including across shards*, because the sharded
engine's ``dump_live`` stitches per-shard tables that all sit at the same
applied prefix.  The pin therefore just materializes the key-sorted live
table (optionally one tenant's interval of it) into immutable arrays; no
coordination with maintenance is needed, and maintenance proceeds freely
underneath — the differential tests in ``tests/test_tenancy.py`` drive
cascades between pin and read to check exactly that.

Reads against a pinned :class:`Snapshot` are binary searches over the
frozen arrays; the engine is never touched after the pin.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sorted_run import KEY_DTYPE


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """An immutable read view frozen at ``watermark_lsn``."""

    snap_id: int
    watermark_lsn: int
    pinned_at_s: float            # sim-clock instant of the pin
    keys: np.ndarray              # uint64, key-sorted, frozen
    vals: np.ndarray              # int64
    key_range: tuple | None = None   # inclusive scope (None = whole keyspace)

    def __post_init__(self):
        self.keys.setflags(write=False)
        self.vals.setflags(write=False)

    def __len__(self) -> int:
        return len(self.keys)

    def query(self, keys) -> tuple:
        """Point reads: ``(found: bool[n], vals: int64[n])`` at the pin."""
        q = np.asarray(keys, KEY_DTYPE)
        if len(self.keys) == 0:
            return np.zeros(len(q), bool), np.zeros(len(q), np.int64)
        idx = np.searchsorted(self.keys, q, "left")
        idx_c = np.minimum(idx, len(self.keys) - 1)
        found = (idx < len(self.keys)) & (self.keys[idx_c] == q)
        vals = np.where(found, self.vals[idx_c], 0).astype(np.int64)
        return found, vals

    def range(self, lo: int, hi: int) -> tuple:
        """Inclusive range scan ``[lo, hi]`` at the pin: ``(keys, vals)``."""
        a = int(np.searchsorted(self.keys, np.asarray(lo, KEY_DTYPE), "left"))
        b = int(np.searchsorted(self.keys, np.asarray(hi, KEY_DTYPE),
                                "right"))
        return self.keys[a:b], self.vals[a:b]


class SnapshotManager:
    """Pin/release ledger over one engine; see module docstring.

    The caller (the multi-tenant frontend) must invoke :meth:`pin` only on
    a group-commit boundary — that placement, not anything this class
    does, is what makes the watermark exact.
    """

    def __init__(self, engine):
        self.engine = engine
        self._next_id = 1
        self._active: dict[int, Snapshot] = {}
        self.pins = 0
        self.releases = 0
        self.pinned_pairs_max = 0

    def pin(self, watermark_lsn: int, now_s: float = 0.0, *,
            key_range: tuple | None = None) -> Snapshot:
        """Freeze the live table (or one key interval of it) right now."""
        if key_range is None:
            keys, vals = self.engine.dump_live()
        else:
            lo, hi = int(key_range[0]), int(key_range[1])
            assert 0 <= lo <= hi
            keys, vals = self.engine.dump_live_range(lo, hi)
        snap = Snapshot(self._next_id, int(watermark_lsn), float(now_s),
                        np.ascontiguousarray(keys, KEY_DTYPE),
                        np.ascontiguousarray(vals, np.int64), key_range)
        self._active[snap.snap_id] = snap
        self._next_id += 1
        self.pins += 1
        self.pinned_pairs_max = max(
            self.pinned_pairs_max,
            sum(len(s) for s in self._active.values()))
        return snap

    def release(self, snap: Snapshot | int) -> None:
        sid = snap if isinstance(snap, int) else snap.snap_id
        assert sid in self._active, f"snapshot {sid} not active"
        del self._active[sid]
        self.releases += 1

    @property
    def active(self) -> list[Snapshot]:
        return [self._active[k] for k in sorted(self._active)]

    def stats(self) -> dict:
        return {
            "pins": self.pins,
            "releases": self.releases,
            "active": len(self._active),
            "active_pairs": sum(len(s) for s in self._active.values()),
            "pinned_pairs_max": self.pinned_pairs_max,
        }
