"""Open-loop ingest frontend: bounded queue, group commit, simulated clock.

This is the serving layer between workload generation and the storage
engines (DESIGN.md §7).  A closed-loop driver asks "how long does an op
take once the engine starts it?"; an open-loop frontend asks the question
the paper's worst-case-delay claim is actually about: *what latency does a
request experience when it arrives on its own schedule* — queueing behind
a compaction stall included.

:class:`IngestFrontend` simulates a single-server ingest node on a
deterministic clock:

* **Arrivals** come from an :class:`~repro.ingest.arrivals.ArrivalTrace`
  (timestamped ops).  An op is *admitted* if the bounded ingest queue has
  room at its arrival instant, else it is **shed** (admission control —
  the knob that trades availability for bounded memory and bounded tail).
* **Group commit**: the server coalesces queued ops into an
  :class:`~repro.core.engine_api.OpBatch` of up to ``commit_ops``,
  lingering at most ``linger_s`` past the moment it could first serve
  (classic group commit: size *or* deadline, whichever first).  Arrival
  order is preserved, so the protocol's sequential batch semantics match
  the trace's logical order.
* **Service** is charged from the engine's own accounting: on cost-model
  tiers (``clock == "sim"``) a batch's service time is the sum of its
  per-op simulated latencies and maintenance time is the engine's charged
  I/O delta — so the whole run is a pure function of (trace, engine
  config) and two runs produce byte-identical reports.  On the wall-clock
  device tier, real measurements are nondeterministic by nature, so the
  clock instead uses a fixed *virtual* per-op service time
  (``virtual_op_service_s``); device rows exercise the full protocol and
  queueing math deterministically, while their absolute latencies are the
  surrogate model's, flagged ``service_model: "virtual"`` in reports.
* **Maintenance** is interleaved once per commit — ``maintain(budget)``
  on the simulated clock, exactly like the closed-loop driver — and the
  engine's pending-debt snapshot is recorded at every commit, which is
  what lets :mod:`repro.ingest.slo` attribute tail latency to stalls and
  verify the deamortized debt bound under load.

End-to-end latency of op *i* = (commit time + its share of batch service)
- arrival time = queueing + service; the SLO tracker reports exact
p50/p99/p99.9/p100 per kind plus queue/shed/stall accounting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine_api import OpBatch, OpKind, StorageEngine

from .arrivals import ArrivalTrace
from .slo import SLOTracker

_KIND_NAMES = {int(k): k.name.lower() for k in OpKind}


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Serving-node knobs (defaults sized for benchmark-scale traces)."""

    max_queue: int = 4096          # admission-control bound (ops)
    commit_ops: int = 64           # group-commit size cap
    linger_s: float = 1e-3         # group-commit deadline past first-servable
    maintain_budget: int = 1       # maintenance units interleaved per commit
    #: deterministic surrogate service time per op for wall-clock engines
    #: (see module docstring); ignored on sim tiers.
    virtual_op_service_s: float = 5e-6

    def __post_init__(self):
        assert self.max_queue >= 1 and self.commit_ops >= 1
        assert self.commit_ops <= self.max_queue, \
            "a commit cannot exceed the queue bound"
        assert self.linger_s >= 0.0 and self.maintain_budget >= 0
        assert self.virtual_op_service_s > 0.0


class IngestFrontend:
    """Single-server open-loop serving simulation over one engine."""

    def __init__(self, engine: StorageEngine, config: FrontendConfig | None = None):
        self.engine = engine
        self.config = config or FrontendConfig()
        # the engine self-reports its clock domain via stats(); adapters set
        # a class attribute, so probing one snapshot is cheap and universal.
        self.sim_clock = engine.stats().clock == "sim"

    # ----------------------------------------------------------------- running
    def run(self, trace: ArrivalTrace, *, drain: bool = True) -> dict:
        """Serve ``trace``; returns the JSON-ready open-loop report."""
        cfg = self.config
        eng = self.engine
        tracker = SLOTracker()

        # load phase: closed-loop, before the clock starts (not offered load).
        if len(trace.preload):
            eng.apply(trace.preload)
            eng.drain()

        kinds = np.asarray(trace.ops.kinds)
        t_arr = np.asarray(trace.t_arrive, np.float64)
        n = len(kinds)
        queue: list[int] = []       # FIFO of admitted op indices
        self._i = 0                 # next arrival not yet admitted/shed
        t_free = 0.0                # server becomes available at this time

        def admit_until(t: float) -> None:
            """Admit (or shed) every arrival with t_arrive <= t, in order.

            Occupancy only grows between commits, so evaluating arrivals in
            timestamp order against the live queue length gives each op the
            admission decision it would see at its own arrival instant.
            """
            i = self._i
            while i < n and t_arr[i] <= t:
                if len(queue) < cfg.max_queue:
                    queue.append(i)
                    tracker.record_queue_depth(len(queue))
                else:
                    tracker.record_shed(_KIND_NAMES[int(kinds[i])])
                i += 1
            self._i = i

        while queue or self._i < n:
            admit_until(t_free)
            if not queue:
                # idle: jump the clock to the next arrival (plus any ties).
                admit_until(t_arr[self._i])
            t0 = max(t_free, t_arr[queue[0]])

            # ---- group commit: size or deadline, whichever first ----------
            if len(queue) >= cfg.commit_ops or self._i >= n:
                t_commit = t0
            else:
                deadline = t0 + cfg.linger_s
                need = cfg.commit_ops - len(queue)
                j, got = self._i, 0
                while j < n and t_arr[j] <= deadline and got < need:
                    j, got = j + 1, got + 1
                t_commit = max(t0, t_arr[j - 1]) if got == need else deadline
            admit_until(t_commit)

            take = queue[: cfg.commit_ops]
            del queue[: len(take)]
            idx = np.asarray(take, np.int64)
            batch = OpBatch(kinds[idx], trace.ops.keys[idx],
                            trace.ops.vals[idx], trace.ops.his[idx])

            # ---- service (engine clock -> simulated clock) ----------------
            # apply cost is charged through per-op latencies (the engine's
            # foreground share); maintenance through the charged-I/O delta.
            res = eng.apply(batch)
            if self.sim_clock:
                op_service = np.asarray(res.latency_s, np.float64)
            else:
                op_service = np.full(len(idx), cfg.virtual_op_service_s)
            service_s = float(op_service.sum())

            # ---- interleaved maintenance + debt snapshot ------------------
            io1 = eng.io_time_s()
            debt = eng.maintain(cfg.maintain_budget)
            io2 = eng.io_time_s()
            if self.sim_clock:
                maintain_s = io2 - io1
            else:
                maintain_s = cfg.virtual_op_service_s * cfg.maintain_budget

            done = t_commit + np.cumsum(op_service)
            tracker.record_commit(
                t_commit=t_commit,
                kinds=[_KIND_NAMES[int(k)] for k in kinds[idx]],
                e2e_s=done - t_arr[idx],
                queue_delay_s=t_commit - t_arr[idx],
                qdepth_after=len(queue),
                service_s=service_s, maintain_s=maintain_s, debt=int(debt))
            t_free = t_commit + service_s + maintain_s

        t_end = t_free
        debt_final = eng.maintain(0)
        if drain:
            eng.drain()

        offered = {name: int((kinds == k).sum())
                   for k, name in _KIND_NAMES.items()}
        report = tracker.report(offered=offered, t_end=t_end)
        report["service_model"] = "charged" if self.sim_clock else "virtual"
        report["pending_debt_at_end"] = int(debt_final)
        report["config"] = dataclasses.asdict(self.config)
        return report


def run_open_loop(engine: StorageEngine, trace: ArrivalTrace, *,
                  config: FrontendConfig | None = None) -> dict:
    """One-call harness: serve ``trace`` on ``engine``, full JSON report.

    The returned dict mirrors the closed-loop driver report shape (engine
    name, arrival description, final ``stats()`` snapshot) with the
    open-loop SLO section under ``"open_loop"``.
    """
    fe = IngestFrontend(engine, config)
    ol = fe.run(trace)
    stats = engine.stats()
    return {
        "engine": engine.name,
        "arrival": dict(trace.arrival),
        "trace": {"n_ops": len(trace), "duration_s": trace.duration_s,
                  "seed": trace.seed, "preload_pairs": len(trace.preload)},
        "open_loop": ol,
        "stats": dataclasses.asdict(stats),
    }
