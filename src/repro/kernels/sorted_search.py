"""Pallas TPU kernel: batched binary search in a sorted run (query hot loop).

TPU adaptation of the d-tree B+-tree search (paper Sec. 3.2.3): the internal
d-nodes of a disk B+-tree degenerate, in VMEM, to a vectorized binary search
over the contiguous sorted run — identical asymptotics (log_B sigma), zero
pointer chasing, and every query in the batch proceeds in lockstep (the
searches share the fori step counter, so the kernel has no data-dependent
control flow).

Grid is over query tiles; the run (keys + values) is fully VMEM-resident and
reused across all grid steps (Pallas keeps the block pinned since its index
map is constant).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import KEY_MAX32

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES


def _take(arr, idx):
    return jnp.take(arr, idx, mode="clip")


def _search_kernel(run_keys_ref, run_vals_ref, q_ref, found_ref, val_ref, idx_ref,
                   *, n: int, steps: int):
    run = run_keys_ref[...].reshape(-1)
    vals = run_vals_ref[...].reshape(-1)
    q = q_ref[...]

    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, n, jnp.int32)
    for _ in range(steps):
        i = (lo + hi) >> 1
        probe = _take(run, jnp.clip(i, 0, n - 1))
        go_right = (lo < hi) & (probe < q)
        lo = jnp.where(go_right, i + 1, lo)
        hi = jnp.where(go_right, hi, i)

    hit = _take(run, jnp.clip(lo, 0, n - 1))
    # NB: the sentinel is materialized *inside* the kernel — pallas kernels
    # may not capture module-level traced constants.
    found = (lo < n) & (hit == q) & (q != jnp.uint32(0xFFFFFFFF))
    found_ref[...] = found.astype(jnp.int32)
    val_ref[...] = jnp.where(found, _take(vals, jnp.clip(lo, 0, n - 1)), -1)
    idx_ref[...] = lo


@functools.partial(jax.jit, static_argnames=("interpret",))
def sorted_search(run_keys, run_vals, queries, *, interpret: bool = True):
    """Leftmost-match search of ``queries`` in one sorted run.

    Returns (found int32 (Q,), vals int32 (Q,), idx int32 (Q,)), Q padded to
    a TILE multiple internally and sliced back.
    """
    q_raw = queries.shape[0]
    qn = max(TILE, -(-q_raw // TILE) * TILE)
    queries = jnp.pad(queries, (0, qn - q_raw), constant_values=KEY_MAX32)

    n_raw = run_keys.shape[0]
    n = max(LANES, -(-n_raw // LANES) * LANES)
    run_keys = jnp.pad(run_keys, (0, n - n_raw), constant_values=KEY_MAX32)
    run_vals = jnp.pad(run_vals, (0, n - n_raw), constant_values=0)

    steps = math.ceil(math.log2(n + 1)) + 1
    kernel = functools.partial(_search_kernel, n=n, steps=steps)

    run2 = run_keys.reshape(n // LANES, LANES)
    vals2 = run_vals.reshape(n // LANES, LANES)
    q2 = queries.reshape(qn // LANES, LANES)

    full = pl.BlockSpec((n // LANES, LANES), lambda t: (0, 0))
    qspec = pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0))
    found, vals, idx = pl.pallas_call(
        kernel,
        grid=(qn // TILE,),
        in_specs=[full, full, qspec],
        out_specs=[qspec, qspec, qspec],
        out_shape=[
            jax.ShapeDtypeStruct((qn // LANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((qn // LANES, LANES), jnp.int32),
            jax.ShapeDtypeStruct((qn // LANES, LANES), jnp.int32),
        ],
        interpret=interpret,
    )(run2, vals2, q2)
    return (
        found.reshape(-1)[:q_raw],
        vals.reshape(-1)[:q_raw],
        idx.reshape(-1)[:q_raw],
    )
