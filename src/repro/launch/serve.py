"""Serving driver: continuous batching over the NB-tree paged KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --requests 8 --prompt-len 16 --max-new 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from ..models import registry
from ..models import transformer as T
from ..serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32", remat="none")
    if any(k not in ("dense", "swa") for k, _ in cfg.segments):
        raise SystemExit("paged-KV engine serves attention backbones; "
                         "pick a dense/swa arch (qwen3-8b, gemma-2b, ...)")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_batch=args.max_batch, n_pages=1024,
                 page_size=8)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(1, cfg.vocab, args.prompt_len).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    eng.run(reqs)
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests / {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s CPU-interpret)")
    print(f"free pages after completion: {len(eng.cache.free)} "
          f"(index height {eng.cache.index.height})")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
