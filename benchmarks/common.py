"""Shared benchmark utilities: workloads scaled for CPU wall-clock.

The paper's experiments insert up to 2e9 keys with sigma = 2 GB; here every
index runs the same *scaled* workload (n ~ 1e5..1e6 pairs, sigma scaled to
keep n/sigma and the level count in the paper's regime) under the explicit
I/O cost model (core/cost_model.py, the paper's own Seagate/SSD constants).
Reported numbers are simulated seconds — the measure the paper's theory
section is written in — plus host wall-clock for the data plane.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import HDD, SSD
from repro.core.engine_api import BulkBTreeEngine, OpBatch, OpKind, make_engine


def workload(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(1, 1 << 48, size=int(n * 1.02), dtype=np.uint64)
    keys = np.unique(keys)[:n]          # dedupe (collisions ~n^2/2^49)
    assert len(keys) == n
    return rng.permutation(keys)


#: the paper's sigma is 64 MB..2 GB; simulation sigma is ~1e3..1e4 pairs.
#: A direct scale-down distorts the seek:stream ratio (a flush streams
#: sigma/f bytes per seek — 0.7 GB in the paper, tens of KB here), which
#: flips seek-amortization conclusions.  ``scaled_device`` shrinks T_seek by
#: the same factor as sigma so every per-operation seek:stream ratio matches
#: the paper's geometry at simulation scale.
REF_SIGMA_BYTES = 64 << 20


def scaled_device(base, sigma_pairs: int):
    from repro.core.cost_model import Device, PAIR_BYTES
    factor = max(1e-4, sigma_pairs * PAIR_BYTES / REF_SIGMA_BYTES)
    return Device(base.name + "-scaled", base.page_bytes,
                  base.seek_s * factor, base.read_bw, base.write_bw)


def make_bench_engine(name: str, device, sigma_pairs: int):
    """Registered StorageEngine configured for the scaled cost model."""
    dev = scaled_device(device, sigma_pairs)
    kw = {
        "nbtree": dict(f=3, sigma=sigma_pairs, device=dev),
        "nbtree-nobloom": dict(f=3, sigma=sigma_pairs, device=dev),
        "nbtree-basic": dict(f=3, sigma=sigma_pairs, device=dev),
        "lsm": dict(mem_pairs=sigma_pairs, ratio=10, device=dev),
        "blsm": dict(mem_pairs=sigma_pairs, ratio=10, device=dev),
        "bepsilon": dict(node_bytes=1 << 16, cached_levels=1, device=dev),
        "btree": dict(device=dev),
    }[name]
    return make_engine(name, **kw)


def bulk_btree_engine(keys, device, sigma_pairs: int):
    """The paper's static query yardstick (QUERY/RANGE only)."""
    return BulkBTreeEngine(keys, np.arange(len(keys), dtype=np.int64),
                           device=scaled_device(device, sigma_pairs))


def insert_all(engine, keys) -> tuple[float, float]:
    """(avg_insert_s, max_insert_s) over the whole workload.

    avg is throughput time (total charged cost / n, any clock); max is the
    worst *foreground* op latency (the paper's worst-case-delay metric).
    """
    before = engine.io_time_s()
    res = engine.apply(OpBatch.inserts(keys, np.arange(len(keys),
                                                       dtype=np.int64)))
    return (engine.io_time_s() - before) / len(keys), float(res.latency_s.max())


def query_sample(engine, keys, n_q: int = 400, seed: int = 1):
    rng = np.random.default_rng(seed)
    q = rng.choice(keys, n_q, replace=False)
    res = engine.apply(OpBatch.queries(q))
    lat = res.latencies(OpKind.QUERY)
    return float(np.mean(lat)), float(np.max(lat))


DEVICES = {"hdd": HDD, "ssd": SSD}
