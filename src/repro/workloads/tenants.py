"""Multi-tenant workload scenarios (tenant mixes + arrival processes).

Couples the single-stream building blocks — :mod:`repro.workloads.generator`
op mixes and :mod:`repro.ingest.arrivals` processes — into named
*scenarios*: a set of tenants, each with its own mix, key space, arrival
process, fair-share weight and SLO target.  The canonical one is
``noisy-neighbor`` (two steady well-behaved tenants + one bursty MMPP
aggressor), the workload behind ``benchmarks/fig_tenancy.py``.

Everything is deterministic per seed: each tenant's op stream and arrival
clock get independent seeds derived from ``(scenario seed, tenant id)``,
so adding a tenant never perturbs another tenant's trace.
"""
from __future__ import annotations

import dataclasses

from repro.ingest.arrivals import (DiurnalArrivals, MMPPArrivals,
                                   PoissonArrivals, make_trace)
from repro.tenancy import TenantConfig

from .generator import make_workload

#: tenant-local key spaces stay small: every tenant must fit its namespace
#: interval (2^27 keys at the default 4 tenant bits) with range-scan slack.
_TENANT_KEY_SPACE = 1 << 20


@dataclasses.dataclass(frozen=True)
class TenantStream:
    """One tenant's serving contract plus the trace recipe behind it."""

    tenant: TenantConfig
    mix: str = "insert-heavy"
    arrival: dict = dataclasses.field(
        default_factory=lambda: {"process": "poisson", "rate": 2000.0})
    n_ops: int = 4096
    preload: int = 1024
    key_space: int = _TENANT_KEY_SPACE

    def make_process(self):
        a = dict(self.arrival)
        kind = a.pop("process")
        if kind == "poisson":
            return PoissonArrivals(**a)
        if kind == "mmpp":
            return MMPPArrivals(**a)
        if kind == "diurnal":
            return DiurnalArrivals(**a)
        raise KeyError(f"unknown arrival process {kind!r}")


def build_streams(streams: list, *, seed: int = 0) -> tuple:
    """Expand streams into ``(tenants, traces)`` for the frontend.

    Per-tenant seeds are ``seed*1000 + tenant_id`` on the op stream and
    the same on the arrival clock — independent across tenants, stable
    under adding/removing co-tenants.
    """
    tenants, traces = [], {}
    for s in streams:
        tid = s.tenant.tenant_id
        assert tid not in traces, f"duplicate tenant id {tid}"
        wl = make_workload(s.mix, n_ops=s.n_ops, preload=s.preload,
                           key_space=s.key_space,
                           seed=seed * 1000 + tid)
        traces[tid] = make_trace(wl, s.make_process(),
                                 arrival_seed=seed * 1000 + tid)
        tenants.append(s.tenant)
    return tenants, traces


# --------------------------------------------------------------- scenarios
def noisy_neighbor(*, n_ops: int = 4096, victim_rate: float = 2000.0,
                   aggressor_rate: float = 40000.0,
                   victim_weight: float = 2.0,
                   aggressor_queue: int = 1024,
                   aggressor_ops: int | None = None,
                   slo_p999_s: float | None = None) -> list:
    """Two steady insert-heavy victims + one bursty MMPP aggressor.

    The aggressor's burst rate is the sweep knob: past the engine's drain
    rate, an unfair (shared-FIFO) frontend lets its bursts camp the queue
    and inflate the victims' p99.9 without bound, while fair queuing sheds
    the aggressor against its own bound and holds the victims near their
    solo latency — the claim ``fig_tenancy`` checks.
    """
    victims = [
        TenantStream(
            tenant=TenantConfig(tid, name=f"steady{tid}",
                                weight=victim_weight,
                                slo_p999_s=slo_p999_s),
            mix="insert-heavy", n_ops=n_ops,
            arrival={"process": "poisson", "rate": victim_rate})
        for tid in (0, 1)
    ]
    # default aggressor length: ~cover the victims' trace window at the
    # MMPP mean rate (rate_on x 50% duty) so the bursts overlap the whole
    # measured run instead of ending early.
    if aggressor_ops is None:
        aggressor_ops = max(2 * n_ops, int(aggressor_rate / 2
                                           * (n_ops / victim_rate)))
    aggressor = TenantStream(
        tenant=TenantConfig(2, name="aggressor", weight=1.0,
                            max_queue=aggressor_queue),
        mix="insert-heavy", n_ops=aggressor_ops,
        arrival={"process": "mmpp", "rate_on": aggressor_rate,
                 "rate_off": 0.0, "mean_on_s": 0.05, "mean_off_s": 0.05})
    return victims + [aggressor]


def mixed_oltp(*, n_ops: int = 4096, base_rate: float = 2000.0) -> list:
    """Heterogeneous mixes: writer, point-reader, scanner, diurnal blend.

    Exercises namespace isolation across op kinds — the scanner's RANGEs
    stay inside its own interval no matter what the writer inserts.
    """
    return [
        TenantStream(
            tenant=TenantConfig(0, name="writer", weight=2.0),
            mix="insert-heavy", n_ops=n_ops,
            arrival={"process": "poisson", "rate": base_rate}),
        TenantStream(
            tenant=TenantConfig(1, name="reader", weight=1.0),
            mix="point-read-heavy", n_ops=n_ops,
            arrival={"process": "poisson", "rate": base_rate / 2}),
        TenantStream(
            tenant=TenantConfig(2, name="scanner", weight=1.0),
            mix="ycsb-e", n_ops=n_ops // 2,
            arrival={"process": "poisson", "rate": base_rate / 4}),
        TenantStream(
            tenant=TenantConfig(3, name="diurnal", weight=1.0),
            mix="ycsb-a", n_ops=n_ops,
            arrival={"process": "diurnal", "base_rate": base_rate,
                     "amplitude": 0.8, "period_s": 2.0}),
    ]


#: scenario name -> factory returning ``list[TenantStream]``.
SCENARIOS: dict = {
    "noisy-neighbor": noisy_neighbor,
    "mixed-oltp": mixed_oltp,
}


def build_scenario(name: str, *, seed: int = 0, **overrides) -> tuple:
    """``(tenants, traces)`` for a named scenario; overrides reach the
    scenario factory (rates, sizes, weights — see each factory)."""
    return build_streams(SCENARIOS[name](**overrides), seed=seed)
