"""Substrate: data pipeline, checkpoint/restart, trainer loop, optimizer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (PackedBatches, StreamingIngest,
                                 synthetic_documents)
from repro.models import registry
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.schedules import constant, cosine_with_warmup
from repro.train.trainer import Trainer


def test_ingest_dedup_and_query():
    ing = StreamingIngest()
    docs = synthetic_documents(100, 40, 1000)
    stored = sum(ing.ingest(d) for d in docs)
    assert stored == 100
    assert not ing.ingest(docs[5])          # dedup
    assert ing.dups == 1
    batch = next(iter(PackedBatches(ing, 4, 32)))
    assert batch["tokens"].shape == (4, 32)
    assert batch["labels"].shape == (4, 32)
    assert (batch["tokens"] > 0).all()


def test_synthetic_documents_deterministic():
    a = synthetic_documents(10, 20, 500, seed=3)
    b = synthetic_documents(10, 20, 500, seed=3)
    assert np.array_equal(a, b)
    c = synthetic_documents(10, 20, 500, seed=4)
    assert not np.array_equal(a, c)


def test_schedules():
    s = cosine_with_warmup(1.0, 10, 100)
    assert float(s(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-2)
    assert float(constant(3e-4)(jnp.asarray(7))) == pytest.approx(3e-4)


def test_adamw_decreases_simple_loss():
    params = {"w": jnp.asarray([2.0, -3.0])}
    opt = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(50):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw.update(g, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_trainer_checkpoint_restart(tmp_path):
    cfg = dataclasses.replace(registry.get_config("gemma-2b").reduced(),
                              remat="none")
    ing = StreamingIngest()
    for d in synthetic_documents(64, 40, cfg.vocab):
        ing.ingest(d)
    batches = PackedBatches(ing, batch=4, seq_len=32)

    tr = Trainer(cfg, ckpt_dir=str(tmp_path))
    h1 = tr.run(batches, 4, ckpt_every=2, log_every=0)
    assert all(np.isfinite(h["loss"]) for h in h1)

    tr2 = Trainer(cfg, ckpt_dir=str(tmp_path))   # restart picks up step 4
    assert tr2.step == 4
    h2 = tr2.run(batches, 2, log_every=0)
    assert len(h2) == 2 and np.isfinite(h2[-1]["loss"])
    # restored params identical to saved ones
    l1 = np.asarray(jax.tree_util.tree_leaves(tr.params)[0], np.float32)
    l2 = np.asarray(jax.tree_util.tree_leaves(tr2.params)[0], np.float32)
    # tr ran 4 steps then saved; tr2 restored then ran 2 more — compare via a
    # third restore instead:
    tr3 = Trainer(cfg, ckpt_dir=str(tmp_path))
    l3 = np.asarray(jax.tree_util.tree_leaves(tr3.params)[0], np.float32)


def test_checkpoint_async(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ck.save(1, tree, blocking=False)
    ck.wait()
    out = ck.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10))
    assert out["b"]["c"].dtype == jnp.bfloat16
