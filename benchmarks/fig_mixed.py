"""Mixed-workload scenario: worst-case delay across all five tiers.

The paper measures insertion and query latency in *separate* experiments
(Figs. 6-9); its LSM baselines (Luo & Carey) are evaluated on YCSB-style
*mixed* workloads, where worst-case delay is what ingestion stalls actually
cost a serving system.  This scenario closes that gap: one shared workload
definition (a YCSB-A-style 50/50 insert/read blend with zipfian keys, plus
a delete-churn blend exercising tombstones and ranges) is streamed through
every tier of the paper's comparison set via the unified ``StorageEngine``
protocol, with ``maintain(1)`` between batches — the serving-loop
deamortization budget.

Expected shape: NB-tree's worst foreground insert stays orders of
magnitude below the LSM family's compaction stall even with reads
interleaved; every tier returns identical visible results (the driver's
final live-pair counts must agree — a differential check at benchmark
scale).  The device tier runs the same stream on host wall-clock
(interpret-mode Pallas off-TPU), so its row demonstrates protocol + debt
bounds rather than comparable latency units.
"""
from __future__ import annotations

from repro.core.engine_api import FIVE_TIERS, OpKind, make_engine
from repro.workloads import make_workload
from repro.workloads.driver import run_workload

from .common import DEVICES, make_bench_engine

KEY_SPACE = 1 << 20


def _engine(name: str, device, sigma: int):
    if name == "jax-nbtree":   # wall-clock tier: no cost device to scale
        return make_engine(name, f=4, sigma=max(256, sigma // 2),
                           max_nodes=512)
    return make_bench_engine(name, device, sigma)


def _row_from(report: dict, **extra) -> dict:
    pk = report["per_kind"]
    ins = pk.get("insert", {})
    rd = pk.get("query", pk.get("range", {}))
    return dict(
        fig="mixed",
        index=report["engine"],
        clock=report["stats"]["clock"],
        insert_p50_ms=ins.get("p50_s", 0.0) * 1e3,
        insert_p99_ms=ins.get("p99_s", 0.0) * 1e3,
        insert_p100_ms=ins.get("p100_s", 0.0) * 1e3,
        read_p50_ms=rd.get("p50_s", 0.0) * 1e3,
        read_p100_ms=rd.get("p100_s", 0.0) * 1e3,
        pending_debt=report["stats"]["pending_debt"],
        live_pairs=report["stats"]["total_pairs"],
        **extra)


def run(mixes=("ycsb-a", "delete-churn"), n_ops: int = 4096,
        batch: int = 256, preload: int = 4096):
    # size the memory component so compactions/cascades actually fire
    # inside the measured phase (several buffer turnovers per run).
    sigma = max(256, (preload + n_ops) // 8)
    rows = []
    for mix in mixes:
        for dev_name, dev in DEVICES.items():
            for name in FIVE_TIERS:
                if name == "jax-nbtree" and dev_name != "hdd":
                    continue   # wall-clock tier: cost device is irrelevant
                wl = make_workload(mix, key_space=KEY_SPACE, n_ops=n_ops,
                                   batch_size=batch, preload=preload)
                report = run_workload(_engine(name, dev, sigma), wl,
                                      maintain_budget=1)
                rows.append(_row_from(
                    report, mix=mix, n_ops=n_ops,
                    device="n/a" if name == "jax-nbtree" else dev_name))
    return rows


def check(rows) -> list[str]:
    out = []
    for mix in sorted({r["mix"] for r in rows}):
        sel = [r for r in rows if r["mix"] == mix]
        # every tier produced a worst-case-delay row from the one workload.
        tiers = {r["index"] for r in sel}
        tag = "matches paper" if tiers == set(FIVE_TIERS) else "MISMATCH"
        out.append(f"mixed {mix}: worst-case-delay rows for all five tiers "
                   f"({len(tiers)}/5)  [{tag}]")
        # identical visible state: every engine ends with the same live pairs.
        pairs = {r["live_pairs"] for r in sel}
        tag = "matches paper" if len(pairs) == 1 else "MISMATCH"
        out.append(f"mixed {mix}: all tiers agree on live pairs "
                   f"({sorted(pairs)})  [{tag}]")
        for dev in sorted({r["device"] for r in sel} - {"n/a"}):
            by = {r["index"]: r for r in sel if r["device"] == dev}
            nb, lsm = by["nbtree"], by["lsm"]
            ratio = lsm["insert_p100_ms"] / max(nb["insert_p100_ms"], 1e-9)
            # the separation grows with cascade depth (~data size): ~150x at
            # the default 4096+4096 scale, shallower in --quick runs.
            thr = 100 if nb["n_ops"] >= 4096 else 20
            tag = "matches paper" if ratio > thr else "MISMATCH"
            out.append(f"mixed {mix} {dev}: NB worst insert {ratio:.0f}x "
                       f"below LSM under mixed load  [{tag}]")
        # the device tier honours the bounded-debt contract between batches.
        devrow = next(r for r in sel if r["index"] == "jax-nbtree")
        tag = "matches paper" if devrow["pending_debt"] == 0 else "MISMATCH"
        out.append(f"mixed {mix}: device tier drained to zero debt  [{tag}]")
    return out
