"""Cross-shard deamortized maintenance scheduling (DESIGN.md §6).

The paper's worst-case insertion-delay bound comes from spending a bounded
amount of maintenance per serving step (Sec. 5.1).  A sharded ensemble
breaks that bound if the step budget is spent obliviously: Luo & Carey
("On Performance Stability in LSM-based Storage Systems") show that
unscheduled background maintenance across partitions is exactly what
reintroduces write stalls at scale-out.  The fix is the same deamortization
argument applied one level up — each serving step's budget is *allocated*
across shards so the shard closest to a forced synchronous drain is always
served first.

:class:`DebtScheduler` is that allocator, kept as a pure, deterministic
strategy object so it can be unit-tested without engines: given the current
per-shard debt vector and a unit budget it returns how many maintenance
units each shard receives this step.  Policy: one unit at a time to the
heaviest *remaining* (optimistically decremented) debt, ties broken by a
persistent round-robin pointer so equally-indebted shards share the budget
fairly across steps instead of the lowest id starving the rest.
"""
from __future__ import annotations


class DebtScheduler:
    """Debt-weighted, round-robin-tiebroken budget allocator."""

    def __init__(self):
        self._rr = 0  # persistent tiebreak pointer (fairness across calls)

    def allocate(self, debts, budget: int) -> list[int]:
        """Distribute ``budget`` maintenance units over ``debts``.

        Returns a per-shard unit allocation with ``sum(alloc) ==
        min(budget, sum(debts))``.  Each unit goes to the shard with the
        highest remaining debt (debt is optimistically decremented by one
        per granted unit; the engine refreshes true debt from the shard's
        ``maintain`` return value afterwards).  Exact ties go to the shard
        at or after the round-robin pointer, which then advances — so a
        uniformly indebted ensemble is served in rotation, not by id.
        """
        remaining = [int(d) for d in debts]
        alloc = [0] * len(remaining)
        n = len(remaining)
        for _ in range(max(0, int(budget))):
            best, best_debt = -1, 0
            for off in range(n):
                s = (self._rr + off) % n
                if remaining[s] > best_debt:
                    best, best_debt = s, remaining[s]
            if best < 0:
                break
            alloc[best] += 1
            remaining[best] -= 1
            self._rr = (best + 1) % n
        return alloc
