"""Multi-tenant serving front door (DESIGN.md §10).

Namespaces (tenant id in the key's high bits), weighted-fair admission
(deficit round-robin over per-tenant bounded queues), per-tenant SLO
reports, and commit-watermark snapshot reads — all over ONE shared
storage engine of any tier.
"""
from .fair_queue import WeightedFairQueue
from .frontend import MultiTenantFrontend, TenantConfig, run_multi_tenant
from .namespace import NamespaceMap
from .snapshots import Snapshot, SnapshotManager

__all__ = [
    "MultiTenantFrontend",
    "NamespaceMap",
    "Snapshot",
    "SnapshotManager",
    "TenantConfig",
    "WeightedFairQueue",
    "recover_namespace",
    "run_multi_tenant",
]


def recover_namespace(directory: str, engine_factory, tenant_id: int, *,
                      namespace: NamespaceMap | None = None):
    """Rebuild ONE tenant's namespace from a shared durable directory.

    Thin wrapper over :func:`repro.wal.recovery.recover` with
    ``key_range`` set to the tenant's interval: the snapshot is filtered
    to the namespace and WAL replay skips co-tenants' ops — single-tenant
    restore without paying for the co-tenants' history.
    """
    from repro.wal.recovery import recover

    ns = namespace or NamespaceMap()
    return recover(directory, engine_factory,
                   key_range=ns.tenant_interval(tenant_id))
