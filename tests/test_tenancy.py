"""Multi-tenant serving front door tests (DESIGN.md §10).

Covers the namespace packing (collision-free round trip, contiguous
intervals, batch encoding), the weighted-fair queue (DRR service ratios,
work conservation, per-tenant shed, the mid-visit resume that prevents a
deep queue from monopolizing commits), commit-watermark snapshots
(differential against a sorted-dict oracle frozen at the pin while
inserts and cascades proceed — on a sim tier AND the device tier),
multi-tenant conformance (each namespace's final state equals its own
single-tenant oracle), the durable multi-tenant crash path (every
namespace recovers with zero lost acked writes; single-namespace
key-range recovery), trace multiplexing, and the driver's multi-stream
modes.
"""
import numpy as np
import pytest

from repro.core.engine_api import OpBatch, OpKind, make_engine
from repro.ingest import (DurabilityConfig, FrontendConfig, PoissonArrivals,
                          make_trace, multiplex)
from repro.tenancy import (MultiTenantFrontend, NamespaceMap, SnapshotManager,
                           TenantConfig, WeightedFairQueue, recover_namespace,
                           run_multi_tenant)
from repro.wal import CrashPoint, FaultInjector, SimulatedCrash, recover
from repro.workloads import make_workload
from repro.workloads.tenants import TenantStream, build_scenario, build_streams

KEYS = np.uint64
VALS = np.int64


# ---------------------------------------------------------------- namespaces
def test_namespace_round_trip_and_intervals():
    ns = NamespaceMap()
    assert ns.key_bits == 27 and ns.max_tenants == 16
    rng = np.random.default_rng(0)
    for tid in (0, 3, 15):
        local = rng.integers(1, ns.max_local_key + 1, 256).astype(KEYS)
        enc = ns.encode(tid, local)
        assert enc.dtype == KEYS
        assert int(enc.max()) < (1 << 31), "uint32 device envelope"
        tids, dec = ns.decode(enc)
        assert (tids == tid).all()
        assert np.array_equal(dec, local)
        lo, hi = ns.tenant_interval(tid)
        assert lo <= int(enc.min()) and int(enc.max()) <= hi

    # intervals are disjoint and ordered -> collision-free across tenants
    ivals = [ns.tenant_interval(t) for t in range(ns.max_tenants)]
    for (lo1, hi1), (lo2, hi2) in zip(ivals, ivals[1:]):
        assert hi1 < lo2

    # order within a namespace is preserved (contiguous RANGE scans work)
    local = np.sort(rng.choice(np.arange(1, 10_000, dtype=KEYS), 64, False))
    enc = ns.encode(5, local)
    assert (np.diff(enc.astype(np.int64)) > 0).all()


def test_namespace_rejects_out_of_range():
    ns = NamespaceMap(tenant_bits=2)
    with pytest.raises(AssertionError):
        ns.encode(4, [1])                       # tenant id out of range
    with pytest.raises(AssertionError):
        ns.encode(1, [0])                       # local keys start at 1
    with pytest.raises(AssertionError):
        ns.encode(1, [ns.max_local_key + 1])    # overflows into tenant bits


def test_namespace_encode_batch_ranges():
    ns = NamespaceMap()
    b = OpBatch.ranges(np.array([10, 20], KEYS), np.array([15, 25], KEYS))
    ins = OpBatch.inserts(np.array([7], KEYS), np.array([70], VALS))
    enc = ns.encode_batch(2, OpBatch.concat([ins, b]))
    lo, _ = ns.tenant_interval(2)
    base = lo - 1
    assert enc.keys.tolist() == [base + 7, base + 10, base + 20]
    assert enc.his.tolist() == [0, base + 15, base + 25], \
        "RANGE his encodes; non-RANGE placeholder stays 0"
    assert enc.vals.tolist() == [70, 0, 0]


# ----------------------------------------------------------------- fair queue
def test_drr_service_follows_weights():
    q = WeightedFairQueue(quantum=10)
    q.add_tenant(0, weight=3.0, max_queue=1000)
    q.add_tenant(1, weight=1.0, max_queue=1000)
    for i in range(900):
        q.offer(0, i)
    for i in range(300, 600):
        q.offer(1, i)
    served = {0: 0, 1: 0}
    while q.backlog(0) and q.backlog(1):
        for tid, _ in q.take(16):
            served[tid] += 1
        if q.backlog(0) and q.backlog(1):
            # DRR bound: error vs the 3:1 weight ratio stays within a few
            # quanta over any backlogged interval.
            assert abs(served[0] - 3 * served[1]) <= 5 * q.quantum
    assert served[0] > served[1] > 0


def test_drr_work_conserving_and_shed_accounting():
    q = WeightedFairQueue(quantum=4)
    q.add_tenant(0, weight=1.0, max_queue=4)
    q.add_tenant(1, weight=1.0, max_queue=100)
    for i in range(10):
        q.offer(0, i)                 # 4 admitted, 6 shed
    assert q.backlog(0) == 4
    for i in range(3):
        q.offer(1, i)
    got = q.take(7)                   # never idles while ops are queued
    assert len(got) == 7 and q.backlog() == 0
    st = q.stats()
    assert st["0"]["shed"] == 6 and st["0"]["offered"] == 10
    assert st["1"]["shed"] == 0 and st["1"]["served"] == 3
    assert st["0"]["depth_max"] == 4


def test_drr_deep_queue_cannot_monopolize():
    """Regression: a batch-filling visit must not re-credit the same
    tenant a fresh quantum next call (cursor advances when the deficit is
    spent), so a co-tenant's op is served within the next batch."""
    q = WeightedFairQueue(quantum=16)
    q.add_tenant(0, weight=1.0, max_queue=2000)
    q.add_tenant(1, weight=1.0, max_queue=100)
    for i in range(1000):
        q.offer(0, i)
    q.offer(1, 0)
    first = q.take(16)
    second = q.take(16)
    assert (1, 0) in first + second
    # and per-tenant FIFO order is preserved for the deep queue
    t0 = [item for tid, item in first + second if tid == 0]
    assert t0 == sorted(t0)


# ------------------------------------------------------------------ snapshots
def test_snapshot_reads_are_frozen_at_pin():
    eng = make_engine("nbtree", f=3, sigma=64)
    keys = np.arange(10, 200, 2, dtype=KEYS)
    eng.apply(OpBatch.inserts(keys, keys.astype(VALS)))
    sm = SnapshotManager(eng)
    snap = sm.pin(watermark_lsn=1)
    # mutate + cascade after the pin: the view must not move
    eng.apply(OpBatch.deletes(keys[:50]))
    eng.apply(OpBatch.inserts(np.array([11, 13], KEYS),
                              np.array([1, 2], VALS)))
    eng.drain()
    found, vals = snap.query(np.array([10, 11, 12, 13], KEYS))
    assert found.tolist() == [True, False, True, False]
    assert vals.tolist() == [10, 0, 12, 0]
    rk, rv = snap.range(10, 20)
    assert rk.tolist() == [10, 12, 14, 16, 18, 20]
    assert rv.tolist() == [10, 12, 14, 16, 18, 20]
    sm.release(snap)
    assert sm.stats() == {"pins": 1, "releases": 1, "active": 0,
                          "active_pairs": 0, "pinned_pairs_max": 95}


def _oracle_from_acked(preloads, acked):
    """Sorted-dict ground truth over ENCODED keys: preload + acked ops."""
    d = {}
    for b in preloads:
        for k, v in zip(b.keys.tolist(), b.vals.tolist()):
            d[int(k)] = int(v)
    for _lsn, kinds, keys, vals in acked:
        for kk, k, v in zip(kinds.tolist(), keys.tolist(), vals.tolist()):
            if kk == int(OpKind.INSERT):
                d[int(k)] = int(v)
            else:
                d.pop(int(k), None)
    return d


def _scoped(d, interval):
    lo, hi = interval
    return sorted((k, v) for k, v in d.items() if lo <= k <= hi)


@pytest.mark.parametrize("name,kw", [
    ("nbtree", dict(f=3, sigma=64)),
    ("sharded:nbtree", dict(shards=3, f=3, sigma=64)),
    ("jax-nbtree", dict(f=4, sigma=64, max_nodes=256)),
])
def test_snapshot_differential_vs_oracle_mid_cascade(tmp_path, name, kw):
    """Pin snapshots at commit boundaries mid-run (whole keyspace and one
    tenant's interval), let ingest + emptying cascades proceed, then check
    every pinned view against a sorted-dict oracle frozen at its own
    watermark — on sim, sharded, and device tiers."""
    ns = NamespaceMap()
    streams = [
        TenantStream(tenant=TenantConfig(0, weight=2.0), mix="delete-churn",
                     n_ops=600, preload=128, key_space=1 << 14,
                     arrival={"process": "poisson", "rate": 50_000.0}),
        TenantStream(tenant=TenantConfig(1), mix="insert-heavy",
                     n_ops=600, preload=128, key_space=1 << 14,
                     arrival={"process": "poisson", "rate": 50_000.0}),
    ]
    tenants, traces = build_streams(streams, seed=3)
    eng = make_engine(name, **kw)
    fe = MultiTenantFrontend(
        eng, tenants, FrontendConfig(max_queue=4096, commit_ops=32),
        durability=DurabilityConfig(str(tmp_path / name.replace(":", "_"))),
        namespace=ns)

    pinned = []          # (snapshot, oracle-dict frozen at the pin)

    def on_commit(front, _t):
        if front._n_commits % 7 == 3 and len(pinned) < 8:
            pre = [ns.encode_batch(t, traces[t].preload) for t in traces]
            oracle = _oracle_from_acked(pre, front.acked)
            pinned.append((front.pin_snapshot(), oracle, None))
            pinned.append((front.pin_snapshot(tenant_id=0), oracle,
                           ns.tenant_interval(0)))

    rep = fe.run(traces, on_commit=on_commit)
    assert len(pinned) >= 4, "pins must actually happen mid-run"
    assert rep["snapshots"]["pins"] == len(pinned)
    # cascades really proceeded while snapshots were held
    assert rep["server"]["maintain_s"] >= 0.0
    for snap, oracle, interval in pinned:
        want = _scoped(oracle, interval) if interval else \
            sorted(oracle.items())
        assert snap.keys.tolist() == [k for k, _ in want], \
            "pinned view drifted from its watermark oracle"
        assert snap.vals.tolist() == [v for _, v in want]
        # point reads against the frozen view
        probe = snap.keys[:8]
        if len(probe):
            found, vals = snap.query(probe)
            assert found.all()
            assert vals.tolist() == [oracle[int(k)] for k in probe]


# ---------------------------------------------------------------- conformance
def test_multi_tenant_namespaces_match_solo_oracles():
    """With no shedding, each tenant's final namespace equals the oracle
    of its OWN trace alone — co-tenants are invisible (isolation)."""
    tenants, traces = build_scenario("mixed-oltp", seed=2, n_ops=600,
                                     base_rate=20_000.0)
    eng = make_engine("nbtree", f=3, sigma=128)
    ns = NamespaceMap()
    rep = run_multi_tenant(eng, tenants, traces, namespace=ns)
    ol = rep["open_loop"]
    assert ol["n_shed"] == 0 and ol["n_done"] == ol["n_offered"]
    for t in tenants:
        tid = t.tenant_id
        d = {}
        for k, v in zip(traces[tid].preload.keys.tolist(),
                        traces[tid].preload.vals.tolist()):
            d[int(k)] = int(v)
        kinds = traces[tid].ops.kinds.tolist()
        for kk, k, v in zip(kinds, traces[tid].ops.keys.tolist(),
                            traces[tid].ops.vals.tolist()):
            if kk == int(OpKind.INSERT):
                d[int(k)] = int(v)
            elif kk == int(OpKind.DELETE):
                d.pop(int(k), None)
        lo, hi = ns.tenant_interval(tid)
        gk, gv = eng.dump_live_range(lo, hi)
        _, local = ns.decode(gk)
        assert sorted(d.items()) == list(zip(local.tolist(), gv.tolist()))
        assert ol["tenants"][str(tid)]["live_pairs"] == len(d)


def test_multi_tenant_report_deterministic():
    import json

    def one():
        tenants, traces = build_scenario("noisy-neighbor", seed=4, n_ops=300,
                                         victim_rate=1000.0,
                                         aggressor_rate=20_000.0)
        eng = make_engine("nbtree", f=3, sigma=128)
        return json.dumps(run_multi_tenant(eng, tenants, traces),
                          sort_keys=True, default=float)

    assert one() == one()


def test_unfair_mode_sheds_victims_too():
    tenants, traces = build_scenario("noisy-neighbor", seed=0, n_ops=400,
                                     victim_rate=500.0,
                                     aggressor_rate=50_000.0)
    eng = make_engine("btree")
    rep = run_multi_tenant(
        eng, tenants, traces, fair=False,
        config=FrontendConfig(max_queue=512, commit_ops=16))
    adm = rep["open_loop"]["admission"]
    assert rep["open_loop"]["fair"] is False
    assert adm["2"]["shed"] > 0, "aggressor bursts overflow the shared FIFO"
    assert adm["0"]["shed"] + adm["1"]["shed"] > 0, \
        "shared FIFO lets the aggressor shed victims (the unfair baseline)"


def test_slo_targets_in_report():
    streams = [
        TenantStream(tenant=TenantConfig(0, slo_p999_s=10.0),    # generous
                     n_ops=200, preload=64),
        TenantStream(tenant=TenantConfig(1, slo_p999_s=1e-9),    # impossible
                     n_ops=200, preload=64),
    ]
    tenants, traces = build_streams(streams, seed=1)
    rep = run_multi_tenant(make_engine("nbtree", f=3, sigma=128),
                           tenants, traces)
    slo0 = rep["open_loop"]["tenants"]["0"]["slo"]
    slo1 = rep["open_loop"]["tenants"]["1"]["slo"]
    assert slo0["met"] is True and slo0["p999_target_s"] == 10.0
    assert slo1["met"] is False


# ------------------------------------------------------------------ multiplex
def test_multiplex_merges_in_time_order():
    wl = make_workload("insert-heavy", n_ops=200, preload=0, seed=0)
    traces = {0: make_trace(wl, PoissonArrivals(1000.0), arrival_seed=1),
              2: make_trace(wl, PoissonArrivals(3000.0), arrival_seed=2)}
    t, sid, loc = multiplex(traces)
    assert len(t) == 400
    assert (np.diff(t) >= 0).all(), "merged stream is time-sorted"
    for s in (0, 2):
        mine = loc[sid == s]
        assert np.array_equal(mine, np.arange(len(mine))), \
            "per-stream op order preserved"
    t2, sid2, loc2 = multiplex(traces)
    assert np.array_equal(t, t2) and np.array_equal(sid, sid2) \
        and np.array_equal(loc, loc2)
    e = multiplex({})
    assert len(e[0]) == 0


# --------------------------------------------------------- durability + crash
def _durable_multi(tmp_path, injector=None):
    streams = [
        TenantStream(tenant=TenantConfig(0, weight=2.0), mix="delete-churn",
                     n_ops=700, preload=128, key_space=1 << 14,
                     arrival={"process": "poisson", "rate": 50_000.0}),
        TenantStream(tenant=TenantConfig(1), mix="insert-heavy",
                     n_ops=700, preload=128, key_space=1 << 14,
                     arrival={"process": "poisson", "rate": 50_000.0}),
        TenantStream(tenant=TenantConfig(5), mix="delete-churn",
                     n_ops=400, preload=64, key_space=1 << 14,
                     arrival={"process": "poisson", "rate": 30_000.0}),
    ]
    tenants, traces = build_streams(streams, seed=7)
    eng = make_engine("nbtree", f=3, sigma=64)
    fe = MultiTenantFrontend(
        eng, tenants, FrontendConfig(max_queue=4096, commit_ops=32),
        durability=DurabilityConfig(str(tmp_path), segment_bytes=4096,
                                    checkpoint_every_commits=6),
        injector=injector)
    return fe, traces


def _factory():
    return make_engine("nbtree", f=3, sigma=64)


def test_multi_tenant_crash_recovers_every_namespace(tmp_path):
    """Kill a durable 3-tenant run mid-flight: global recovery restores
    every namespace to exactly its acked prefix (zero lost acked writes,
    zero resurrected unacked ones), and key-range recovery restores each
    single namespace from the shared log."""
    ns = NamespaceMap()
    inj = FaultInjector(CrashPoint.AFTER_WAL_FSYNC, at_occurrence=11)
    fe, traces = _durable_multi(tmp_path, injector=inj)
    with pytest.raises(SimulatedCrash):
        fe.run(traces)
    assert inj.fired and len(fe.acked) >= 10

    pre = [ns.encode_batch(t, traces[t].preload) for t in sorted(traces)]
    oracle = _oracle_from_acked(pre, fe.acked)

    rr = recover(str(tmp_path), _factory)
    rk, rv = rr.engine.dump_live()
    assert list(zip(rk.tolist(), rv.tolist())) == sorted(oracle.items())
    assert rr.last_lsn == fe.last_acked_lsn

    # every tenant id present in the oracle survived recovery
    tids = {int(k) >> ns.key_bits for k in oracle}
    assert tids == {0, 1, 5}

    for tid in (0, 1, 5):
        one = recover_namespace(str(tmp_path), _factory, tid, namespace=ns)
        assert one.key_range == ns.tenant_interval(tid)
        ok, ov = one.engine.dump_live()
        want = _scoped(oracle, ns.tenant_interval(tid))
        assert list(zip(ok.tolist(), ov.tolist())) == want, \
            f"namespace {tid} lost acked writes under scoped recovery"
        assert one.last_lsn == rr.last_lsn, "shared LSN watermark"


def test_wal_replay_key_range_filters_rows(tmp_path):
    from repro.wal import WriteAheadLog

    wal = WriteAheadLog(str(tmp_path))
    wal.append_commit(np.full(3, int(OpKind.INSERT), np.int8),
                      np.array([10, 20, 30], KEYS),
                      np.array([1, 2, 3], VALS))
    wal.append_commit(np.full(2, int(OpKind.INSERT), np.int8),
                      np.array([100, 200], KEYS), np.array([4, 5], VALS))
    recs = list(wal.replay(key_lo=15, key_hi=35))
    assert len(recs) == 1, "records left empty by the filter are skipped"
    assert recs[0].keys.tolist() == [20, 30]
    assert recs[0].vals.tolist() == [2, 3]
    assert [r.lsn for r in wal.replay()] == [1, 2], "unfiltered unchanged"
    wal.close()


# --------------------------------------------------------------- driver modes
def test_driver_multi_stream_closed_loop():
    from repro.workloads.driver import run_multi_workload

    wls = [make_workload("insert-heavy", n_ops=300, preload=64,
                         key_space=1 << 14, seed=0),
           make_workload("delete-churn", n_ops=300, preload=64,
                         key_space=1 << 14, seed=1)]
    eng = make_engine("nbtree", f=3, sigma=128)
    rep = run_multi_workload(eng, wls)
    assert len(rep["streams"]) == 2
    for s in rep["streams"]:
        assert s["per_kind"], "per-stream histograms present"
        assert sum(h["count"] for h in s["per_kind"].values()) == 300
    # namespaces are disjoint, so per-stream live pairs sum to the total
    assert sum(s["live_pairs"] for s in rep["streams"]) \
        == len(eng.dump_live()[0])


def test_driver_multi_stream_open_loop():
    from repro.workloads.driver import SCHEMA_VERSION, run_open_multi_workload

    wls = [make_workload("insert-heavy", n_ops=200, preload=64,
                         key_space=1 << 14, seed=0),
           make_workload("insert-heavy", n_ops=200, preload=64,
                         key_space=1 << 14, seed=1)]
    eng = make_engine("nbtree", f=3, sigma=128)
    rep = run_open_multi_workload(eng, wls, arrival="poisson", rate=20_000.0,
                                  weights=[2.0, 1.0])
    assert rep["schema_version"] == SCHEMA_VERSION
    ol = rep["open_loop"]
    assert ol["fair"] is True
    assert set(ol["tenants"]) == {"0", "1"}
    assert ol["tenants"]["0"]["weight"] == 2.0
    assert ol["n_done"] == 400


def test_driver_cli_multi_mix(tmp_path, capsys):
    from repro.workloads import driver

    out = tmp_path / "multi.json"
    driver.main(["--engines", "nbtree", "--mix", "insert-heavy",
                 "--mix", "point-read-heavy", "--ops", "200", "--batch",
                 "64", "--preload", "64", "--out", str(out)])
    import json
    data = json.loads(out.read_text())
    assert data["mix"] == ["insert-heavy", "point-read-heavy"]
    assert len(data["reports"][0]["streams"]) == 2
    assert "stream 1" in capsys.readouterr().out
