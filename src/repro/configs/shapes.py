"""Assigned input shapes and per-(arch x shape) applicability (DESIGN.md §6).

Four shapes per LM arch:
  train_4k     seq 4096,   global_batch 256  -> lowers train_step
  prefill_32k  seq 32768,  global_batch 32   -> lowers prefill (forward)
  decode_32k   kv 32768,   global_batch 128  -> lowers serve_step (1 token)
  long_500k    kv 524288,  global_batch 1    -> serve_step, sub-quadratic only
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_status(cfg, shape: ShapeSpec) -> str:
    """'run' or a skip reason for an (arch, shape) dry-run cell."""
    if shape.kind == "decode" and cfg.encoder_only:
        return "skip: encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "skip: full-attention arch (needs sub-quadratic attention)"
    return "run"


def all_cells(configs: dict) -> list:
    """All 40 (arch, shape) cells with status."""
    out = []
    for arch, cfg in configs.items():
        for sname, spec in SHAPES.items():
            out.append((arch, sname, cell_status(cfg, spec)))
    return out
