"""Shared benchmark utilities: workloads scaled for CPU wall-clock.

The paper's experiments insert up to 2e9 keys with sigma = 2 GB; here every
index runs the same *scaled* workload (n ~ 1e5..1e6 pairs, sigma scaled to
keep n/sigma and the level count in the paper's regime) under the explicit
I/O cost model (core/cost_model.py, the paper's own Seagate/SSD constants).
Reported numbers are simulated seconds — the measure the paper's theory
section is written in — plus host wall-clock for the data plane.
"""
from __future__ import annotations

import numpy as np

from repro.core.bepsilon import BEpsilonTree
from repro.core.btree import BPlusTree, BPlusTreeBulk
from repro.core.cost_model import HDD, SSD
from repro.core.lsm import LSMTree
from repro.core.refimpl import NBTree


def workload(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    keys = rng.integers(1, 1 << 48, size=int(n * 1.02), dtype=np.uint64)
    keys = np.unique(keys)[:n]          # dedupe (collisions ~n^2/2^49)
    assert len(keys) == n
    return rng.permutation(keys)


#: the paper's sigma is 64 MB..2 GB; simulation sigma is ~1e3..1e4 pairs.
#: A direct scale-down distorts the seek:stream ratio (a flush streams
#: sigma/f bytes per seek — 0.7 GB in the paper, tens of KB here), which
#: flips seek-amortization conclusions.  ``scaled_device`` shrinks T_seek by
#: the same factor as sigma so every per-operation seek:stream ratio matches
#: the paper's geometry at simulation scale.
REF_SIGMA_BYTES = 64 << 20


def scaled_device(base, sigma_pairs: int):
    from repro.core.cost_model import Device, PAIR_BYTES
    factor = max(1e-4, sigma_pairs * PAIR_BYTES / REF_SIGMA_BYTES)
    return Device(base.name + "-scaled", base.page_bytes,
                  base.seek_s * factor, base.read_bw, base.write_bw)


def insert_all(index, keys) -> tuple[float, float]:
    """(avg_insert_s, max_insert_s) over the whole workload."""
    times = [index.insert(k, i) for i, k in enumerate(keys)]
    total = index.cm.time
    return total / len(keys), float(np.max(times))


def query_sample(index, keys, n_q: int = 400, seed: int = 1):
    rng = np.random.default_rng(seed)
    q = rng.choice(keys, n_q, replace=False)
    times = []
    for k in q:
        _, t = index.query(k)
        times.append(t)
    return float(np.mean(times)), float(np.max(times))


def make_index(name: str, device, sigma_pairs: int):
    device = scaled_device(device, sigma_pairs)
    if name == "nbtree":
        return NBTree(f=3, sigma=sigma_pairs, device=device)
    if name == "nbtree-nobloom":
        return NBTree(f=3, sigma=sigma_pairs, device=device, use_bloom=False)
    if name == "nbtree-basic":
        return NBTree(f=3, sigma=sigma_pairs, device=device, deamortize=False)
    if name == "lsm":  # leveldb/rocksdb-style leveling + bloom
        return LSMTree(mem_pairs=sigma_pairs, ratio=10, device=device)
    if name == "blsm":  # bLSM-style level cap
        return LSMTree(mem_pairs=sigma_pairs, ratio=10, device=device, max_levels=3)
    if name == "bepsilon":
        return BEpsilonTree(node_bytes=1 << 16, cached_levels=1, device=device)
    if name == "btree":
        return BPlusTree(device=device)
    raise KeyError(name)


DEVICES = {"hdd": HDD, "ssd": SSD}
