"""Device-tier NB-tree (core/jax_nbtree): behaviour, invariants, ref-parity."""
import numpy as np
import pytest

from repro.core.jax_nbtree import NBTreeIndex
from repro.core.refimpl import NBTree as RefNBTree


def _keys(rng, n):
    return rng.choice(np.arange(1, 2**31, dtype=np.uint32), n, replace=False)


@pytest.fixture(scope="module")
def loaded():
    rng = np.random.default_rng(3)
    keys = _keys(rng, 20_000)
    idx = NBTreeIndex(f=4, sigma=1024, max_nodes=64)
    B = 512
    for i in range(0, len(keys), B):
        idx.insert_batch(keys[i:i + B], np.arange(i, i + len(keys[i:i + B]), dtype=np.int32))
        idx.maintain(2)
    idx.drain()
    return idx, keys


def test_roundtrip_and_invariants(loaded):
    idx, keys = loaded
    idx.check_invariants()
    present, vals = idx.query_batch(keys[:4096])
    assert np.array(present).all()
    assert np.array_equal(np.array(vals), np.arange(4096, dtype=np.int32))


def test_negatives(loaded):
    idx, keys = loaded
    rng = np.random.default_rng(4)
    neg = rng.integers(2**31, 2**32 - 2, 2048).astype(np.uint32)
    present, _ = idx.query_batch(neg)
    assert not np.array(present).any()


def test_delete_update():
    rng = np.random.default_rng(5)
    keys = _keys(rng, 6000)
    idx = NBTreeIndex(f=4, sigma=512, max_nodes=64)
    idx.insert_batch(keys, np.arange(len(keys), dtype=np.int32))
    idx.drain()
    idx.delete_batch(keys[:100])
    idx.insert_batch(keys[100:200], np.full(100, 42, np.int32))
    idx.drain()
    p, v = idx.query_batch(keys[:200])
    p, v = np.array(p), np.array(v)
    assert not p[:100].any()
    assert p[100:].all() and (v[100:] == 42).all()


def test_maintenance_budget_bounded():
    """maintain(k) performs at most k units — the deamortization contract."""
    rng = np.random.default_rng(6)
    idx = NBTreeIndex(f=4, sigma=512, max_nodes=128)
    keys = _keys(rng, 8000)
    max_pending_drop = 0
    for i in range(0, len(keys), 256):
        idx.insert_batch(keys[i:i + 256], np.arange(256, dtype=np.int32))
        before = len(idx._pending)
        idx.maintain(1)
        after = len(idx._pending)
        # one unit can retire at most one queue entry (it may also enqueue)
        max_pending_drop = max(max_pending_drop, before - after)
    assert max_pending_drop <= 1
    idx.drain()
    idx.check_invariants()


def test_parity_with_refimpl():
    """Same ops through both tiers -> same visible key-value map."""
    rng = np.random.default_rng(7)
    keys = _keys(rng, 4000)
    dev = NBTreeIndex(f=3, sigma=256, max_nodes=128)
    ref = RefNBTree(f=3, sigma=256)
    dev.insert_batch(keys, np.arange(len(keys), dtype=np.int32))
    dev.drain()
    for i, k in enumerate(keys):
        ref.insert(np.uint64(k), i)
    ref.drain()
    q = rng.choice(keys, 500, replace=False)
    p, v = dev.query_batch(q)
    p, v = np.array(p), np.array(v)
    for j, k in enumerate(q):
        rv = ref.get(np.uint64(k))
        assert p[j] and v[j] == rv, (k, v[j], rv)


def test_grow_tables():
    rng = np.random.default_rng(8)
    idx = NBTreeIndex(f=3, sigma=64, max_nodes=8)   # forces growth
    keys = _keys(rng, 3000)
    idx.insert_batch(keys, np.arange(len(keys), dtype=np.int32))
    idx.drain()
    idx.check_invariants()
    assert idx.max_nodes > 8
    p, _ = idx.query_batch(keys[:512])
    assert np.array(p).all()
