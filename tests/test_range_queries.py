"""Differential tests for the range-query subsystem (no hypothesis needed).

Random interleavings of insert_batch / delete_batch / maintain / drain are
applied identically to the device tier, the paper-faithful reference
implementation, and a sorted-dict oracle; ``range_query_batch`` must match
both at every interleaving point — including mid-maintenance states, empty
ranges, lo == hi, and ranges spanning node splits.  Batch sizes are drawn
from a fixed set so interpret-mode Pallas kernels compile once per shape.
"""
import numpy as np
import pytest

from repro.core.btree import BPlusTree, BPlusTreeBulk
from repro.core.jax_nbtree import NBTreeIndex
from repro.core.lsm import LSMTree
from repro.core.refimpl import NBTree as RefNBTree

KEYSPACE = 50_000
BATCH_SIZES = (32, 64, 128)
MAXR = 8192  # large enough that differential runs are never truncated


def _oracle_range(model, lo, hi):
    ks = sorted(k for k in model if lo <= k <= hi)
    return ks, [model[k] for k in ks]


def _ranges(rng, dev, model):
    """8 ranges/checkpoint: random spans + point + inverted + full + splits."""
    out = [(1, KEYSPACE)]                                    # full key space
    if model:
        k = int(rng.choice(sorted(model)))
        out.append((k, k))                                   # lo == hi, hit
    out.append((KEYSPACE // 2, KEYSPACE // 3))               # inverted: empty
    if dev.root.skeys:                                       # spans a split
        s = int(dev.root.skeys[0])
        out.append((max(1, s - 200), s + 200))
    while len(out) < 8:
        lo = int(rng.integers(1, KEYSPACE))
        out.append((lo, lo + int(rng.integers(0, KEYSPACE // 4))))
    return out[:8]


def _check_all(dev, ref, model, rng):
    ranges = _ranges(rng, dev, model)
    los = np.array([r[0] for r in ranges], np.uint32)
    his = np.array([r[1] for r in ranges], np.uint32)
    k, v, c, trunc = dev.range_query_batch(los, his, max_results=MAXR)
    k, v, c, trunc = np.array(k), np.array(v), np.array(c), np.array(trunc)
    for i, (lo, hi) in enumerate(ranges):
        ek, ev = _oracle_range(model, lo, hi)
        assert not trunc[i], (lo, hi)
        assert c[i] == len(ek), (lo, hi, int(c[i]), len(ek))
        assert k[i, : c[i]].tolist() == ek, (lo, hi)
        assert v[i, : c[i]].tolist() == ev, (lo, hi)
        rk, rv = ref.range_query(lo, hi)
        assert rk.tolist() == ek and rv.tolist() == ev, (lo, hi)


@pytest.mark.parametrize("seed", range(5))
def test_interleaved_ops_match_oracle_and_refimpl(seed):
    rng = np.random.default_rng(seed)
    dev = NBTreeIndex(f=3, sigma=128, max_nodes=64)
    ref = RefNBTree(f=3, sigma=128)
    model = {}
    for _ in range(10):
        op = rng.choice(["insert", "insert", "insert", "delete", "maintain",
                         "drain"])
        if op == "insert":
            n = int(rng.choice(BATCH_SIZES))
            ks = rng.integers(1, KEYSPACE, n).astype(np.uint32)
            vs = rng.integers(0, 2**20, n).astype(np.int32)
            dev.insert_batch(ks, vs)
            for kk, vv in zip(ks.tolist(), vs.tolist()):
                ref.insert(kk, vv)
                model[kk] = vv
        elif op == "delete":
            n = int(rng.choice(BATCH_SIZES))
            pool = sorted(model) if model else [1]
            ks = rng.choice(np.array(pool, np.uint32), n)  # mostly present
            ks[:: 4] = rng.integers(1, KEYSPACE, len(ks[::4]))  # some absent
            dev.delete_batch(ks)
            for kk in ks.tolist():
                ref.delete(kk)
                model.pop(kk, None)
        elif op == "maintain":
            dev.maintain(int(rng.integers(1, 4)))
        else:
            dev.drain()
            ref.drain()
        _check_all(dev, ref, model, rng)
    dev.drain()
    ref.drain()
    dev.check_invariants()
    ref.check_invariants()
    _check_all(dev, ref, model, rng)


def test_tombstones_never_resurface_across_maintenance():
    """Deleted keys must stay deleted across flush / split / leaf-compaction
    boundaries (regression: _compact_tombstones used to drop only the
    tombstone record, resurrecting the stale older copy it deleted)."""
    rng = np.random.default_rng(42)
    dev = NBTreeIndex(f=3, sigma=128, max_nodes=64)
    keys = rng.choice(np.arange(1, KEYSPACE, dtype=np.uint32), 4000,
                      replace=False)

    def insert(ks, v0):
        for i in range(0, len(ks), 128):
            ch = ks[i : i + 128]
            dev.insert_batch(ch, np.arange(v0 + i, v0 + i + len(ch),
                                           dtype=np.int32))
            dev.maintain(2)

    insert(keys[:2000], 0)
    dev.drain()
    deleted = keys[:256]
    dev.delete_batch(deleted)           # tombstones enter the root
    survivors = {int(k): i for i, k in enumerate(keys.tolist())
                 if i >= 256 and i < 2000}

    def assert_no_resurrection():
        k, v, c, trunc = dev.range_query_batch(
            np.array([1], np.uint32), np.array([KEYSPACE], np.uint32),
            max_results=MAXR)
        got = dict(zip(np.array(k)[0, : int(np.array(c)[0])].tolist(),
                       np.array(v)[0, : int(np.array(c)[0])].tolist()))
        assert not bool(np.array(trunc)[0])
        hit = set(got) & {int(x) for x in deleted.tolist()}
        assert not hit, f"deleted keys resurfaced: {sorted(hit)[:10]}"
        for kk, vv in survivors.items():
            assert got.get(kk) == vv, kk
        p, _ = dev.query_batch(deleted)
        assert not np.array(p).any()

    assert_no_resurrection()
    # deeper cascades push the tombstones through flushes and leaf
    # compaction; splits rearrange the runs they pass through.
    insert(keys[2000:], 2000)
    survivors.update({int(k): 2000 + i for i, k in
                      enumerate(keys[2000:].tolist())})
    assert_no_resurrection()
    dev.drain()
    dev.check_invariants()
    assert_no_resurrection()


def test_flush_never_splits_duplicate_group():
    """Regression: _flush's moved-boundary cut must not separate duplicate
    copies of one key (fresh copy flushed down, stale copy left in the
    ancestor would invert the ancestors-are-fresher rule)."""
    dev = NBTreeIndex(f=3, sigma=8, max_nodes=16)
    dev.insert_batch(np.arange(1, 8, dtype=np.uint32),
                     np.arange(7, dtype=np.int32))
    dev.drain()                                   # root becomes internal
    dev.insert_batch(np.array([100], np.uint32), np.array([111], np.int32))
    dev.insert_batch(np.array([100], np.uint32), np.array([222], np.int32))
    # root run now ends [. . (100,222), (100,111)]; sigma cut falls between
    dev.drain()
    p, v = dev.query_batch(np.array([100], np.uint32))
    assert bool(np.array(p)[0]) and int(np.array(v)[0]) == 222
    k, v, c, _ = dev.range_query_batch([100], [100], max_results=8)
    assert int(np.array(c)[0]) == 1 and int(np.array(v)[0, 0]) == 222


@pytest.mark.parametrize("make", [
    lambda: RefNBTree(f=3, sigma=64),
    lambda: LSMTree(mem_pairs=64),
    lambda: BPlusTree(),
], ids=["refimpl", "lsm", "btree"])
def test_baseline_range_matches_oracle(rng, make):
    idx = make()
    model = {}
    keys = rng.choice(np.arange(1, KEYSPACE, dtype=np.uint64), 1500,
                      replace=False)
    for i, k in enumerate(keys.tolist()):
        idx.insert(k, i)
        model[k] = i
    for k in keys[::5].tolist():
        idx.delete(k)
        model.pop(k, None)
    for lo, hi in [(1, KEYSPACE), (KEYSPACE, 1), (int(keys[7]), int(keys[7])),
                   (KEYSPACE // 4, KEYSPACE // 2), (0, 0)]:
        rk, rv = idx.range_query(lo, hi)
        ek, ev = _oracle_range(model, lo, hi)
        assert rk.tolist() == ek, (lo, hi)
        assert rv.tolist() == ev, (lo, hi)


def test_bulk_btree_range(rng):
    keys = rng.choice(np.arange(1, KEYSPACE, dtype=np.uint64), 2000,
                      replace=False)
    bt = BPlusTreeBulk(keys, np.arange(2000, dtype=np.int64))
    model = {int(k): i for i, k in enumerate(keys.tolist())}
    for lo, hi in [(1, KEYSPACE), (KEYSPACE // 3, KEYSPACE // 2),
                   (int(keys[0]), int(keys[0])), (9, 3)]:
        rk, rv = bt.range_query(lo, hi)
        ek, ev = _oracle_range(model, lo, hi)
        assert rk.tolist() == ek and rv.tolist() == ev, (lo, hi)


def test_kernel_backed_scan_matches_device_root():
    """ops.range_scan over a node row == the single-node slice of the fused
    descent (kernel and descent share search + gather semantics)."""
    rng = np.random.default_rng(7)
    dev = NBTreeIndex(f=4, sigma=1024, max_nodes=16)
    keys = rng.choice(np.arange(1, 2**20, dtype=np.uint32), 800, replace=False)
    dev.insert_batch(keys, np.arange(800, dtype=np.int32))   # stays in root
    from repro.kernels import ops

    lo = np.array([1, 2**19], np.uint32)
    hi = np.array([2**19, 2**20], np.uint32)
    k1, v1, c1 = ops.range_scan(dev.run_keys[0], dev.run_vals[0],
                                lo, hi, max_results=1024)
    k2, v2, c2, _ = dev.range_query_batch(lo, hi, max_results=1024)
    assert np.array_equal(np.array(c1), np.array(c2))
    n0, n1 = int(np.array(c1)[0]), int(np.array(c1)[1])
    assert np.array_equal(np.array(k1)[0, :n0], np.array(k2)[0, :n0])
    assert np.array_equal(np.array(v1)[1, :n1], np.array(v2)[1, :n1])
