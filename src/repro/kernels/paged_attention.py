"""Pallas TPU kernel: decode attention over a paged KV cache.

This is where the paper's index meets the model: block tables are produced
by the NB-tree page index (serve/kv_cache.py) — logical page p of sequence b
lives at physical page ``block_tables[b, p]``.  The kernel streams those
pages HBM->VMEM with *scalar prefetch* (the block table rides in SMEM and is
consumed by the BlockSpec index_map, so the DMA for page p+1 is issued while
page p is being processed — sequential streaming over a scattered physical
layout, exactly the paper's seek-free design goal transplanted to HBM).

Flash-decoding style: online softmax over pages with fp32 running (m, l,
acc) carried in VMEM scratch across grid steps; output written at the last
page step of each (batch, kv-head).

Shapes (G = query heads per KV head, S = page slots):
  q             (B, KVH, G, D)
  k_pages       (KVH, P, S, D)
  v_pages       (KVH, P, S, D)
  block_tables  (B, MP) int32
  seq_lens      (B,)    int32
  out           (B, KVH, G, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(block_tables_ref, seq_lens_ref,   # scalar prefetch
                       q_ref, k_ref, v_ref,               # VMEM blocks
                       o_ref,                             # output block
                       m_ref, l_ref, acc_ref,             # VMEM scratch
                       *, page_size: int, max_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)           # (S, D)
    v = v_ref[0, 0].astype(jnp.float32)           # (S, D)
    d = q.shape[-1]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (1.0 / (d ** 0.5))                    # (G, S)

    valid = seq_lens_ref[b] - p * page_size
    slot = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(slot < valid, s, NEG_INF)

    m_prev = m_ref[:, 0:1]                        # (G, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)    # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)               # rescale of old state
    p_exp = jnp.exp(s - m_new)                    # (G, S)
    l_new = alpha * l_ref[:, 0:1] + jnp.sum(p_exp, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p_exp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(p == max_pages - 1)
    def _finish():
        l = l_ref[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)           # empty sequence guard
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    interpret: bool = True):
    """Decode attention; see module docstring for shapes."""
    B, KVH, G, D = q.shape
    _, P, S, _ = k_pages.shape
    MP = block_tables.shape[1]

    g_pad = max(8, -(-G // 8) * 8)
    if g_pad != G:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, g_pad - G), (0, 0)))

    grid = (B, KVH, MP)
    kernel = functools.partial(_paged_attn_kernel, page_size=S, max_pages=MP)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g_pad, D), lambda b, h, p, bt, sl: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, S, D), lambda b, h, p, bt, sl: (h, bt[b, p], 0, 0)),
                pl.BlockSpec((1, 1, S, D), lambda b, h, p, bt, sl: (h, bt[b, p], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g_pad, D), lambda b, h, p, bt, sl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g_pad, 128), jnp.float32),   # m
                pltpu.VMEM((g_pad, 128), jnp.float32),   # l
                pltpu.VMEM((g_pad, D), jnp.float32),     # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KVH, g_pad, D), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, q, k_pages, v_pages)
    return out[:, :, :G]
