"""Bloom filters (paper Sec. 5.2) — host (numpy) implementation.

One filter per d-tree; k bits/key and h hash functions.  The paper's
configuration (k=8, h=3 → <5% FP; experiments use 10 bits/key) is the
default.  Hashing is multiply-shift over uint64 keys — the same family the
``bloom_filter`` Pallas kernel vectorizes on TPU (kernels/bloom_filter.py).
"""
from __future__ import annotations

import numpy as np

# odd 64-bit multipliers (splitmix64 / Murmur finalizer constants).
_MULTS = np.array(
    [
        0xFF51AFD7ED558CCD,
        0xC4CEB9FE1A85EC53,
        0x9E3779B97F4A7C15,
        0xBF58476D1CE4E5B9,
        0x94D049BB133111EB,
        0x2545F4914F6CDD1D,
    ],
    dtype=np.uint64,
)


def _hashes(keys: np.ndarray, h: int, nbits: int) -> np.ndarray:
    """(h, n) array of bit positions in [0, nbits)."""
    keys = keys.astype(np.uint64)[None, :]
    m = _MULTS[:h, None]
    with np.errstate(over="ignore"):
        x = keys * m
        x ^= x >> np.uint64(33)
        x *= np.uint64(0xC2B2AE3D27D4EB4F)
        x ^= x >> np.uint64(29)
    return (x % np.uint64(nbits)).astype(np.int64)


class BloomFilter:
    def __init__(self, capacity: int, bits_per_key: int = 10, num_hashes: int = 3):
        self.nbits = max(64, int(capacity * bits_per_key))
        self.h = num_hashes
        self.bits = np.zeros((self.nbits + 63) // 64, dtype=np.uint64)

    @property
    def size_bytes(self) -> int:
        return self.bits.nbytes

    def add(self, keys: np.ndarray) -> None:
        keys = np.atleast_1d(np.asarray(keys, dtype=np.uint64))
        if keys.size == 0:
            return
        pos = _hashes(keys, self.h, self.nbits).ravel()
        np.bitwise_or.at(self.bits, pos >> 6, np.uint64(1) << (pos & 63).astype(np.uint64))

    def contains(self, keys: np.ndarray) -> np.ndarray:
        """Vectorized membership test → bool array (no false negatives)."""
        keys = np.atleast_1d(np.asarray(keys, dtype=np.uint64))
        if keys.size == 0:
            return np.zeros(0, bool)
        pos = _hashes(keys, self.h, self.nbits)  # (h, n)
        word = self.bits[pos >> 6]
        bit = (word >> (pos & 63).astype(np.uint64)) & np.uint64(1)
        return bit.all(axis=0) == 1

    @staticmethod
    def build(keys: np.ndarray, bits_per_key: int = 10, num_hashes: int = 3) -> "BloomFilter":
        bf = BloomFilter(max(1, len(keys)), bits_per_key, num_hashes)
        bf.add(keys)
        return bf
