"""Render dry-run JSONL records as the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report runs/dryrun_baseline.jsonl [--mesh single]
"""
from __future__ import annotations

import argparse
import json


def load(path, mesh=None):
    recs = [json.loads(l) for l in open(path)]
    if mesh:
        recs = [r for r in recs if r.get("mesh_kind") == mesh]
    return recs


MOVE_HINT = {
    "compute": "raise arithmetic intensity (fuse, larger tiles/microbatch)",
    "memory": "cut HBM traffic (blockwise attn, bf16 streams, in-place cache)",
    "collective": "cut wire bytes (local dispatch, sharded weights, int8 DCN)",
}


def table(recs):
    lines = [
        "| mesh | arch | shape | peak GiB | t_comp s | t_mem s | t_coll s "
        "| bottleneck | MODEL_FLOPs/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        tmax = max(ro["t_compute"], ro["t_memory"], ro["t_collective"], 1e-12)
        frac = ro["t_compute"] / tmax
        lines.append(
            f"| {r['mesh_kind']} | {r['arch']} | {r['shape']} "
            f"| {r['memory_analysis']['peak_gib']:.2f} "
            f"| {ro['t_compute']:.4f} | {ro['t_memory']:.4f} "
            f"| {ro['t_collective']:.4f} | {ro['bottleneck']} "
            f"| {min(ro['useful_flops_ratio'], 9.99):.3f} | {frac*100:.1f}% |")
    skips = [r for r in recs if r["status"].startswith("skip")]
    if skips:
        lines.append("")
        lines.append("Skipped cells (per assignment rules):")
        for r in sorted({(r["arch"], r["shape"], r["status"]) for r in skips}):
            lines.append(f"* {r[0]} x {r[1]} — {r[2]}")
    return "\n".join(lines)


def bottleneck_summary(recs):
    out = []
    for r in recs:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        out.append(f"* {r['arch']} x {r['shape']} [{r['mesh_kind']}]: "
                   f"{ro['bottleneck']}-bound -> {MOVE_HINT[ro['bottleneck']]}")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--hints", action="store_true")
    args = ap.parse_args()
    recs = load(args.path, args.mesh)
    print(table(recs))
    if args.hints:
        print()
        print(bottleneck_summary(recs))


if __name__ == "__main__":
    main()
