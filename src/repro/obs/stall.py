"""Stalled-window detection and span-category attribution.

A *stall window* is a timeline window whose p99 latency exceeds ``k``
times the trailing median p99 of the preceding non-empty windows — the
windowed analogue of the per-op ``slo.STALL_FACTOR`` rule, and the form
Luo & Carey use to quantify LSM write-stall behaviour.  The trailing
median (rather than the run-wide median) makes the detector causal: a
diurnal rate swing moves the baseline slowly, while a compaction stall
spikes a window far above its own recent history.

Attribution then answers *why*: for each stalled window, the span
category (from ``obs.trace.SPAN_CATEGORIES``) with the largest total
overlapping duration is the dominant concurrent activity.  On the
NB-tree tier that is typically ``commit`` (service time itself), on a
saw-toothing LSM it is ``cascade`` (a forced multi-level merge), and
after a crash it is ``recovery`` — which is exactly the narrative the
stability figure needs to tell.
"""
from __future__ import annotations

import statistics


def detect_stalls(windows: list[dict], *, k: float = 4.0,
                  trailing: int = 16, min_history: int = 4) -> list[dict]:
    """Return stalled windows as ``[{index, t_start_s, t_end_s, p99_s,
    baseline_p99_s}]``.

    ``windows`` are timeline rows from :class:`~repro.obs.metrics.
    WindowedMetrics` (need ``ops``, ``p99_s``, ``t_start_s``,
    ``t_end_s``).  Empty windows never stall and never enter the
    baseline.  The first ``min_history`` non-empty windows are exempt
    (no meaningful baseline yet).
    """
    out = []
    history: list[float] = []
    for i, w in enumerate(windows):
        if w["ops"] <= 0:
            continue
        if len(history) >= min_history:
            base = statistics.median(history[-trailing:])
            if base > 0 and w["p99_s"] > k * base:
                out.append({"index": i, "t_start_s": w["t_start_s"],
                            "t_end_s": w["t_end_s"], "p99_s": w["p99_s"],
                            "baseline_p99_s": base})
                # a stalled window is excluded from the baseline so a
                # long stall does not normalise itself away
                continue
        history.append(w["p99_s"])
    return out


def _overlap_s(ev: dict, t0_s: float, t1_s: float) -> float:
    """Seconds of an X-span event overlapping [t0_s, t1_s)."""
    s0 = ev["ts"] / 1e6
    s1 = s0 + ev.get("dur", 0.0) / 1e6
    return max(0.0, min(s1, t1_s) - max(s0, t0_s))


def attribute_stalls(stalls: list[dict], events: list[dict]) -> list[dict]:
    """Annotate each stall with its dominant concurrent span category.

    ``events`` are Chrome trace events (e.g. ``Tracer.events()``); only
    complete ("X") spans participate.  Each stall gains ``cause`` (the
    category with the most overlapping busy time, or ``"unknown"`` when
    no span overlaps) and ``cause_overlap_s`` breakdowns.
    """
    xs = [e for e in events if e.get("ph") == "X"]
    out = []
    for st in stalls:
        t0, t1 = st["t_start_s"], st["t_end_s"]
        by_cat: dict[str, float] = {}
        for e in xs:
            ov = _overlap_s(e, t0, t1)
            if ov > 0.0:
                by_cat[e["cat"]] = by_cat.get(e["cat"], 0.0) + ov
        if by_cat:
            # deterministic tie-break: largest overlap, then category name
            cause = max(sorted(by_cat), key=lambda c: by_cat[c])
        else:
            cause = "unknown"
        out.append({**st, "cause": cause,
                    "cause_overlap_s": {c: round(v, 9)
                                        for c, v in sorted(by_cat.items())}})
    return out
