"""Key-space partitioners for the sharded storage layer (DESIGN.md §6).

A partitioner maps every key to exactly one shard id and every inclusive
range ``[lo, hi]`` to the set of shards it may touch.  Two strategies:

* :class:`RangePartitioner` — contiguous key intervals separated by sorted
  pivots, shard ``i`` serving ``[pivot[i-1], pivot[i])`` (the first/last
  intervals are open toward 0 / key-max).  Pivots are sampled as quantiles
  of the first observed insert keys (:meth:`RangePartitioner.from_sample`),
  so the initial split mirrors the ingest distribution; skew that develops
  later is fixed by :meth:`split` (hot-shard splitting — the engine decides
  *when*, the partitioner implements *where*).  Range ops touch only the
  shards whose intervals intersect, which is what keeps the sharded range
  fan-out narrow.
* :class:`HashPartitioner` — splitmix64-scattered modulo placement.  Ideal
  balance under any key distribution, but every range op must fan out to
  all shards and the layout cannot be rebalanced (``can_split`` is False).

Both are pure routing tables: no engine state, no I/O cost — which is what
makes them unit-testable in isolation and reusable by the driver and the
scaling benchmark.
"""
from __future__ import annotations

import numpy as np

from repro.core.sorted_run import KEY_DTYPE
from repro.core.splitmix import splitmix64 as _splitmix64


class RangePartitioner:
    """Sorted-pivot range partitioning with dynamic shard splitting."""

    can_split = True

    def __init__(self, pivots):
        self.pivots = np.asarray(sorted(int(p) for p in pivots), KEY_DTYPE)
        assert len(np.unique(self.pivots)) == len(self.pivots), \
            "pivots must be distinct"

    @staticmethod
    def from_sample(keys, n_shards: int) -> "RangePartitioner":
        """Quantile pivots from a key sample; duplicates collapse, so the
        effective shard count is ``len(pivots) + 1 <= n_shards``."""
        assert n_shards >= 1
        keys = np.unique(np.asarray(keys, KEY_DTYPE))
        if n_shards == 1 or len(keys) < 2:
            return RangePartitioner([])
        qs = (np.arange(1, n_shards) * len(keys)) // n_shards
        return RangePartitioner(np.unique(keys[np.minimum(qs, len(keys) - 1)]))

    @staticmethod
    def even(n_shards: int, key_hi: int) -> "RangePartitioner":
        """Evenly spaced pivots over ``[0, key_hi)`` — the distribution-free
        bootstrap the replication layer uses when no preload sample exists
        (replica groups need their key intervals before the first batch)."""
        assert n_shards >= 1 and key_hi >= n_shards
        return RangePartitioner(sorted({(i * key_hi) // n_shards
                                        for i in range(1, n_shards)}))

    @property
    def n_shards(self) -> int:
        return len(self.pivots) + 1

    def shard_of(self, keys) -> np.ndarray:
        """Vectorized key -> shard id (#pivots <= key)."""
        keys = np.asarray(keys, KEY_DTYPE)
        return np.searchsorted(self.pivots, keys, side="right")

    def shards_for_range(self, lo: int, hi: int) -> range:
        """Ids of every shard whose interval intersects ``[lo, hi]``."""
        if lo > hi:
            return range(0)
        s0 = int(np.searchsorted(self.pivots, np.uint64(lo), side="right"))
        s1 = int(np.searchsorted(self.pivots, np.uint64(hi), side="right"))
        return range(s0, s1 + 1)

    def interval(self, sid: int) -> tuple[int, int]:
        """Shard ``sid``'s inclusive key interval ``[lo, hi]``."""
        lo = 0 if sid == 0 else int(self.pivots[sid - 1])
        hi = (int(np.iinfo(KEY_DTYPE).max) if sid == len(self.pivots)
              else int(self.pivots[sid]) - 1)
        return lo, hi

    def split(self, sid: int, new_pivot: int) -> None:
        """Split shard ``sid`` at ``new_pivot``: keys ``< new_pivot`` stay in
        ``sid``, keys ``>= new_pivot`` move to the new shard ``sid + 1``."""
        lo, hi = self.interval(sid)
        assert lo < new_pivot <= hi, (lo, new_pivot, hi)
        self.pivots = np.insert(self.pivots, sid, np.uint64(new_pivot))


class HashPartitioner:
    """Splitmix64-scattered modulo placement (static, range-oblivious)."""

    can_split = False

    def __init__(self, n_shards: int):
        assert n_shards >= 1
        self._n = int(n_shards)

    @property
    def n_shards(self) -> int:
        return self._n

    def shard_of(self, keys) -> np.ndarray:
        keys = np.asarray(keys, KEY_DTYPE)
        return (_splitmix64(keys) % np.uint64(self._n)).astype(np.int64)

    def shards_for_range(self, lo: int, hi: int) -> range:
        return range(0) if lo > hi else range(self._n)
