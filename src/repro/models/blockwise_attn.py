"""Blockwise (flash-style) attention in pure XLA — the memory-term fix.

Naive SDPA materializes (B, H, S, T) fp32 scores; at 32k context that is
tens-to-hundreds of GiB per device (the dominant memory term of every
train/prefill baseline cell — EXPERIMENTS.md §Perf).  This implements the
FlashAttention recurrence: an outer ``lax.map`` over query chunks and an
inner ``lax.scan`` over KV chunks with online softmax (running m, l, acc),
the chunk body rematerialized (jax.checkpoint) so backward recomputes chunk
scores instead of saving them.  Peak attention footprint per layer drops
from O(S*T) to O(q_chunk * kv_chunk) — 67 MB instead of 137 GB for the
qwen3 train_4k backward, 86 s -> sub-second memory term for hymba prefill.

Pure-XLA rather than Pallas so it differentiates for training out of the
box; the Pallas decode path (kernels/paged_attention.py) covers the serving
hot loop.  Masks (causal / sliding-window / bidir / cache-position) are
computed analytically per chunk pair from positions — never materialized at
(S, T).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def blockwise_sdpa(q, k, v, *, qpos, kpos, kind: str = "causal",
                   window: int | None = None, q_chunk: int = 512,
                   kv_chunk: int = 1024, kv_scales=None):
    """q (B,S,H,D), k/v (B,T,KVH,D), qpos (B,S), kpos (B,T) -> (B,S,H,D).

    kpos < 0 marks invalid (unwritten cache) slots.  Semantics identical to
    the naive softmax attention + position masks; tested for parity.
    ``kv_scales`` = (k_scale, v_scale) (B,T,KVH) enables int8 K/V: chunks are
    dequantized in-register per tile (HBM reads stay int8 — the 2x decode
    bandwidth win of EXPERIMENTS.md §Perf It.7).
    """
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    Dv = v.shape[3]              # may differ from D (MLA: qk 96, v 64)
    out_dtype = v.dtype if kv_scales is None else q.dtype
    # never pad queries past the actual sequence (decode: S=1 -> qc=8).
    q_chunk = min(q_chunk, max(8, -(-S // 8) * 8))

    q5 = q.reshape(B, S, KVH, G, D).astype(jnp.float32) / np.sqrt(D)
    q5, S0 = _pad_to(q5, 1, q_chunk)
    qpos_p, _ = _pad_to(qpos, 1, q_chunk)
    Sp = q5.shape[1]
    nq = Sp // q_chunk

    k, T0 = _pad_to(k, 1, kv_chunk)
    v, _ = _pad_to(v, 1, kv_chunk)
    kpos, _ = _pad_to(kpos, 1, kv_chunk)
    T = k.shape[1]
    kpos = jnp.where(jnp.arange(T)[None, :] >= T0, -1, kpos)
    nc = T // kv_chunk

    kc = k.reshape(B, nc, kv_chunk, KVH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, kv_chunk, KVH, Dv).transpose(1, 0, 2, 3, 4)
    pc = kpos.reshape(B, nc, kv_chunk).transpose(1, 0, 2)
    if kv_scales is not None:
        ks_, vs_ = kv_scales
        ks_, _ = _pad_to(ks_, 1, kv_chunk)
        vs_, _ = _pad_to(vs_, 1, kv_chunk)
        ksc = ks_.reshape(B, nc, kv_chunk, KVH).transpose(1, 0, 2, 3)
        vsc = vs_.reshape(B, nc, kv_chunk, KVH).transpose(1, 0, 2, 3)
    else:  # unit scales keep one code path
        ksc = vsc = jnp.ones((nc, 1, 1, 1), jnp.float32)

    qs = q5.reshape(B, nq, q_chunk, KVH, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp = qpos_p.reshape(B, nq, q_chunk).transpose(1, 0, 2)

    def per_q_chunk(args):
        qi, qpi = args                            # (B,qc,KVH,G,D), (B,qc)

        def chunk_body(carry, xs):
            m, l, acc = carry
            kc_i, vc_i, pc_i, ks_i, vs_i = xs     # (B,c,KVH,D), (B,c), (B,c,KVH)
            kf = kc_i.astype(jnp.float32) * ks_i[..., None]  # in-register dequant
            vf = vc_i.astype(jnp.float32) * vs_i[..., None]
            s = jnp.einsum("bskgd,bckd->bkgsc", qi, kf)
            valid = pc_i[:, None, None, None, :] >= 0
            if kind == "causal":
                valid = valid & (pc_i[:, None, None, None, :]
                                 <= qpi[:, None, None, :, None])
                if window is not None:
                    valid = valid & (pc_i[:, None, None, None, :]
                                     > qpi[:, None, None, :, None] - window)
            s = jnp.where(valid, s, NEG_INF)
            m_c = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, m_c)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, -1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgsc,bckd->bkgsd", p, vf)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, q_chunk, Dv), jnp.float32)
        # remat: backward recomputes chunk scores, never saves (..., s, c).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(chunk_body),
                                      (m0, l0, a0), (kc, vc, pc, ksc, vsc))
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l[..., None]).astype(out_dtype)  # (B,KVH,G,qc,D)

    outs = jax.lax.map(per_q_chunk, (qs, qp))     # (nq,B,KVH,G,qc,Dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, KVH, G, Dv)
    return out[:, :S0].reshape(B, S0, H, Dv)


#: score-tensor element threshold above which attention goes blockwise.
BLOCKWISE_THRESHOLD = 32 * 1024 * 1024


def should_use_blockwise(B, S, T, H) -> bool:
    return B * S * T * H > BLOCKWISE_THRESHOLD


def tile_schedule(S: int, T: int, q_chunk: int = 512, kv_chunk: int = 1024):
    """(nq, nc, qc, kc) the kernel will actually run — for the roofline's
    analytic supplement (XLA cost analysis counts loop bodies once)."""
    qc = min(q_chunk, max(8, -(-S // 8) * 8))
    Sp = -(-S // qc) * qc
    Tp = -(-T // kv_chunk) * kv_chunk
    return Sp // qc, Tp // kv_chunk, qc, kv_chunk


def analytic_costs(B, S, T, H, D, KVH, kind="train", dtype_bytes=2):
    """Per-layer attention (flops, hbm_bytes) the blockwise kernel implies.

    flops: 4*B*qc*kc*H*D per tile (QK^T + PV), all nq*nc tiles computed
    (masked tiles still run — data-independent schedule).  Backward of a
    rematerialized flash layer recomputes forward and differentiates:
    ~3.5x forward flops for training.
    hbm  : K and V chunks re-stream once per q-chunk pass (the flash
    traffic model: (nq) * T * KVH * D * 2), plus Q/out once.
    """
    nq, nc, qc, kc = tile_schedule(S, T)
    fwd = 4.0 * B * (nq * qc) * (nc * kc) * H * D
    flops = fwd * (3.5 if kind == "train" else 1.0)
    hbm = (nq * (nc * kc) * KVH * D * 2 * dtype_bytes * B
           + 2 * B * S * H * D * dtype_bytes)
    hbm = hbm * (3.0 if kind == "train" else 1.0)
    return flops, hbm

