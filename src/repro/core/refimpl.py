"""Paper-faithful NB-tree reference implementation (Secs. 3-5 of the paper).

This is the *verbatim* pointer-based algorithm — ``HandleFullSNode``,
``SNodeSplit``, ``flush``, the advanced-version modifications (single
recursive call, lazy removal watermarks, deamortization) and per-d-tree
Bloom filters — executed against the explicit I/O cost model of
``cost_model.py``.  It serves three roles:

1. the oracle for property tests of the device-tier ``jax_nbtree``;
2. the driver for the paper-figure benchmarks (Figs. 4-9, Tables 1-2);
3. executable documentation of the algorithm.

Deamortization (paper Sec. 5.1) is implemented at *page quantum*
granularity: a pending root-buffer cascade is described by a generator that
yields once per simulated page of I/O, and every subsequent insertion
advances it by a bounded number of quanta.  Structure mutations commit
atomically at child-merge boundaries, so queries interleaved with a pending
cascade always see a consistent tree.  This realizes the paper's
``O(log_f(n/sigma) * (f/B * T_seq + f/sigma * T_seek))`` worst-case
insertion bound: per insertion, O(height * f/B) pages plus O(height * f)
seeks amortized over sigma insertions.
"""
from __future__ import annotations

import numpy as np

from .bloom import BloomFilter
from .cost_model import PAIR_BYTES, CostModel, Device, HDD
from .sorted_run import (KEY_DTYPE, TOMBSTONE, VAL_DTYPE, Run, drop_tombstones,
                         merge_runs, partition_by_pivots)


class SNode:
    """An s-node: pivots (s-keys), children, and its d-tree (a sorted run)."""

    __slots__ = ("skeys", "children", "run", "bloom", "parent")

    def __init__(self, parent=None):
        self.skeys: list = []          # sorted pivot keys, len == len(children)-1
        self.children: list = []       # empty <=> leaf s-node
        self.run: Run = Run.empty()    # the node's d-tree as an on-disk run
        self.bloom: BloomFilter | None = None
        self.parent: SNode | None = parent

    @property
    def is_leaf(self) -> bool:
        return not self.children

    def child_for(self, key) -> "SNode":
        """Descend per the cross-s-node linkage property (Sec. 3.1.1)."""
        i = int(np.searchsorted(np.asarray(self.skeys, dtype=KEY_DTYPE), key, side="right"))
        return self.children[i]


class NBTree:
    """The final (advanced, Sec. 5) NB-tree.

    Parameters mirror the paper: ``f`` s-tree fanout, ``sigma`` d-tree
    capacity in pairs, ``bits_per_key`` Bloom sizing.  ``deamortize=False``
    recovers the basic version of Secs. 3-4 (synchronous cascades, linear
    worst-case insertion).
    """

    def __init__(
        self,
        f: int = 3,
        sigma: int = 4096,
        *,
        device: Device = HDD,
        use_bloom: bool = True,
        bits_per_key: int = 10,
        num_hashes: int = 3,
        deamortize: bool = True,
        cost: CostModel | None = None,
    ):
        assert f >= 2 and sigma >= 2 * f, "paper requires f at most a fraction of sigma"
        self.f, self.sigma = f, sigma
        self.use_bloom = use_bloom
        self.bits_per_key, self.num_hashes = bits_per_key, num_hashes
        self.deamortize = deamortize
        self.cm = cost or CostModel(device)

        self.root = SNode()
        self._buf: dict = {}            # root d-tree, in memory (Sec. 4)
        self._frozen: Run | None = None  # buffer snapshot while a cascade is pending
        self._cascade = None             # page-quantum generator
        self.n_inserted = 0
        # Bloom effectiveness counters (paper Sec. 5.2): every d-tree probe,
        # the negatives that skipped a run search, and the positives that
        # searched and missed (false positives).  Query-savings attribution
        # for nbtree vs nbtree-nobloom runs.
        self.bloom_probes = 0
        self.bloom_negative_skips = 0
        self.bloom_false_positives = 0

    # ------------------------------------------------------------------ public
    def insert(self, key, value) -> float:
        """Insert one pair; returns the *foreground* latency of this insertion.

        Deamortized mode (the paper's final version): per insertion a bounded
        number of page quanta of the pending cascade are executed.  Their
        sequential-transfer share lands on the insertion's critical path (the
        1/sigma work fraction of Sec. 5.1); seeks are overlapped with the
        in-memory insert by asynchronous I/O, as in any deamortized engine,
        and are charged to total (throughput) time only.  A forced synchronous
        drain — the buffer refilling before the cascade finishes, or
        ``deamortize=False`` (the basic Sec. 3-4 version) — stalls the
        insertion for the full remaining cascade, seeks included; this is the
        long-delay event the paper eliminates and Fig. 7 measures.
        """
        fg = 0.0
        self._buf[np.uint64(key)] = np.int64(value)
        self.n_inserted += 1
        if self._cascade is not None:
            fg += self._advance_cascade()
            if len(self._buf) >= self.sigma and self._cascade is not None:
                with self.cm.measure() as t:  # backpressure stall: full drain
                    self._drain_cascade()
                fg += t.seconds
        if len(self._buf) >= self.sigma and self._cascade is None:
            self._freeze_and_start_cascade()
            if not self.deamortize:
                with self.cm.measure() as t:
                    self._drain_cascade()
                fg += t.seconds
        return fg

    def delete(self, key) -> float:
        """Delta-record deletion (Sec. 3.2.2)."""
        return self.insert(key, TOMBSTONE)

    def update(self, key, value) -> float:
        return self.insert(key, value)

    def get(self, key):
        """Point query; returns value or None.  Freshest copy wins."""
        key = np.uint64(key)
        with self.cm.measure() as t:
            val = self._get(key)
        self._last_query_time = t.seconds
        return val

    def query(self, key):
        """Like :meth:`get` but returns (value, simulated_seconds)."""
        v = self.get(key)
        return v, self._last_query_time

    def drain(self) -> None:
        """Finish all pending deamortized work (for tests/shutdown)."""
        self._drain_cascade()

    def range_query(self, lo, hi):
        """Inclusive range scan [lo, hi]; returns (keys, vals) numpy arrays.

        Visits every s-node whose key interval intersects the range
        (pre-order, so ancestors — fresher data — resolve duplicates first),
        scans each visited d-tree's matching span sequentially, then merges
        with freshest-copy-wins and drops tombstones.  Cost accounting per
        visited node with data: one seek + one leaf-locate page + the
        sequential transfer of the matching span (internal d-nodes are
        cached in memory, as for point queries).  Bloom filters are not
        consulted — they cannot answer range predicates.  ``lo > hi`` is an
        empty range.
        """
        lo, hi = np.uint64(lo), np.uint64(hi)
        with self.cm.measure() as t:
            out = self._range_query(lo, hi)
        self._last_query_time = t.seconds
        return out

    def _range_query(self, lo, hi):
        result: dict = {}

        def add(ks, vs):
            for k, v in zip(ks.tolist(), vs.tolist()):
                if k not in result:
                    result[k] = v

        if lo <= hi:
            # 1. live buffer, then frozen buffer (in memory, newest first).
            for k, v in self._buf.items():          # keys unique: no order dep
                if lo <= k <= hi:
                    result[int(k)] = int(v)
            if self._frozen is not None:
                add(*self._frozen.range(lo, hi))

            # 2. pre-order walk of the intersecting s-nodes.
            def rec(node):
                if node is not self.root and len(node.run) > 0:
                    rk, rv = node.run.range(lo, hi)
                    self.cm.page_read()          # locate the first leaf
                    self.cm.read_pairs(len(rk))  # sequential span scan
                    add(rk, rv)
                if node.is_leaf:
                    return
                bounds = [None, *node.skeys, None]
                for i, c in enumerate(node.children):
                    clo, chi = bounds[i], bounds[i + 1]
                    if (chi is None or lo < chi) and (clo is None or hi >= clo):
                        rec(c)

            rec(self.root)
        ks = sorted(k for k, v in result.items() if v != TOMBSTONE)
        return (np.asarray(ks, KEY_DTYPE),
                np.asarray([result[k] for k in ks], VAL_DTYPE))

    # ----------------------------------------------------------------- queries
    def _get(self, key):
        # 1. live buffer, then frozen buffer (both in memory, newest first).
        if key in self._buf:
            v = self._buf[key]
            return None if v == TOMBSTONE else v
        if self._frozen is not None:
            v = self._frozen.lookup(key)
            if v is not None:
                return None if v == TOMBSTONE else v
        # 2. descend the s-tree; search each visited node's d-tree,
        #    gated by its Bloom filter (Sec. 5.2).
        node = self.root
        while True:
            if node is not self.root and len(node.run) > 0:
                positive = True
                if self.use_bloom and node.bloom is not None:
                    self.bloom_probes += 1
                    positive = bool(node.bloom.contains(np.asarray([key]))[0])
                    if not positive:
                        self.bloom_negative_skips += 1
                if positive:
                    # B+-tree search of the run: internal d-nodes are cached
                    # in memory (paper Sec. 6.2 memory accounting), so one
                    # seek + one leaf page.
                    self.cm.page_read()
                    v = node.run.lookup(key)
                    if v is not None:
                        return None if v == TOMBSTONE else v
                    if self.use_bloom and node.bloom is not None:
                        self.bloom_false_positives += 1
            if node.is_leaf:
                return None
            node = node.child_for(key)

    # ------------------------------------------------------- cascade machinery
    def _freeze_and_start_cascade(self) -> None:
        keys = np.fromiter(self._buf.keys(), dtype=KEY_DTYPE, count=len(self._buf))
        vals = np.fromiter(self._buf.values(), dtype=VAL_DTYPE, count=len(self._buf))
        order = np.argsort(keys)
        self._frozen = Run(keys[order], vals[order])
        self._buf = {}
        self._cascade = self._handle_full_root()

    def _advance_cascade(self) -> float:
        """Bounded per-insert quanta (deamortization, Sec. 5.1).

        Returns the foreground share: the sequential-transfer time of the
        quanta executed (seeks overlap with the in-memory insert path).
        """
        if self._cascade is None:
            return 0.0
        # ~2 page quanta per insert (a full cascade is ~1.5*sigma quanta in
        # the worst case, so base pace 2 always finishes within one buffer
        # refill); accelerate defensively as the live buffer refills so a
        # forced synchronous drain can never trigger in steady state.
        frac = len(self._buf) / self.sigma
        quanta = 2 if frac < 0.75 else (8 if frac < 0.95 else 64)
        executed = 0
        try:
            for _ in range(quanta):
                next(self._cascade)
                executed += 1
        except StopIteration:
            self._cascade = None
            self._frozen = None
        return executed * self.cm.device.page_bytes / self.cm.device.write_bw

    def _drain_cascade(self) -> None:
        if self._cascade is not None:
            for _ in self._cascade:
                pass
            self._cascade = None
            self._frozen = None

    # Each ``yield`` below is one page quantum of simulated I/O.
    def _page_quanta(self, nbytes: int, write: bool):
        pages = max(1, -(-nbytes // self.cm.device.page_bytes))
        for _ in range(pages):
            if write:
                self.cm.seq_write(self.cm.device.page_bytes)
            else:
                self.cm.seq_read(self.cm.device.page_bytes)
            yield

    def _handle_full_root(self):
        """HandleFullSNode(root) with the root's d-tree = frozen buffer."""
        self.root.run = self._frozen  # conceptually the root's d-tree
        yield from self._handle_full(self.root)
        self.root.run = Run.empty()

    def _handle_full(self, node: SNode):
        """HandleFullSNode (Sec. 5.1, single-recursive-call version)."""
        while True:
            if node.is_leaf:
                yield from self._split_upward(node)
                return
            yield from self._flush(node)
            # single recursive call: the largest child, if oversized.
            sizes = [len(c.run) for c in node.children]
            biggest = int(np.argmax(sizes))
            if sizes[biggest] > self.sigma:
                node = node.children[biggest]
                continue
            return

    def _flush(self, node: SNode):
        """flush(N) (Secs. 4.1, 5.1): stream-merge N's live run into children.

        Moves down at most sigma pairs; the moved prefix is lazily removed
        by advancing N's watermark (no rewrite).  Cost: sequential read of
        the moved portion + per receiving child a seek, a sequential read of
        its live run, and a sequential write of the merged run.
        """
        live_k, live_v = node.run.live_keys, node.run.live_vals
        moved = min(len(live_k), self.sigma)
        mk, mv = live_k[:moved], live_v[:moved]
        if node is not self.root:
            self.cm.seek()
            yield from self._page_quanta(moved * PAIR_BYTES, write=False)
        parts = partition_by_pivots(mk, mv, node.skeys)
        for child, (pk, pv) in zip(node.children, parts):
            if len(pk) == 0:
                continue
            self.cm.seek()
            yield from self._page_quanta(len(child.run) * PAIR_BYTES, write=False)
            nk, nv = merge_runs(pk, pv, child.run.live_keys, child.run.live_vals)
            if child.is_leaf:  # delta records resolve at the last level (Sec. 3.2.2)
                nk, nv = drop_tombstones(nk, nv)
            self.cm.seek()
            yield from self._page_quanta(len(nk) * PAIR_BYTES, write=True)
            # commit the child atomically; fresh run => watermark 0 and the
            # child's previous dead prefix is discarded (lazy-removal payoff).
            child.run = Run(nk, nv)
            self._rebuild_bloom(child)
        # lazy removal on N: advance watermark only (Sec. 5.1).
        node.run = Run(node.run.keys, node.run.vals, node.run.wm + moved)
        self._snode_page_write(node)

    def _split_upward(self, node: SNode):
        """SNodeSplit at ``node`` then ancestor splits while fanout > f."""
        yield from self._snode_split(node)
        anc = node.parent
        while anc is not None and len(anc.children) > self.f:
            yield from self._snode_split(anc)
            anc = anc.parent

    def _snode_split(self, node: SNode):
        """SNodeSplit(N) (Sec. 3.2.1): median split of N and its d-tree."""
        live_k, live_v = node.run.live_keys, node.run.live_vals
        if node.is_leaf:
            k_m = live_k[len(live_k) // 2]  # median d-key
        else:
            k_m = np.asarray(node.skeys, KEY_DTYPE)[len(node.skeys) // 2]  # median s-key

        small, large = SNode(node.parent), SNode(node.parent)
        cut = int(np.searchsorted(live_k, k_m, side="left"))
        in_memory = node is self.root
        if not in_memory:
            self.cm.seek()
            yield from self._page_quanta(len(live_k) * PAIR_BYTES, write=False)
        self.cm.seek()
        yield from self._page_quanta(cut * PAIR_BYTES, write=True)
        self.cm.seek()
        yield from self._page_quanta((len(live_k) - cut) * PAIR_BYTES, write=True)
        small.run = Run(live_k[:cut].copy(), live_v[:cut].copy())
        large.run = Run(live_k[cut:].copy(), live_v[cut:].copy())
        self._rebuild_bloom(small)
        self._rebuild_bloom(large)

        if not node.is_leaf:
            i = node.skeys.index(k_m)
            small.skeys, large.skeys = node.skeys[:i], node.skeys[i + 1:]
            small.children, large.children = node.children[: i + 1], node.children[i + 1:]
            for c in small.children:
                c.parent = small
            for c in large.children:
                c.parent = large

        parent = node.parent
        if parent is None:  # root split: s-tree height grows by one.
            new_root = SNode()
            new_root.children = [small, large]
            new_root.skeys = [k_m]
            small.parent = large.parent = new_root
            self.root = new_root
        else:
            i = parent.children.index(node)
            parent.children[i: i + 1] = [small, large]
            parent.skeys.insert(i, k_m)
            self._snode_page_write(parent)
        self._snode_page_write(small)
        self._snode_page_write(large)

    # ------------------------------------------------------------------- misc
    def _rebuild_bloom(self, node: SNode) -> None:
        if self.use_bloom:
            node.bloom = BloomFilter.build(
                node.run.live_keys, self.bits_per_key, self.num_hashes
            )

    def _snode_page_write(self, node: SNode) -> None:
        """s-tree manipulations add at most one page write (Sec. 4.2)."""
        if node is not self.root:
            self.cm.seq_write(self.cm.device.page_bytes)

    # ------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Structural + cross-s-node-linkage properties (Sec. 3.1.1).

        Call after :meth:`drain`.  Raises AssertionError on violation.
        """
        assert self._cascade is None, "drain() before checking invariants"
        depths = set()
        sigma, f = self.sigma, self.f

        def rec(node: SNode, lo, hi_excl, depth):
            """Keys of ``node``'s subtree must lie in [lo, hi_excl)."""
            ks = node.run.live_keys
            if len(ks):
                assert np.all(ks[:-1] < ks[1:]), "run not strictly sorted"
                assert (lo is None or ks[0] >= lo) and (
                    hi_excl is None or ks[-1] < hi_excl
                ), "cross-s-node linkage property violated"
            # total-sibling bound of Sec. 5.1 implies |d-tree| <= f*(sigma+1).
            assert len(node.run) <= f * (sigma + 1), "d-tree size bound violated"
            if node.is_leaf:
                depths.add(depth)
                return
            assert len(node.children) == len(node.skeys) + 1
            assert len(node.children) <= f, "fanout overflow"
            if node is not self.root:
                assert len(node.children) >= -(-f // 2), "fanout underflow"
            sk = np.asarray(node.skeys, KEY_DTYPE)
            assert np.all(sk[:-1] < sk[1:]), "s-keys not sorted"
            bounds = [lo, *node.skeys, hi_excl]
            for i, c in enumerate(node.children):
                assert c.parent is node
                rec(c, bounds[i], bounds[i + 1], depth + 1)

        rec(self.root, None, None, 0)
        assert len(depths) <= 1, "leaves not at uniform depth"

    @property
    def height(self) -> int:
        h, node = 0, self.root
        while not node.is_leaf:
            node = node.children[0]
            h += 1
        return h

    def total_pairs(self) -> int:
        """Live pairs across buffer + all d-trees (may count in-flight dups)."""
        total = len(self._buf) + (len(self._frozen) if self._frozen is not None else 0)
        stack = [self.root]
        while stack:
            n = stack.pop()
            total += len(n.run)
            stack.extend(n.children)
        return total
