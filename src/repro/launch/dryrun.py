import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell (configs/shapes.py::cell_status) this script builds
ShapeDtypeStruct stand-ins for params / optimizer state / batch / cache,
jits the step with explicit in/out shardings, ``.lower().compile()``s it on
the production mesh (single-pod 16x16 and multi-pod 2x16x16 over 512
host-platform placeholder devices), prints memory_analysis / cost_analysis,
and records the three-term roofline (repro/roofline) to a JSONL file that
EXPERIMENTS.md §Dry-run / §Roofline read from.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out runs/dryrun.jsonl
"""
import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.shapes import SHAPES, cell_status
from ..distributed.sharding import param_specs
from ..models import registry
from ..models import transformer as T
from ..optim import adamw
from ..roofline import analysis
from ..serve import steps as serve_steps
from ..train.train_step import make_train_step
from .mesh import make_production_mesh, mesh_context


# ---------------------------------------------------------------- input specs
def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.encoder_only:
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
            if shape.kind == "train":
                batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return batch
    # decode: cache at full kv length + one incoming token per sequence.
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def _dims(mesh):
    return (mesh.shape.get("data", 1), mesh.shape.get("model", 1),
            mesh.shape.get("pod", 1))


def batch_specs(cfg, shape, mesh):
    data, model, pod = _dims(mesh)
    dp = ("pod", "data") if pod > 1 else ("data",)
    B = shape.global_batch
    # shard batch over as much of the dp product as divides it.
    if B % (pod * data) == 0:
        bspec = dp
    elif B % data == 0:
        bspec = ("data",)
    else:
        bspec = None
    def spec(leaf):
        s = [bspec] + [None] * (leaf.ndim - 1)
        return P(*s)
    return spec


def _compile_step(cfg, shape, mesh, microbatches: int = 1):
    """Build the jitted step for this (cfg, shape) and compile on mesh."""
    params_shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                                   jax.random.PRNGKey(0))
    pspecs = param_specs(params_shapes, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    params_structs = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        params_shapes, psh)
    batch = input_specs(cfg, shape)

    with mesh_context(mesh):
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(adamw.init, params_shapes)
            ospecs = {"m": pspecs, "v": pspecs, "count": P()}
            osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)
            opt_structs = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                opt_shapes, osh)
            bs = batch_specs(cfg, shape, mesh)
            bspecs = jax.tree.map(bs, batch)
            bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)
            bstructs = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                batch, bsh)
            step = make_train_step(cfg, adamw.AdamWConfig(),
                                   num_microbatches=microbatches)
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_structs, opt_structs, bstructs)
        elif shape.kind == "prefill":
            bs = batch_specs(cfg, shape, mesh)
            bspecs = jax.tree.map(bs, batch)
            bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspecs)
            bstructs = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                batch, bsh)
            if cfg.encoder_only:
                fn = serve_steps.make_encode_step(cfg)
            else:
                fn = serve_steps.make_prefill_step(cfg, cache_len=shape.seq_len)
            jitted = jax.jit(fn, in_shardings=(psh, bsh))
            lowered = jitted.lower(params_structs, bstructs)
        else:  # decode
            cache = jax.eval_shape(lambda: T.init_cache(cfg, shape.global_batch,
                                                        shape.seq_len))
            cspecs = cache_specs(cfg, shape, mesh, cache)
            csh = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
            cstructs = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                cache, csh)
            tok_s = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
            idx_s = jax.ShapeDtypeStruct((), jnp.int32)
            fn = serve_steps.make_serve_step(cfg)
            jitted = jax.jit(fn, in_shardings=(psh, csh, None, None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_structs, cstructs, tok_s, idx_s)
        return lowered.compile()


def cache_specs(cfg, shape, mesh, cache_shapes):
    """Shard decode caches: batch over dp; KVH or head_dim over model;
    for B=1 long-context, the sequence dim over data (sequence parallelism)."""
    data, model, pod = _dims(mesh)
    dp = ("pod", "data") if pod > 1 else ("data",)
    B = shape.global_batch

    def one(leaf):
        nd = leaf.ndim
        spec = [None] * nd
        # leading dim is the stacked segment axis (count), dim1 = batch.
        if nd >= 2 and leaf.shape[1] == B and B % (np.prod([mesh.shape[a] for a in dp])) == 0:
            spec[1] = dp
        if nd == 5:  # (seg, B, T, KVH, D)
            if leaf.shape[3] % model == 0 and leaf.shape[3] >= model:
                spec[3] = "model"
            elif leaf.shape[4] % model == 0:
                spec[4] = "model"
            if B == 1 and leaf.shape[2] % data == 0:
                spec[2] = "data"       # SP over the KV sequence
        elif nd == 4 and leaf.shape[2] > 4096:  # (seg, B, T, R) mla latents
            if B == 1 and leaf.shape[2] % data == 0:
                spec[2] = "data"
        elif nd == 3 and leaf.shape[2] > 4096:  # (seg, B, T) position rings
            if B == 1 and leaf.shape[2] % data == 0:
                spec[2] = "data"
        return P(*spec)

    return jax.tree.map(one, cache_shapes)


# ---------------------------------------------------------- cost correction
def _raw_costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax 0.4.x: one dict per device kind
        ca = ca[0] if ca else {}
    wires = analysis.collective_wire_bytes(compiled.as_text())
    return np.array([float(ca.get("flops", 0.0)),
                     float(ca.get("bytes accessed", 0.0)),
                     wires["ici"], wires["dcn"]])


def corrected_costs(cfg, base_compiled, compile_fn):
    """Scan-body trip-count correction for cost_analysis totals.

    XLA's HloCostAnalysis visits a while-loop body once, so a scanned
    segment of L layers contributes 1x, not Lx, to flops / bytes / parsed
    collective payloads.  We recover per-layer body costs by lowering one
    extra variant per distinct block kind with an appended 2-layer segment
    of that kind: body_k = cost(variant_k) - cost(base).  Then
        corrected = base + sum_k (layers_of_kind_k - segments_of_kind_k) * body_k
    (base already counts one body per *segment*).  Exact for flops, tight
    for bytes (fusion boundaries shift marginally).
    """
    import dataclasses as dc
    base = _raw_costs(base_compiled)
    kinds = {}
    for kind, count in cfg.segments:
        k = kinds.setdefault(kind, [0, 0])
        k[0] += count   # layers of this kind
        k[1] += 1       # segments of this kind
    corrected = base.copy()
    for kind, (layers, segs) in kinds.items():
        extra = layers - segs
        if extra <= 0:
            continue
        cfg_k = dc.replace(cfg, segments=cfg.segments + ((kind, 2),),
                           n_layers=cfg.n_layers + 2)
        variant = _raw_costs(compile_fn(cfg_k))
        body = np.maximum(variant - base, 0.0)
        corrected += extra * body
    return corrected


def blockwise_supplement(cfg, shape, n_devices: int):
    """Analytic per-device (flops, hbm_bytes) for blockwise-attention layers.

    The flash q/kv loops are HLO while-bodies (counted once by cost
    analysis); their true totals are data-independent and exactly known
    from the tile schedule, so we add them analytically.  The single tile
    the HLO did count is < 0.1% of the total and is not subtracted.
    """
    from ..models.blockwise_attn import analytic_costs, should_use_blockwise
    B = shape.global_batch
    H, D, KVH = cfg.n_heads, cfg.resolved_head_dim, cfg.n_kv_heads
    tot_f = tot_b = 0.0
    for kind_, count in cfg.segments:
        if kind_ not in ("dense", "swa", "moe", "moe_swa", "encoder",
                         "hybrid", "hybrid_global", "mla"):
            continue
        h_, d_, kvh_ = H, D, KVH
        if kind_ == "mla":
            if shape.kind == "decode":
                continue  # absorbed decode path: no blockwise loops
            d_ = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
            kvh_ = H
        if shape.kind in ("train", "prefill"):
            S = T = shape.seq_len
            mode = "train" if shape.kind == "train" else "serve"
        else:
            S = 1
            T = shape.seq_len
            if kind_ in ("swa", "moe_swa", "hybrid"):
                T = min(T, max(cfg.swa_window + 128, 256))
            mode = "serve"
        if not should_use_blockwise(B, S, T, h_):
            continue
        dtype_bytes = 1 if (shape.kind == "decode"
                            and cfg.kv_cache_dtype == "int8") else 2
        f, b = analytic_costs(B, S, T, h_, d_, kvh_, mode,
                              dtype_bytes=dtype_bytes)
        tot_f += f * count
        tot_b += b * count
    return tot_f / n_devices, tot_b / n_devices


# ------------------------------------------------------------------ lowering
def lower_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
               cfg=None, shape=None, cost_correct: bool = True):
    """Lower+compile one cell.  cfg/shape overrides support reduced-scale
    integration tests that exercise the identical code path."""
    cfg = cfg or registry.get_config(arch)
    shape = shape or SHAPES[shape_name]
    status = cell_status(cfg, shape)
    if status != "run":
        return {"arch": arch, "shape": shape_name, "status": status}

    n_dev = int(np.prod(list(mesh.shape.values())))
    tokens_per_step = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)

    # flops/bytes/collectives are microbatch-invariant in reality but the
    # microbatch scan body is HLO-counted once, so the *cost* artifact is
    # always lowered at mb=1; the *memory* artifact uses the requested mb.
    def compile_for(c):
        return _compile_step(c, shape, mesh, 1)

    t0 = time.time()
    compiled = compile_for(cfg)
    if microbatches > 1 and shape.kind == "train":
        compiled_mem = _compile_step(cfg, shape, mesh, microbatches)
    else:
        compiled_mem = compiled
    compile_s = time.time() - t0

    if cost_correct:
        flops, bytes_acc, ici, dcn = corrected_costs(cfg, compiled, compile_for)
    else:
        flops, bytes_acc, ici, dcn = _raw_costs(compiled)
    sup_f, sup_b = blockwise_supplement(cfg, shape, n_dev)
    flops += sup_f
    bytes_acc += sup_b

    mf = analysis.model_flops(cfg, tokens_per_step,
                              "train" if shape.kind == "train" else "serve")
    mem = compiled_mem.memory_analysis()
    peak = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes + mem.generated_code_size_in_bytes)
    roof = analysis.analyze_from(
        flops=flops, hbm_bytes=bytes_acc, ici_bytes=ici, dcn_bytes=dcn,
        peak_mem=peak, n_devices=n_dev, model_flops_total=mf,
        by_kind=analysis.collective_wire_bytes(compiled.as_text())["by_kind"])
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "x".join(str(v) for v in mesh.shape.values()),
        "n_devices": n_dev,
        "compile_s": round(compile_s, 1),
        "tokens_per_step": tokens_per_step,
        "memory_analysis": {
            "argument_gib": mem.argument_size_in_bytes / 2**30,
            "output_gib": mem.output_size_in_bytes / 2**30,
            "temp_gib": mem.temp_size_in_bytes / 2**30,
            "peak_gib": roof.peak_mem_bytes / 2**30,
        },
        "roofline": roof.as_dict(),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--kv-int8", action="store_true",
                    help="quantized int8 decode KV cache")
    ap.add_argument("--out", default="runs/dryrun.jsonl")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    archs = registry.list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            r = json.loads(line)
            done.add((r["arch"], r["shape"], r.get("mesh_kind", r.get("mesh"))))

    with open(args.out, "a") as f:
        for mesh_kind in meshes:
            mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
            for arch in archs:
                for shape in shapes:
                    key = (arch, shape, mesh_kind)
                    if key in done:
                        continue
                    t0 = time.time()
                    try:
                        cfg_cell = registry.get_config(arch)
                        if args.kv_int8:
                            import dataclasses as _dc
                            cfg_cell = _dc.replace(cfg_cell,
                                                   kv_cache_dtype="int8")
                        rec = lower_cell(arch, shape, mesh, cfg=cfg_cell,
                                         microbatches=args.microbatches)
                    except Exception as e:  # record failures; they are bugs
                        rec = {"arch": arch, "shape": shape, "status": "FAIL",
                               "error": f"{type(e).__name__}: {e}",
                               "trace": traceback.format_exc()[-2000:]}
                    rec["mesh_kind"] = mesh_kind
                    rec["wall_s"] = round(time.time() - t0, 1)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    status = rec["status"]
                    extra = ""
                    if status == "ok":
                        r = rec["roofline"]
                        extra = (f" peak={rec['memory_analysis']['peak_gib']:.2f}GiB"
                                 f" bottleneck={r['bottleneck']}"
                                 f" t=({r['t_compute']:.4f},{r['t_memory']:.4f},"
                                 f"{r['t_collective']:.4f})s")
                    elif status == "FAIL":
                        extra = " " + rec["error"][:200]
                    print(f"[{mesh_kind}] {arch} x {shape}: {status}"
                          f" ({rec['wall_s']}s){extra}", flush=True)


if __name__ == "__main__":
    main()
