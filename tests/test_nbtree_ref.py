"""Paper-faithful NB-tree (core/refimpl): behaviour + invariants + claims."""
import numpy as np
import pytest

from repro.core.cost_model import SSD, CostModel
from repro.core.refimpl import NBTree


def _unique_keys(rng, n, hi=10_000_000):
    return rng.choice(np.arange(1, hi, dtype=np.uint64), size=n, replace=False)


@pytest.mark.parametrize("f,sigma", [(3, 256), (4, 512), (8, 128)])
def test_insert_query_roundtrip(rng, f, sigma):
    keys = _unique_keys(rng, 5000)
    nb = NBTree(f=f, sigma=sigma)
    for i, k in enumerate(keys):
        nb.insert(k, i)
    nb.drain()
    nb.check_invariants()
    for i in [0, 1, 17, 999, 2500, 4999]:
        assert nb.get(keys[i]) == i
    # negatives
    for k in rng.integers(10_000_001, 2**63, 100).astype(np.uint64):
        assert nb.get(k) is None


def test_delete_update_delta_records(rng):
    keys = _unique_keys(rng, 3000)
    nb = NBTree(f=3, sigma=256)
    for i, k in enumerate(keys):
        nb.insert(k, i)
    for k in keys[:100]:
        nb.delete(k)
    for k in keys[100:200]:
        nb.update(k, 777)
    nb.drain()
    nb.check_invariants()
    assert all(nb.get(k) is None for k in keys[:100])
    assert all(nb.get(k) == 777 for k in keys[100:200])
    assert nb.get(keys[500]) == 500


def test_duplicate_insert_newest_wins(rng):
    nb = NBTree(f=3, sigma=128)
    keys = _unique_keys(rng, 1000)
    for i, k in enumerate(keys):
        nb.insert(k, i)
    for i, k in enumerate(keys[:300]):
        nb.insert(k, 10_000 + i)
    nb.drain()
    assert all(nb.get(k) == 10_000 + i for i, k in enumerate(keys[:300]))


def test_height_logarithmic(rng):
    sigma, f = 128, 3
    nb = NBTree(f=f, sigma=sigma)
    n = 20_000
    for i, k in enumerate(_unique_keys(rng, n)):
        nb.insert(k, i)
    nb.drain()
    # height <= c * log_f(n / sigma) with a small constant
    import math
    bound = math.log(n / sigma, f) + 3
    assert nb.height <= bound, (nb.height, bound)


def test_deamortized_worst_case_vs_basic(rng):
    """The paper's core claim (Fig. 7): deamortized max insertion time is
    orders of magnitude below the basic (synchronous-cascade) version."""
    keys = _unique_keys(rng, 30_000)
    t_de = [NBTree(f=3, sigma=1024).insert(0, 0)]  # warm shape
    nb1 = NBTree(f=3, sigma=1024, deamortize=True)
    t1 = [nb1.insert(k, i) for i, k in enumerate(keys)]
    nb2 = NBTree(f=3, sigma=1024, deamortize=False)
    t2 = [nb2.insert(k, i) for i, k in enumerate(keys)]
    assert max(t1) * 50 < max(t2), (max(t1), max(t2))


def test_bloom_reduces_query_cost(rng):
    keys = _unique_keys(rng, 20_000)
    q = rng.choice(keys, 500, replace=False)

    def avg_q(use_bloom):
        nb = NBTree(f=3, sigma=512, use_bloom=use_bloom)
        for i, k in enumerate(keys):
            nb.insert(k, i)
        nb.drain()
        return np.mean([nb.query(k)[1] for k in q])

    with_bloom, without = avg_q(True), avg_q(False)
    assert with_bloom < without, (with_bloom, without)


def test_ssd_faster_than_hdd(rng):
    keys = _unique_keys(rng, 10_000)
    times = {}
    for dev in ("hdd", "ssd"):
        from repro.core.cost_model import HDD, SSD
        nb = NBTree(f=3, sigma=512, device=HDD if dev == "hdd" else SSD)
        for i, k in enumerate(keys):
            nb.insert(k, i)
        nb.drain()
        times[dev] = nb.cm.time
    assert times["ssd"] < times["hdd"]


def test_conservation(rng):
    keys = _unique_keys(rng, 8000)
    nb = NBTree(f=4, sigma=256)
    for i, k in enumerate(keys):
        nb.insert(k, i)
    nb.drain()
    assert nb.total_pairs() == len(keys)
