"""Device-tier NB-tree: the paper's index as a composable JAX module.

Architecture (DESIGN.md §2-3) — the split every production serving engine
uses (vLLM block manager, LevelDB manifest): a *host control plane* runs the
paper's s-tree algorithm (flush / SNodeSplit / single-recursive-call /
bounded maintenance quota = deamortization), while the *device data plane*
keeps all key/value runs, pivot tables and Bloom bit-arrays as flat padded
arrays in (simulated) HBM and executes the hot operations with the Pallas
kernels:

  * ``insert_batch``  — sorted-batch merge into the root run (merge kernel),
  * ``query_batch``   — one fused jitted descent: Bloom probe + lockstep
                        binary search per level, first (= freshest) hit wins,
  * ``maintain``      — up to ``max_units`` child-merge/split work units per
                        call: the serving-loop analogue of the paper's
                        1/sigma-per-insert deamortization (no allocator or
                        compaction stall can exceed the per-step budget).

Fused maintenance pipeline (DESIGN.md §8): the write path is dispatched the
same way the query path has been since PR 1 — as a handful of fused jitted
device calls, not a chatty eager loop.  Each maintenance primitive is ONE
device dispatch:

  * ``_insert_impl``  — batch sort + root merge + count bump + *incremental*
                        Bloom update (OR only the batch's bits: O(batch),
                        not O(run_cap), and bit-identical to a rebuild —
                        see ``kernels.ref.bloom_update_ref``),
  * ``_flush_impl``   — the whole emptying cascade step for one node:
                        duplicate-safe cut, pivot partition, batched
                        merge-path merge into all <= f children
                        (``merge_sorted_batch``, a single 2-d-grid kernel
                        launch), fused tombstone compaction, parent-run
                        compaction, and child/parent Bloom rebuilds, with
                        buffer donation on the node tables so no full-table
                        copy survives the call,
  * ``_split_impl`` / ``_clear_impl`` / ``_sync_impl`` / ``_grow_impl`` —
                        run split (+ filters), row clear, structure mirror,
                        and capacity doubling, one dispatch each.

Host control metadata (node id, child ids, pivots) is routed in as scalars
and tiny arrays; the only device->host traffic per flush is the returned
(<= f+1)-element count vector.  Every device computation the index launches
goes through the ``_device_call`` funnel, so dispatch budgets are
observable (per-instance ``dispatch_count`` / ``dispatch_stats``, plus
optional per-dispatch tracer spans) and regression-tested.  The pre-fusion
eager path is kept under ``fused=False`` as the differential-testing and
benchmarking baseline (``benchmarks/bench_ingest_device.py`` measures the
before/after).

Range queries (DESIGN.md §4): ``range_query_batch(lo, hi, max_results)``
serves inclusive scans ``[lo, hi]`` with the same host/device split as point
lookups.  The *host control plane* routes each query over its pivot
structure, collecting — in pre-order, so ancestors (fresher data) come
first — the ids of every node whose key interval intersects the range; the
*device data plane* then runs one fused jitted pass that (a) lower/upper
bound binary-searches every candidate run in lockstep, (b) gathers the
matching spans into a fixed-capacity candidate tile, (c) resolves per-key
freshness by a single stable sort over the level-major candidates (the
range generalization of the point lookup's first-hit-wins rule: for
duplicate keys, the copy from the shallower level — or leftmost in-run
position — survives), (d) filters ``TOMBSTONE32`` delta-deletes, and (e)
returns sorted, KEY_MAX-padded results with a live count and a truncation
flag.  Bloom filters are not consulted: they cannot answer range
predicates.  The standalone ``ops.range_scan`` Pallas kernel implements the
same search+gather step for single-run scans (LSM-style baselines,
microbenchmarks).

Static-shape adaptations vs. the paper (recorded in DESIGN.md §2): runs are
fixed-capacity rows of a node table (RUN_CAP >= f*(sigma+1) + sigma, the
paper's Sec. 5.1 sibling bound plus one incoming flush); device rows are
always compacted on rewrite, the lazy-removal watermark living in the host
control plane only (rewriting an HBM row is a stream copy, the thing the
paper's lazy removal avoids on *disk* seeks).

Device keys are uint32 (TPU lane width), values int32 payload references;
``TOMBSTONE32`` realizes delta-record deletions (paper Sec. 3.2.2).
"""
from __future__ import annotations

import functools
import math
import time
from collections import Counter, deque

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..kernels.merge_sorted import merge_sorted as _merge_pair
from ..kernels.merge_sorted import merge_sorted_batch as _merge_batch
from ..kernels.ref import bloom_build_ref, bloom_hash_ref

KEY_MAX32 = np.uint32(0xFFFFFFFF)
TOMBSTONE32 = np.int32(-(2**31))
TILE = 1024

def _device_call(fn, *args, **kwargs):
    """Single funnel for every device computation the index launches.

    One call == one device dispatch (each ``fn`` here is either a fused
    jitted impl or a single eager XLA op).  Kept as a module-level
    indirection so tests can monkeypatch it to intercept dispatches;
    *counting* is per-instance (``NBTreeIndex.dispatch_count``, routed
    through :meth:`NBTreeIndex._dispatch`), so concurrent engines —
    sharded ensembles, fused-vs-eager side-by-side benchmarks — no longer
    share mutable global state.
    """
    return fn(*args, **kwargs)


def _tree_nbytes(x) -> int:
    """Total array bytes in a (possibly nested) dispatch input/output."""
    if isinstance(x, (tuple, list)):
        return sum(_tree_nbytes(e) for e in x)
    return int(getattr(x, "nbytes", 0))


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


class _HostNode:
    """Control-plane view of an s-node (structure only, no key data)."""

    __slots__ = ("nid", "skeys", "children", "count", "parent")

    def __init__(self, nid: int, parent=None):
        self.nid = nid
        self.skeys: list[int] = []
        self.children: list[_HostNode] = []
        self.count = 0           # live pairs in the device run row
        self.parent: _HostNode | None = parent

    @property
    def is_leaf(self):
        return not self.children


# --------------------------------------------------------------------- jit fns
@functools.partial(jax.jit, donate_argnums=(0,))
def _write_row(table, row, data):
    return table.at[row].set(data)


@functools.partial(jax.jit, static_argnames=("cap",))
def _window(row_keys, row_vals, start, length, cap: int):
    """Fixed-size (cap,) slice [start, start+length) padded with KEY_MAX."""
    idx = start + jnp.arange(cap, dtype=jnp.int32)
    k = jnp.take(row_keys, idx, mode="clip")
    v = jnp.take(row_vals, idx, mode="clip")
    mask = jnp.arange(cap, dtype=jnp.int32) < length
    return jnp.where(mask, k, jnp.uint32(KEY_MAX32)), jnp.where(mask, v, 0)


@jax.jit
def _prepare_batch(keys, vals):
    """Sort an incoming batch descending-recency-stable (newest copy first)."""
    # stable argsort keeps earlier (older) duplicates first; we want the
    # newest first, so sort the *reversed* batch.
    keys, vals = keys[::-1], vals[::-1]
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


@functools.partial(jax.jit, static_argnames=("nbits", "h"))
def _build_bloom(keys, nbits: int, h: int):
    return ops.bloom_build(keys, nbits, h)


def _compact_rows(keys, vals, cap: int):
    """Leaf-level delta resolution (Sec. 3.2.2): dedup then drop deletes.

    The merge kernel keeps duplicate keys (newest copy leftmost — that is
    what makes leftmost-match point lookups see the freshest record), so a
    leaf run accumulates stale copies.  Compaction must retire the stale
    duplicates *together with* the tombstone records: dropping only the
    tombstone would resurrect the older copy it deleted.  Traced by both
    the eager jit wrapper below and (vmapped) the fused flush impl.
    """
    first = jnp.concatenate(
        [jnp.ones(1, bool), keys[1:] != keys[:-1]])   # leftmost = freshest
    dead = ~first | (vals == TOMBSTONE32)
    keys = jnp.where(dead, jnp.uint32(KEY_MAX32), keys)
    order = jnp.argsort(keys, stable=True)
    keys, vals = keys[order], vals[order]
    live = jnp.sum((keys != KEY_MAX32).astype(jnp.int32))
    return keys[:cap], vals[:cap], live


_compact_tombstones = jax.jit(_compact_rows, static_argnames=("cap",))


# ----------------------------------------------------- fused maintenance impls
@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=("run_cap", "nbits", "h", "interpret"))
def _insert_impl(run_keys, run_vals, run_count, bloom, keys, vals, *,
                 run_cap: int, nbits: int, h: int, interpret: bool):
    """One-dispatch root ingest: sort batch, merge, incremental Bloom OR."""
    bk, bv = _prepare_batch(keys, vals)
    mk, mv = _merge_pair(bk, bv, run_keys[0], run_vals[0], interpret=interpret)
    run_keys = run_keys.at[0].set(mk[:run_cap])
    run_vals = run_vals.at[0].set(mv[:run_cap])
    run_count = run_count.at[0].add(jnp.int32(keys.shape[0]))
    # O(batch) incremental filter maintenance; == from-scratch rebuild
    # because OR over a grown key set is associative (DESIGN.md §8).
    bloom = bloom.at[0].set(ops.bloom_update(bloom[0], bk, nbits, h))
    return run_keys, run_vals, run_count, bloom


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=("nc", "leaf", "sigma", "sigma_pad",
                                    "run_cap", "nbits", "h", "interpret"))
def _flush_impl(run_keys, run_vals, run_count, bloom, nid, child_ids, piv,
                count, *, nc: int, leaf: bool, sigma: int, sigma_pad: int,
                run_cap: int, nbits: int, h: int, interpret: bool):
    """One-dispatch emptying-cascade step for one internal node.

    Replaces the eager per-child loop (merge + compact + 3 row writes +
    full Bloom rebuild per child, with host-synced ``searchsorted`` cuts in
    the middle, ~25 dispatches at f=4) with a single call: duplicate-safe
    cut and pivot partition on device, one batched merge across all ``nc``
    children, vmapped tombstone compaction (leaf level), parent-run
    compaction, Bloom rebuilds for every touched row.  Untouched children
    (empty partition) keep rows, counts and filters bit-for-bit, matching
    the eager path exactly.  Returns the updated tables plus the
    ``(nc+1,)`` count vector (children then parent) — the only
    device->host traffic of the whole flush.
    """
    row_k = run_keys[nid]
    row_v = run_vals[nid]
    # ---- duplicate-safe cut (was 2-3 blocking host round trips) -----------
    # Never split a duplicate group across the moved boundary: runs keep
    # duplicate copies newest-first, so flushing the fresh copy while the
    # stale one stays behind would invert the ancestors-are-fresher rule
    # both query paths rely on.  Back the cut up to the group start; if the
    # whole prefix is one key, move the entire group (progress guaranteed:
    # RUN_CAP >= f*(sigma+1) + sigma gives the child sigma headroom).
    moved0 = jnp.minimum(count, sigma)
    k_cut = row_k[jnp.clip(moved0, 0, run_cap - 1)]
    left = jnp.searchsorted(row_k, k_cut, side="left").astype(jnp.int32)
    right = jnp.searchsorted(row_k, k_cut, side="right").astype(jnp.int32)
    adj = jnp.where(left > 0, jnp.minimum(left, moved0),
                    jnp.minimum(right, count))
    moved = jnp.where(moved0 < count, adj, moved0)

    # ---- pivot partition of the moved prefix ------------------------------
    cuts = jnp.minimum(
        jnp.searchsorted(row_k, piv, side="left").astype(jnp.int32), moved)
    bounds = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), cuts, jnp.reshape(moved, (1,))])
    starts, lens = bounds[:-1], bounds[1:] - bounds[:-1]

    def window(start, ln, cap):
        idx = start + jnp.arange(cap, dtype=jnp.int32)
        m = jnp.arange(cap, dtype=jnp.int32) < ln
        return (jnp.where(m, jnp.take(row_k, idx, mode="clip"),
                          jnp.uint32(KEY_MAX32)),
                jnp.where(m, jnp.take(row_v, idx, mode="clip"), 0))

    pk, pv = jax.vmap(lambda s, ln: window(s, ln, sigma_pad))(starts, lens)

    # ---- one batched merge across all children ----------------------------
    ck, cv = run_keys[child_ids], run_vals[child_ids]
    old_counts = run_count[child_ids]
    mk, mv = _merge_batch(pk, pv, ck, cv, interpret=interpret)
    if leaf:
        mk, mv, new_counts = jax.vmap(
            lambda k, v: _compact_rows(k, v, run_cap))(mk, mv)
    else:
        mk, mv = mk[:, :run_cap], mv[:, :run_cap]
        new_counts = old_counts + lens
    touched = lens > 0
    mk = jnp.where(touched[:, None], mk, ck)
    mv = jnp.where(touched[:, None], mv, cv)
    new_counts = jnp.where(touched, new_counts, old_counts)
    # unrolled over the static child count: measurably faster than vmap for
    # the scatter-heavy build, and nc <= f is tiny.
    new_blooms = jnp.stack([bloom_build_ref(mk[i], nbits, h)
                            for i in range(nc)])
    new_blooms = jnp.where(touched[:, None], new_blooms, bloom[child_ids])

    # ---- parent remainder (immediate compaction, DESIGN.md §2) ------------
    rest = count - moved
    rk, rv = window(moved, rest, run_cap)
    pb = bloom_build_ref(rk, nbits, h)

    run_keys = run_keys.at[child_ids].set(mk).at[nid].set(rk)
    run_vals = run_vals.at[child_ids].set(mv).at[nid].set(rv)
    run_count = run_count.at[child_ids].set(new_counts).at[nid].set(rest)
    bloom = bloom.at[child_ids].set(new_blooms).at[nid].set(pb)
    counts = jnp.concatenate([new_counts, jnp.reshape(rest, (1,))])
    return run_keys, run_vals, run_count, bloom, counts


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=("has_key", "run_cap", "nbits", "h"))
def _split_impl(run_keys, run_vals, run_count, bloom, nid, left_id, right_id,
                count, at_key, *, has_key: bool, run_cap: int, nbits: int,
                h: int):
    """One-dispatch run split: windows, counts and filters for both halves.

    Returns the updated tables plus ``[k_m, cut]`` (uint32) — the split key
    for the host pivot structure and the left-half length.
    """
    row_k = run_keys[nid]
    row_v = run_vals[nid]
    if has_key:
        k_m = at_key
        cut = jnp.minimum(
            jnp.searchsorted(row_k, k_m, side="left").astype(jnp.int32),
            count)
    else:
        k_m = row_k[jnp.clip(count // 2, 0, run_cap - 1)]
        cut = jnp.searchsorted(row_k, k_m, side="left").astype(jnp.int32)

    def window(start, ln):
        idx = start + jnp.arange(run_cap, dtype=jnp.int32)
        m = jnp.arange(run_cap, dtype=jnp.int32) < ln
        return (jnp.where(m, jnp.take(row_k, idx, mode="clip"),
                          jnp.uint32(KEY_MAX32)),
                jnp.where(m, jnp.take(row_v, idx, mode="clip"), 0))

    halves_k, halves_v = jax.vmap(window)(
        jnp.stack([jnp.int32(0), cut]), jnp.stack([cut, count - cut]))
    ids = jnp.stack([left_id, right_id])
    run_keys = run_keys.at[ids].set(halves_k)
    run_vals = run_vals.at[ids].set(halves_v)
    run_count = run_count.at[ids].set(jnp.stack([cut, count - cut]))
    bloom = bloom.at[ids].set(
        jnp.stack([bloom_build_ref(halves_k[i], nbits, h) for i in range(2)]))
    return (run_keys, run_vals, run_count, bloom,
            jnp.stack([k_m, cut.astype(jnp.uint32)]))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _clear_impl(run_keys, run_vals, run_count, bloom, nid):
    """One-dispatch row retire: keys, values, count and filter of one node."""
    return (run_keys.at[nid].set(jnp.uint32(KEY_MAX32)),
            run_vals.at[nid].set(jnp.int32(0)),
            run_count.at[nid].set(0),
            bloom.at[nid].set(jnp.uint32(0)))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _sync_impl(pivots, children, nchild, nid, pv, ch, n):
    """One-dispatch structure mirror: pivots, child ids, fanout of one node."""
    return (pivots.at[nid].set(pv), children.at[nid].set(ch),
            nchild.at[nid].set(n))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
def _grow_impl(pivots, children, nchild, run_keys, run_vals, run_count, bloom):
    """One-dispatch capacity doubling of all seven node tables.

    Donating every table lets XLA release each old buffer as soon as its
    copy lands, so growth never holds 2x of *every* table at once the way
    seven sequential eager concatenates did.
    """
    def pad(t, fill):
        return jnp.concatenate([t, jnp.full(t.shape, fill, t.dtype)])

    return (pad(pivots, KEY_MAX32), pad(children, 0), pad(nchild, 0),
            pad(run_keys, KEY_MAX32), pad(run_vals, 0), pad(run_count, 0),
            pad(bloom, 0))


@functools.partial(
    jax.jit, static_argnames=("f", "levels", "run_cap", "nbits", "h", "steps")
)
def _query_batch_impl(pivots, nchild, children, run_keys, run_vals, run_count,
                      bloom, q, *, f, levels, run_cap, nbits, h, steps):
    B = q.shape[0]
    node = jnp.zeros(B, jnp.int32)
    found = jnp.zeros(B, bool)
    out = jnp.full(B, -1, jnp.int32)
    # Bloom-effectiveness tallies (paper Sec. 5.2), reduced on device so the
    # fused call stays one round trip: probes issued, negatives that skipped
    # a run search, and positives whose search then missed (false positives).
    n_probe = jnp.int32(0)
    n_neg = jnp.int32(0)
    n_fp = jnp.int32(0)
    # the descent parks on its leaf for any iterations left after reaching
    # it; `prev` masks those repeats out of the tallies (one logical probe
    # per distinct node on each query's root-to-leaf path).
    prev = jnp.full(B, -1, jnp.int32)

    pos = bloom_hash_ref(q, h, nbits)  # (h, B), shared across levels

    for _ in range(levels + 1):
        cnt = run_count[node]
        # ---- Bloom probe (skip the run search on negative) ----------------
        w = bloom[node[None, :], pos // 32]              # (h, B)
        bit = (w >> (pos % 32).astype(jnp.uint32)) & jnp.uint32(1)
        positive = jnp.all(bit == 1, axis=0)
        probe = ~found & (cnt > 0) & (node != prev)      # filter consulted
        do = positive & probe
        # ---- lockstep binary search over the node's run -------------------
        lo = jnp.zeros(B, jnp.int32)
        hi = cnt
        for _s in range(steps):
            mid = (lo + hi) >> 1
            key = run_keys[node, jnp.clip(mid, 0, run_cap - 1)]
            right = (lo < hi) & (key < q)
            lo = jnp.where(right, mid + 1, lo)
            hi = jnp.where(right, hi, mid)
        hitk = run_keys[node, jnp.clip(lo, 0, run_cap - 1)]
        hit = do & (lo < cnt) & (hitk == q)
        out = jnp.where(hit & ~found, run_vals[node, jnp.clip(lo, 0, run_cap - 1)], out)
        found = found | hit
        n_probe += jnp.sum(probe.astype(jnp.int32))
        n_neg += jnp.sum((probe & ~positive).astype(jnp.int32))
        n_fp += jnp.sum((do & ~hit).astype(jnp.int32))
        # ---- descend via pivots (cross-s-node linkage) ---------------------
        pv = pivots[node]                                # (B, f-1)
        ci = jnp.sum((q[:, None] >= pv).astype(jnp.int32), axis=1)
        child = children[node, jnp.clip(ci, 0, f - 1)]
        prev = node
        node = jnp.where(nchild[node] > 0, child, node)
    present = found & (out != TOMBSTONE32)
    return present, out, n_probe, n_neg, n_fp


@functools.partial(
    jax.jit, static_argnames=("cap", "max_results", "run_cap", "steps"))
def _range_query_batch_impl(run_keys, run_vals, run_count, nodes, lo, hi, *,
                            cap, max_results, run_cap, steps):
    B, M = nodes.shape
    valid_node = nodes >= 0                      # (B, M), -1 = padding
    nid = jnp.maximum(nodes, 0)
    cnt = jnp.where(valid_node, run_count[nid], 0)
    lo_b, hi_b = lo[:, None], hi[:, None]

    # ---- lockstep lower/upper bound over every candidate run --------------
    def bound(q, closed):
        l = jnp.zeros((B, M), jnp.int32)
        h = cnt                                  # excludes KEY_MAX padding
        for _ in range(steps):
            mid = (l + h) >> 1
            key = run_keys[nid, jnp.clip(mid, 0, run_cap - 1)]
            go = (l < h) & ((key <= q) if closed else (key < q))
            l = jnp.where(go, mid + 1, l)
            h = jnp.where(go, h, mid)
        return l

    start = bound(lo_b, False)
    end = bound(hi_b, True)
    n_match = jnp.maximum(end - start, 0)        # per-node matches (pre-cap)

    # ---- masked gather of each matching span ------------------------------
    idx = start[..., None] + jnp.arange(cap, dtype=jnp.int32)   # (B, M, cap)
    valid = idx < end[..., None]
    safe = jnp.clip(idx, 0, run_cap - 1)
    gk = run_keys[nid[..., None], safe]
    gv = run_vals[nid[..., None], safe]
    ck = jnp.where(valid, gk, jnp.uint32(KEY_MAX32)).reshape(B, M * cap)
    cv = jnp.where(valid, gv, 0).reshape(B, M * cap)

    # ---- freshness resolution ---------------------------------------------
    # Candidates are level-major with m ordered pre-order (ancestors first)
    # and in-run position order within m (newer duplicate copies first, the
    # merge kernel's tie-break), so a *stable* sort by key puts the freshest
    # copy of every key first — the range generalization of first-hit-wins.
    order = jnp.argsort(ck, axis=1, stable=True)
    sk = jnp.take_along_axis(ck, order, axis=1)
    sv = jnp.take_along_axis(cv, order, axis=1)
    fresh = jnp.concatenate(
        [jnp.ones((B, 1), bool), sk[:, 1:] != sk[:, :-1]], axis=1)
    live = fresh & (sk != KEY_MAX32) & (sv != TOMBSTONE32)
    sk = jnp.where(live, sk, jnp.uint32(KEY_MAX32))
    sv = jnp.where(live, sv, 0)
    order2 = jnp.argsort(sk, axis=1, stable=True)
    sk = jnp.take_along_axis(sk, order2, axis=1)
    sv = jnp.take_along_axis(sv, order2, axis=1)
    total = jnp.sum(live.astype(jnp.int32), axis=1)
    truncated = (total > max_results) | jnp.any(n_match > cap, axis=1)
    return (sk[:, :max_results], sv[:, :max_results],
            jnp.minimum(total, max_results), truncated)


class NBTreeIndex:
    """Composable device-backed NB-tree index (see module docstring).

    ``fused=True`` (the default) runs the one-dispatch maintenance
    pipeline; ``fused=False`` keeps the pre-fusion eager write path —
    physically identical state, ~25x the dispatches per flush — as the
    differential-test oracle and benchmark baseline.
    """

    def __init__(self, f: int = 4, sigma: int = 4096, *, bits_per_key: int = 10,
                 num_hashes: int = 3, max_nodes: int = 256, max_levels: int = 12,
                 fused: bool = True):
        assert f >= 2 and sigma >= 2 * f
        self.f, self.sigma = f, sigma
        self.h = num_hashes
        self.sigma_pad = _round_up(sigma, TILE)
        self.run_cap = _round_up(f * (sigma + 1) + sigma, TILE)
        self.nbits = _round_up(self.run_cap * bits_per_key, 32 * 128)
        self.max_levels = max_levels
        self._steps = math.ceil(math.log2(self.run_cap + 1)) + 1
        self._fused = bool(fused)

        self.max_nodes = max_nodes
        nw = self.nbits // 32
        self.pivots = jnp.full((max_nodes, f - 1), KEY_MAX32, jnp.uint32)
        self.children = jnp.zeros((max_nodes, f), jnp.int32)
        self.nchild = jnp.zeros((max_nodes,), jnp.int32)
        self.run_keys = jnp.full((max_nodes, self.run_cap), KEY_MAX32, jnp.uint32)
        self.run_vals = jnp.zeros((max_nodes, self.run_cap), jnp.int32)
        self.run_count = jnp.zeros((max_nodes,), jnp.int32)
        self.bloom = jnp.zeros((max_nodes, nw), jnp.uint32)

        self.root = _HostNode(0)
        self._next_id = 1
        # oversized nodes awaiting work: deque + membership counter so the
        # hot loop's dequeue and the per-chunk "already queued?" check are
        # O(1) (they were O(n) list.pop(0) / `in` scans).
        self._pending: deque[_HostNode] = deque()
        self._pending_n: Counter = Counter()
        self.n_items = 0
        self.units_done = 0   # cumulative flush/split work units executed
        # Bloom effectiveness (paper Sec. 5.2); see query_batch.
        self.bloom_probes = 0
        self.bloom_negative_skips = 0
        self.bloom_false_positives = 0
        #: device dispatches issued by THIS index (per-instance; surfaced
        #: as ``EngineStats.device_dispatches``).
        self.dispatch_count = 0
        #: per-impl measured totals ``{name: {count, wall_s, bytes}}``,
        #: populated only while a tracer is attached (the roofline
        #: measured-bandwidth source; see repro.roofline.analysis).
        self.dispatch_stats: dict = {}
        self._tracer = None
        self._t_origin = 0.0

    # ------------------------------------------------------------ dispatch
    def attach_tracer(self, tracer, *, t_origin: float | None = None) -> None:
        """Record per-dispatch wall spans (category ``dispatch``) and
        per-impl timing/byte totals.  ``t_origin`` anchors span timestamps
        (perf_counter seconds); defaults to attach time."""
        self._tracer = tracer
        self._t_origin = (time.perf_counter() if t_origin is None
                          else t_origin)

    def _dispatch(self, fn, *args, **kwargs):
        """Per-instance dispatch shim over the module :func:`_device_call`
        funnel (still monkeypatchable there).  Counting is always on and
        O(1); timing + span emission only while a tracer is attached, so
        the untraced hot path stays a counter bump."""
        self.dispatch_count += 1
        if self._tracer is None:
            return _device_call(fn, *args, **kwargs)
        name = getattr(fn, "__name__", None) or repr(fn)
        t0 = time.perf_counter()
        out = _device_call(fn, *args, **kwargs)
        dt = time.perf_counter() - t0
        st = self.dispatch_stats.setdefault(
            name, {"count": 0, "wall_s": 0.0, "bytes": 0})
        st["count"] += 1
        st["wall_s"] += dt
        st["bytes"] += _tree_nbytes(args) + _tree_nbytes(out)
        self._tracer.complete("dispatch", name, t0 - self._t_origin, dt)
        return out

    # --------------------------------------------------------- pending queue
    def _enqueue(self, node: _HostNode, front: bool = False) -> None:
        (self._pending.appendleft if front else self._pending.append)(node)
        self._pending_n[node.nid] += 1

    def _dequeue(self) -> _HostNode:
        node = self._pending.popleft()
        self._pending_n[node.nid] -= 1
        if not self._pending_n[node.nid]:
            del self._pending_n[node.nid]
        return node

    # ------------------------------------------------------------------ public
    def insert_batch(self, keys, vals) -> None:
        """Merge a batch into the root run (device merge kernel).

        Oversized batches are split into sigma-sized chunks with
        backpressure maintenance between them — the bounded-latency
        contract holds per chunk (a caller that submits a giant batch has
        asked for the work; it is never deferred into later steps).
        """
        keys = jnp.asarray(keys, jnp.uint32)
        vals = jnp.asarray(vals, jnp.int32)
        n = int(keys.shape[0])
        if self.root.count + n > self.run_cap or n > self.sigma:
            for i in range(0, n, self.sigma):
                while self.root.count + self.sigma > self.run_cap:
                    if self.maintain(4) == 0 and self.root.count + self.sigma > self.run_cap:
                        break  # tree fully maintained; capacity guaranteed
                self._insert_chunk(keys[i:i + self.sigma], vals[i:i + self.sigma])
            return
        self._insert_chunk(keys, vals)

    def _insert_chunk(self, keys, vals) -> None:
        n = int(keys.shape[0])
        if self._fused:
            (self.run_keys, self.run_vals, self.run_count, self.bloom) = \
                self._dispatch(_insert_impl, self.run_keys, self.run_vals,
                             self.run_count, self.bloom, keys, vals,
                             run_cap=self.run_cap, nbits=self.nbits,
                             h=self.h, interpret=ops._interpret())
            self.root.count += n
        else:
            bk, bv = self._dispatch(_prepare_batch, keys, vals)
            merged_k, merged_v = self._dispatch(
                ops.merge_sorted, bk, bv,
                self.run_keys[0, : self.run_cap], self.run_vals[0])
            self.run_keys = self._dispatch(
                _write_row, self.run_keys, 0, merged_k[: self.run_cap])
            self.run_vals = self._dispatch(
                _write_row, self.run_vals, 0, merged_v[: self.run_cap])
            self.root.count += n
            self.run_count = self._dispatch(
                self.run_count.at[0].set, self.root.count)
            self.bloom = self._dispatch(
                _write_row, self.bloom, 0,
                self._dispatch(_build_bloom, self.run_keys[0], self.nbits,
                             self.h))
        assert self.root.count <= self.run_cap, "root run overflow: call maintain()"
        self.n_items += n
        if self.root.count > self.sigma and self.root.nid not in self._pending_n:
            self._enqueue(self.root)

    def delete_batch(self, keys) -> None:
        keys = jnp.asarray(keys, jnp.uint32)
        self.insert_batch(keys, jnp.full(keys.shape, TOMBSTONE32, jnp.int32))

    def query_batch(self, keys):
        """(present: bool (B,), vals: int32 (B,)) — one fused device call.

        Bloom-effectiveness tallies for the batch (probes / negative skips /
        false positives, reduced on device) accumulate into
        ``bloom_probes`` / ``bloom_negative_skips`` /
        ``bloom_false_positives`` — the paper Sec. 5.2 attribution counters
        surfaced through ``EngineStats``.
        """
        q = jnp.asarray(keys, jnp.uint32)
        present, out, n_probe, n_neg, n_fp = self._dispatch(
            _query_batch_impl, self.pivots, self.nchild, self.children,
            self.run_keys, self.run_vals, self.run_count, self.bloom, q,
            f=self.f, levels=self.max_levels, run_cap=self.run_cap,
            nbits=self.nbits, h=self.h, steps=self._steps)
        self.bloom_probes += int(n_probe)
        self.bloom_negative_skips += int(n_neg)
        self.bloom_false_positives += int(n_fp)
        return present, out

    def range_query_batch(self, lo, hi, max_results: int = 256):
        """Batched inclusive range scan [lo_b, hi_b] — one fused device call.

        Returns ``(keys uint32 (B, max_results), vals int32 (B, max_results),
        count int32 (B,), truncated bool (B,))``: per query the up-to-
        ``max_results`` freshest live pairs in the range, sorted by key and
        KEY_MAX-padded; ``count`` is the number of valid slots; ``truncated``
        flags queries whose full result did not fit (re-issue with a larger
        ``max_results`` for exact results).  ``lo > hi`` is an empty range.

        The host control plane routes each query to the nodes whose key
        interval intersects it (pre-order, ancestors first — see module
        docstring); the device pass searches, gathers, freshness-resolves
        and tombstone-filters in one jitted call.  Recompiles per distinct
        (B, routed-node-count-bucket, max_results) combination; the node
        bucket is padded to a power of two to bound recompiles.
        """
        lo = np.asarray(lo, np.uint32)
        hi = np.asarray(hi, np.uint32)
        assert lo.shape == hi.shape and lo.ndim == 1
        B = lo.shape[0]
        routes = [self._route_range(int(l), int(h)) for l, h in zip(lo, hi)]
        M = max(1, *(len(r) for r in routes)) if routes else 1
        M = 1 << (M - 1).bit_length()
        nodes = np.full((B, M), -1, np.int32)
        for b, r in enumerate(routes):
            nodes[b, : len(r)] = r
        return self._dispatch(
            _range_query_batch_impl,
            self.run_keys, self.run_vals, self.run_count,
            jnp.asarray(nodes), jnp.asarray(lo), jnp.asarray(hi),
            cap=int(max_results), max_results=int(max_results),
            run_cap=self.run_cap, steps=self._steps)

    def _route_range(self, lo: int, hi: int) -> list[int]:
        """Pre-order ids of nodes whose key interval intersects [lo, hi]."""
        if lo > hi:
            return []
        out: list[int] = []

        def rec(node, nlo, nhi):
            out.append(node.nid)
            if node.is_leaf:
                return
            bounds = [nlo, *node.skeys, nhi]
            for i, c in enumerate(node.children):
                clo, chi = bounds[i], bounds[i + 1]
                if (chi is None or lo < chi) and (clo is None or hi >= clo):
                    rec(c, clo, chi)

        rec(self.root, None, None)
        return out

    def maintain(self, max_units: int = 1) -> int:
        """Run up to ``max_units`` flush/split units; returns pending count.

        This is the deamortization knob: a serving loop calls
        ``maintain(k)`` once per step, so index upkeep can never stall a
        step for longer than k units — the paper's bounded worst-case
        insertion transplanted to the engine level.  On the fused path a
        flush unit is ONE device dispatch (plus one tiny count readback)
        and a split unit at most four — the per-unit dispatch budget is
        regression-tested.
        """
        units = 0
        while self._pending and units < max_units:
            node = self._dequeue()
            if node.count <= self.sigma:
                continue
            units += self._handle_full(node)
        return len(self._pending)

    def drain(self) -> None:
        while self.maintain(64):
            pass

    # -------------------------------------------------------- paper operations
    def _handle_full(self, node: _HostNode) -> int:
        """One HandleFullSNode step (Sec. 5.1).  Returns work units done."""
        self.units_done += 1
        if node.is_leaf:
            if node is self.root:
                self._split_root_leaf()
            else:
                self._split_upward(node)
            return 1
        self._flush(node)
        sizes = [c.count for c in node.children]
        big = int(np.argmax(sizes))
        if sizes[big] > self.sigma:
            # single recursive call — queued as a separate work unit.
            self._enqueue(node.children[big], front=True)
        if node.count > self.sigma:
            # node absorbed multiple batches; it still owes another flush.
            self._enqueue(node)
        return 1

    def _alloc(self, parent) -> _HostNode:
        if self._next_id >= self.max_nodes:
            self._grow_tables()
        n = _HostNode(self._next_id, parent)
        self._next_id += 1
        return n

    def _grow_tables(self) -> None:
        (self.pivots, self.children, self.nchild, self.run_keys,
         self.run_vals, self.run_count, self.bloom) = self._dispatch(
            _grow_impl, self.pivots, self.children, self.nchild,
            self.run_keys, self.run_vals, self.run_count, self.bloom)
        self.max_nodes *= 2

    def _flush(self, node: _HostNode) -> None:
        """Stream-merge the first sigma live pairs into the children."""
        if self._fused:
            self._flush_fused(node)
        else:
            self._flush_eager(node)

    def _flush_fused(self, node: _HostNode) -> None:
        nc = len(node.children)
        (self.run_keys, self.run_vals, self.run_count, self.bloom,
         counts) = self._dispatch(
            _flush_impl, self.run_keys, self.run_vals, self.run_count,
            self.bloom, jnp.int32(node.nid),
            jnp.asarray([c.nid for c in node.children], jnp.int32),
            jnp.asarray([int(k) for k in node.skeys], jnp.uint32),
            jnp.int32(node.count),
            nc=nc, leaf=node.children[0].is_leaf, sigma=self.sigma,
            sigma_pad=self.sigma_pad, run_cap=self.run_cap,
            nbits=self.nbits, h=self.h, interpret=ops._interpret())
        counts = np.asarray(counts)      # the flush's one device->host sync
        for child, c in zip(node.children, counts[:-1].tolist()):
            child.count = int(c)
            assert child.count <= self.run_cap, "child run overflow"
        node.count = int(counts[-1])

    def _flush_eager(self, node: _HostNode) -> None:
        """Pre-fusion write path: ~25 dispatches + host syncs per flush."""
        nid = node.nid
        moved = min(node.count, self.sigma)
        row_k, row_v = self.run_keys[nid], self.run_vals[nid]
        if moved < node.count:
            # Never split a duplicate group across the moved boundary (see
            # _flush_impl).
            k_cut = jnp.uint32(int(row_k[moved]))
            left = int(self._dispatch(jnp.searchsorted, row_k, k_cut,
                                    side="left"))
            if left > 0:
                moved = min(left, moved)
            else:
                moved = min(int(self._dispatch(jnp.searchsorted, row_k, k_cut,
                                             side="right")), node.count)
        piv = jnp.asarray([int(k) for k in node.skeys], jnp.uint32)
        cuts = jnp.minimum(
            self._dispatch(jnp.searchsorted, row_k, piv, side="left"), moved)
        cuts = np.asarray(cuts)                          # host ints, f-1 of them
        bounds = [0, *cuts.tolist(), moved]
        for i, child in enumerate(node.children):
            lo, hi = bounds[i], bounds[i + 1]
            if hi <= lo:
                continue
            part_k, part_v = self._dispatch(_window, row_k, row_v, jnp.int32(lo),
                                          jnp.int32(hi - lo), self.sigma_pad)
            mk, mv = self._dispatch(ops.merge_sorted, part_k, part_v,
                                  self.run_keys[child.nid],
                                  self.run_vals[child.nid])
            new_count = child.count + (hi - lo)
            if child.is_leaf:
                mk, mv, live = self._dispatch(_compact_tombstones, mk, mv,
                                            self.run_cap)
                new_count = int(live)
            else:
                mk, mv = mk[: self.run_cap], mv[: self.run_cap]
            assert new_count <= self.run_cap, "child run overflow"
            self.run_keys = self._dispatch(_write_row, self.run_keys,
                                         child.nid, mk)
            self.run_vals = self._dispatch(_write_row, self.run_vals,
                                         child.nid, mv)
            child.count = new_count
            self.run_count = self._dispatch(
                self.run_count.at[child.nid].set, new_count)
            self.bloom = self._dispatch(
                _write_row, self.bloom, child.nid,
                self._dispatch(_build_bloom, mk, self.nbits, self.h))
        # the paper advances a lazy watermark; a device row rewrite is a
        # stream copy, so we compact immediately (DESIGN.md §2).
        rest = node.count - moved
        rk, rv = self._dispatch(_window, row_k, row_v, jnp.int32(moved),
                              jnp.int32(rest), self.run_cap)
        self.run_keys = self._dispatch(_write_row, self.run_keys, nid, rk)
        self.run_vals = self._dispatch(_write_row, self.run_vals, nid, rv)
        node.count = rest
        self.run_count = self._dispatch(self.run_count.at[nid].set, rest)
        self.bloom = self._dispatch(
            _write_row, self.bloom, nid,
            self._dispatch(_build_bloom, rk, self.nbits, self.h))

    def _split_root_leaf(self) -> None:
        """First split: the root leaf becomes a root with two leaf children."""
        left, right = self._alloc(self.root), self._alloc(self.root)
        k_m = self._split_run(self.root, left, right)
        self.root.skeys = [k_m]
        self.root.children = [left, right]
        self._sync_structure(self.root)
        # root keeps an empty run (the in-memory buffer of the paper).
        self._clear_run(self.root)

    def _split_upward(self, node: _HostNode) -> None:
        self._split_node(node)
        anc = node.parent
        while anc is not None and len(anc.children) > self.f:
            if anc is self.root:
                self._split_root_internal()
                return
            self._split_node(anc)
            anc = anc.parent

    def _split_node(self, node: _HostNode) -> None:
        parent = node.parent
        left, right = self._alloc(parent), self._alloc(parent)
        k_m = self._split_structure(node, left, right)
        i = parent.children.index(node)
        parent.children[i: i + 1] = [left, right]
        parent.skeys.insert(i, k_m)
        self._sync_structure(parent)

    def _split_root_internal(self) -> None:
        """Root fanout exceeded f: grow the s-tree height by one."""
        old = self.root
        left = self._alloc(None)
        right = self._alloc(None)
        k_m = self._split_structure(old, left, right)
        old.skeys = [k_m]
        old.children = [left, right]
        left.parent = right.parent = old
        self._sync_structure(old)

    def _split_structure(self, node, left, right) -> int:
        """Split node's run (and pivots/children for internal nodes)."""
        if node.is_leaf:
            k_m = self._split_run(node, left, right)
        else:
            mid = len(node.skeys) // 2
            k_m = node.skeys[mid]
            left.skeys, right.skeys = node.skeys[:mid], node.skeys[mid + 1:]
            left.children, right.children = node.children[: mid + 1], node.children[mid + 1:]
            for c in left.children:
                c.parent = left
            for c in right.children:
                c.parent = right
            self._split_run(node, left, right, at_key=k_m)
            self._sync_structure(left)
            self._sync_structure(right)
        # the original node id is retired (host-side free list elided: ids
        # are cheap; production would recycle).
        self._clear_run(node)
        node.count = 0
        return k_m

    def _split_run(self, node, left, right, at_key: int | None = None) -> int:
        if self._fused:
            has_key = at_key is not None
            (self.run_keys, self.run_vals, self.run_count, self.bloom,
             out) = self._dispatch(
                _split_impl, self.run_keys, self.run_vals, self.run_count,
                self.bloom, jnp.int32(node.nid), jnp.int32(left.nid),
                jnp.int32(right.nid), jnp.int32(node.count),
                jnp.uint32(at_key if has_key else 0),
                has_key=has_key, run_cap=self.run_cap, nbits=self.nbits,
                h=self.h)
            out = np.asarray(out)        # the split's one device->host sync
            k_m, cut = int(out[0]), int(out[1])
            left.count, right.count = cut, node.count - cut
            return k_m
        nid = node.nid
        row_k, row_v = self.run_keys[nid], self.run_vals[nid]
        if at_key is None:
            mid = node.count // 2
            k_m = int(np.asarray(row_k[mid]))
            cut = int(np.asarray(self._dispatch(
                jnp.searchsorted, row_k, jnp.uint32(k_m), side="left")))
        else:
            k_m = int(at_key)
            cut = int(np.asarray(self._dispatch(
                jnp.searchsorted, row_k, jnp.uint32(k_m), side="left")))
            cut = min(cut, node.count)
        for dst, lo, ln in ((left, 0, cut), (right, cut, node.count - cut)):
            dk, dv = self._dispatch(_window, row_k, row_v, jnp.int32(lo),
                                  jnp.int32(ln), self.run_cap)
            self.run_keys = self._dispatch(_write_row, self.run_keys, dst.nid, dk)
            self.run_vals = self._dispatch(_write_row, self.run_vals, dst.nid, dv)
            dst.count = ln
            self.run_count = self._dispatch(self.run_count.at[dst.nid].set, ln)
            self.bloom = self._dispatch(
                _write_row, self.bloom, dst.nid,
                self._dispatch(_build_bloom, dk, self.nbits, self.h))
        return k_m

    def _clear_run(self, node) -> None:
        nid = node.nid
        if self._fused:
            (self.run_keys, self.run_vals, self.run_count, self.bloom) = \
                self._dispatch(_clear_impl, self.run_keys, self.run_vals,
                             self.run_count, self.bloom, jnp.int32(nid))
        else:
            self.run_keys = self._dispatch(
                _write_row, self.run_keys, nid,
                jnp.full(self.run_cap, KEY_MAX32, jnp.uint32))
            self.run_vals = self._dispatch(
                _write_row, self.run_vals, nid,
                jnp.zeros(self.run_cap, jnp.int32))
            self.run_count = self._dispatch(self.run_count.at[nid].set, 0)
            self.bloom = self._dispatch(
                _write_row, self.bloom, nid,
                jnp.zeros(self.nbits // 32, jnp.uint32))
        node.count = 0

    def _sync_structure(self, node: _HostNode) -> None:
        """Mirror a host node's pivots/children into the device tables."""
        nid = node.nid
        pv = np.full(self.f - 1, KEY_MAX32, np.uint32)
        ch = np.zeros(self.f, np.int32)
        for i, k in enumerate(node.skeys[: self.f - 1]):
            pv[i] = np.uint32(k)
        for i, c in enumerate(node.children[: self.f]):
            ch[i] = c.nid
        if self._fused:
            (self.pivots, self.children, self.nchild) = self._dispatch(
                _sync_impl, self.pivots, self.children, self.nchild,
                jnp.int32(nid), jnp.asarray(pv), jnp.asarray(ch),
                jnp.int32(len(node.children)))
        else:
            self.pivots = self._dispatch(self.pivots.at[nid].set,
                                       jnp.asarray(pv))
            self.children = self._dispatch(self.children.at[nid].set,
                                         jnp.asarray(ch))
            self.nchild = self._dispatch(self.nchild.at[nid].set,
                                       len(node.children))

    # ------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        assert not self._pending, "drain() before checking invariants"
        run_keys = np.asarray(self.run_keys)

        def rec(node, lo, hi_excl, depth, depths):
            ks = run_keys[node.nid][: node.count]
            if len(ks):
                assert np.all(ks[:-1] <= ks[1:]), "run not sorted"
                assert lo is None or ks[0] >= lo
                assert hi_excl is None or ks[-1] < hi_excl
            if node.is_leaf:
                depths.add(depth)
                return
            assert len(node.children) == len(node.skeys) + 1 <= self.f
            bounds = [lo, *node.skeys, hi_excl]
            for i, c in enumerate(node.children):
                assert c.parent is node
                rec(c, bounds[i], bounds[i + 1], depth + 1, depths)

        depths: set = set()
        rec(self.root, None, None, 0, depths)
        assert len(depths) <= 1, "leaves at non-uniform depth"

    @property
    def height(self) -> int:
        h, n = 0, self.root
        while not n.is_leaf:
            n, h = n.children[0], h + 1
        return h

    def total_pairs(self) -> int:
        total, stack = 0, [self.root]
        while stack:
            n = stack.pop()
            total += n.count
            stack.extend(n.children)
        return total
