"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

At multi-pod scale the "pod" axis rides data-center network, ~30x thinner
than ICI; the cross-pod gradient all-reduce is the step's dominant
collective.  We compress it 4x (f32 -> int8 on the wire): inside a
partial-manual ``shard_map`` over *only* the pod axis, per-pod gradients are
quantized with a shared per-tensor scale (psum-max), summed as int32, and
dequantized; the local quantization residual is carried to the next step
(error feedback), which keeps SGD convergence unbiased in practice
[Seide'14, 1-bit SGD lineage].

Intra-pod (data/model) reductions remain uncompressed XLA collectives —
they ride ICI where bandwidth is plentiful.

KNOWN LIMITATION (jaxlib 0.8.2): partial-manual shard_map over "pod"
combined with gathers on tensors sharded over a third ("model") mesh axis
trips an XLA SPMD-partitioner CHECK (spmd_partitioner_util.cc:504).  The
feature is therefore validated on ("pod", "data") DP/FSDP meshes — which is
where DCN compression matters; TP shards exchange only pod-local traffic.
Tracked for re-enable on 3-axis meshes with a jaxlib upgrade.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantized_psum_mean(tree, error, axis: str = "pod", bits: int = 8):
    """Compressed mean-reduction of a gradient pytree over a manual axis.

    Must be called inside a shard_map that is manual over ``axis``.
    Returns (reduced_tree, new_error_tree).
    """
    qmax = float(2 ** (bits - 1) - 1)
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis)
    else:                               # jax 0.4.x spelling
        n = jax.lax.psum(1, axis)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(x))
        amax = jax.lax.pmax(amax, axis)                  # shared scale
        scale = jnp.maximum(amax, 1e-12) / qmax
        q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
        # int8 on the wire; int32 accumulator avoids overflow for <=2^23 pods.
        s = jax.lax.psum(q.astype(jnp.int8).astype(jnp.int32), axis)
        deq = (s.astype(jnp.float32) * scale) / n
        new_e = x - q * scale                            # local residual
        return deq.astype(g.dtype), new_e

    out = jax.tree.map(one, tree, error)
    is_pair = lambda t: isinstance(t, tuple) and len(t) == 2 and not isinstance(t[0], tuple)
    red = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    err = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return red, err


def init_error(params, n_pods: int):
    """Per-pod residual buffers: leading pod axis, sharded P('pod')."""
    return jax.tree.map(
        lambda t: jnp.zeros((n_pods,) + t.shape, jnp.float32), params)
