"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib

_ARCHS = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x22b": "mixtral_8x22b",
    "xlstm-1.3b": "xlstm_1_3b",
    "starcoder2-3b": "starcoder2_3b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen3-8b": "qwen3_8b",
    "gemma-2b": "gemma_2b",
    "hubert-xlarge": "hubert_xlarge",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


def list_archs() -> list[str]:
    return sorted(_ARCHS)


def get_config(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch]}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in list_archs()}
