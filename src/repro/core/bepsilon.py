"""B^epsilon-tree baseline (paper Sec. 1.2/7, "B-tree with Buffer" [10]).

One node = one disk page; a fraction of the page holds a pivot array
(fanout ``f_be``) and the rest an insert buffer of ``buf_pairs`` pairs.
New pairs go to the root buffer (root and upper levels cached in memory);
a full buffer flushes to the child receiving the most pending pairs
(read-modify-write of one child page per flush step).

The paper's point — that the *small* per-node buffer forces frequent
scattered single-page flushes, i.e. a seek per few pairs moved, making
both average and worst-case insertion slow — emerges directly: each flush
moves O(buf_pairs / f_be) pairs for one seek + two page transfers, versus
NB-tree's sigma/f pairs per seek.  (The paper frames B^eps-trees as the
special case of NB-trees with s-node size = one disk page.)
"""
from __future__ import annotations

import numpy as np

from .cost_model import PAIR_BYTES, CostModel, Device, HDD
from .sorted_run import KEY_DTYPE, TOMBSTONE, VAL_DTYPE, merge_runs


class _Node:
    __slots__ = ("pivots", "children", "buf", "leaf_keys", "leaf_vals", "parent")

    def __init__(self, leaf: bool, parent=None):
        self.pivots: list = []
        self.children: list = []
        self.buf: dict = {}
        self.parent = parent
        self.leaf_keys = np.empty(0, KEY_DTYPE) if leaf else None
        self.leaf_vals = np.empty(0, VAL_DTYPE) if leaf else None

    @property
    def is_leaf(self):
        return self.leaf_keys is not None


class BEpsilonTree:
    def __init__(
        self,
        *,
        fanout: int = 16,
        node_bytes: int = 4 << 20,  # TokuDB-style 4 MB nodes
        cached_levels: int = 2,     # root region pinned in memory
        device: Device = HDD,
        cost: CostModel | None = None,
    ):
        self.f = fanout
        self.node_bytes = node_bytes
        # half the node holds the buffer, leaves are full nodes of pairs.
        self.buf_pairs = max(4, (node_bytes // 2) // PAIR_BYTES)
        self.leaf_pairs = max(8, node_bytes // PAIR_BYTES)
        self.cached_levels = cached_levels
        self.cm = cost or CostModel(device)
        self.root = _Node(leaf=True)
        self.n_inserted = 0

    # ---------------------------------------------------------------- inserts
    def insert(self, key, value) -> float:
        with self.cm.measure() as t:
            self._insert(self.root, np.uint64(key), np.int64(value), depth=0)
            self.n_inserted += 1
        return t.seconds

    def delete(self, key) -> float:
        return self.insert(key, TOMBSTONE)

    def _touch(self, depth: int, write: bool) -> None:
        """Node I/O (read-modify-write) unless this level is pinned in memory.

        B^eps nodes are scattered on disk, so every touch pays a seek — the
        contrast with NB-tree's sequential d-tree streams (paper Sec. 7).
        """
        if depth >= self.cached_levels:
            self.cm.seek()
            self.cm.seq_read(self.node_bytes)
            if write:
                self.cm.seek()
                self.cm.seq_write(self.node_bytes)

    def _insert(self, node: _Node, key, val, depth: int) -> None:
        if node.is_leaf:
            self._leaf_put(node, np.asarray([key], KEY_DTYPE), np.asarray([val], VAL_DTYPE), depth)
            return
        node.buf[key] = val
        if len(node.buf) > self.buf_pairs:
            self._flush(node, depth)

    def _flush(self, node: _Node, depth: int) -> None:
        """Flush the node buffer to the single fullest child (classic B^eps)."""
        self._touch(depth, write=True)  # rewrite this node's page (buffer drained)
        keys = np.fromiter(node.buf.keys(), KEY_DTYPE, len(node.buf))
        vals = np.fromiter(node.buf.values(), VAL_DTYPE, len(node.buf))
        order = np.argsort(keys)
        keys, vals = keys[order], vals[order]
        piv = np.asarray(node.pivots, KEY_DTYPE)
        cidx = np.searchsorted(piv, keys, side="right")
        counts = np.bincount(cidx, minlength=len(node.children))
        target = int(np.argmax(counts))
        sel = cidx == target
        tk, tv = keys[sel], vals[sel]
        node.buf = {k: v for k, v, s in zip(keys, vals, ~sel) if s}
        child = node.children[target]
        if child.is_leaf:
            self._leaf_put(child, tk, tv, depth + 1)
        else:
            self._touch(depth + 1, write=True)
            for k, v in zip(tk, tv):
                child.buf[k] = v
            if len(child.buf) > self.buf_pairs:
                self._flush(child, depth + 1)
        # child-count growth (and any further splits) is handled by _replace.

    def _leaf_put(self, leaf: _Node, keys, vals, depth: int) -> None:
        self._touch(depth, write=True)
        leaf.leaf_keys, leaf.leaf_vals = merge_runs(keys, vals, leaf.leaf_keys, leaf.leaf_vals)
        self._maybe_split(leaf, depth)

    def _maybe_split(self, node: _Node, depth: int) -> None:
        if node.is_leaf:
            if len(node.leaf_keys) <= self.leaf_pairs:
                return
            mid = len(node.leaf_keys) // 2
            k_m = node.leaf_keys[mid]
            left, right = _Node(True), _Node(True)
            left.leaf_keys, left.leaf_vals = node.leaf_keys[:mid], node.leaf_vals[:mid]
            right.leaf_keys, right.leaf_vals = node.leaf_keys[mid:], node.leaf_vals[mid:]
        else:
            if len(node.children) <= self.f:
                return
            mid = len(node.pivots) // 2
            k_m = node.pivots[mid]
            left, right = _Node(False), _Node(False)
            left.pivots, right.pivots = node.pivots[:mid], node.pivots[mid + 1:]
            left.children, right.children = node.children[: mid + 1], node.children[mid + 1:]
            for c in left.children:
                c.parent = left
            for c in right.children:
                c.parent = right
            for k, v in node.buf.items():
                (left if k < k_m else right).buf[k] = v
        self.cm.seek()
        self.cm.seq_write(2 * self.node_bytes)
        self._replace(node, k_m, left, right, depth)

    def _replace(self, node: _Node, k_m, left, right, depth: int) -> None:
        if node is self.root:
            new_root = _Node(False)
            new_root.pivots = [k_m]
            new_root.children = [left, right]
            left.parent = right.parent = new_root
            self.root = new_root
            return
        parent = node.parent
        left.parent = right.parent = parent
        i = parent.children.index(node)
        parent.children[i: i + 1] = [left, right]
        parent.pivots.insert(i, k_m)
        if len(parent.children) > self.f:
            self._maybe_split(parent, depth - 1)

    # ---------------------------------------------------------------- queries
    def get(self, key):
        key = np.uint64(key)
        with self.cm.measure() as t:
            v = self._get(key)
        self._last_query_time = t.seconds
        return v

    def query(self, key):
        v = self.get(key)
        return v, self._last_query_time

    def _get(self, key):
        node, depth = self.root, 0
        while True:
            if depth >= self.cached_levels:
                self.cm.page_read()  # queries touch one basement page, not the node
            if node.is_leaf:
                i = int(np.searchsorted(node.leaf_keys, key))
                if i < len(node.leaf_keys) and node.leaf_keys[i] == key:
                    v = node.leaf_vals[i]
                    return None if v == TOMBSTONE else v
                return None
            if key in node.buf:
                v = node.buf[key]
                return None if v == TOMBSTONE else v
            i = int(np.searchsorted(np.asarray(node.pivots, KEY_DTYPE), key, side="right"))
            node = node.children[i]
            depth += 1

    def range_query(self, lo, hi):
        """Inclusive range scan [lo, hi]; returns (keys, vals) numpy arrays.

        Pre-order walk of every node whose key interval intersects the
        range, node buffer before children: buffered entries are *newer*
        than any copy of the same key below them (entries only ever flush
        downward), so first-occurrence-wins resolves freshness, and the
        cross-node pivot invariant guarantees a key appears along only one
        root-to-leaf path.  Tombstone delta records are dropped at the end.

        Cost accounting mirrors :meth:`_get`: each visited node at an
        uncached level pays one random page read (seek + page — the node's
        pivots and buffer arrive with its page); visited leaves additionally
        stream their matching span sequentially.  Many scattered node pages
        per range is exactly the B^eps read amplification the paper
        contrasts with NB-tree's few sequential d-tree spans.  ``lo > hi``
        is an empty range.
        """
        lo, hi = np.uint64(lo), np.uint64(hi)
        with self.cm.measure() as t:
            result: dict = {}

            def rec(node: _Node, depth: int) -> None:
                if depth >= self.cached_levels:
                    self.cm.page_read()
                if node.is_leaf:
                    i0 = int(np.searchsorted(node.leaf_keys, lo, side="left"))
                    i1 = int(np.searchsorted(node.leaf_keys, hi, side="right"))
                    if i1 > i0:
                        if depth >= self.cached_levels:
                            self.cm.read_pairs(i1 - i0)
                        for k, v in zip(node.leaf_keys[i0:i1].tolist(),
                                        node.leaf_vals[i0:i1].tolist()):
                            if k not in result:
                                result[k] = v
                    return
                for k, v in node.buf.items():       # keys unique within a buf
                    if lo <= k <= hi and int(k) not in result:
                        result[int(k)] = int(v)
                bounds = [None, *node.pivots, None]
                for i, c in enumerate(node.children):
                    clo, chi = bounds[i], bounds[i + 1]
                    if (chi is None or lo < chi) and (clo is None or hi >= clo):
                        rec(c, depth + 1)

            if lo <= hi:
                rec(self.root, 0)
            ks = sorted(k for k, v in result.items() if v != TOMBSTONE)
            out = (np.asarray(ks, KEY_DTYPE),
                   np.asarray([result[k] for k in ks], VAL_DTYPE))
        self._last_query_time = t.seconds
        return out

    def drain(self) -> None:
        pass

    def total_pairs(self) -> int:
        total, stack = 0, [self.root]
        while stack:
            n = stack.pop()
            total += len(n.buf) if not n.is_leaf else len(n.leaf_keys)
            stack.extend(n.children)
        return total
