"""Model / run configuration dataclasses.

One ``ModelConfig`` instance per assigned architecture lives in
``repro/configs/<arch>.py``; reduced variants for CPU smoke tests come from
:func:`ModelConfig.reduced`.

``segments`` describes the layer stack as (block_kind, count) groups.  Each
group with count > 1 is executed as one ``lax.scan`` over stacked parameters
(compact HLO — essential for 512-way SPMD compiles), so heterogeneous stacks
(hymba's global/local mix, xlstm's mLSTM/sLSTM interleave) are expressed
*exactly*, without dead branches that would pollute the roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Segments = Tuple[Tuple[str, int], ...]

#: block kinds understood by models/transformer.py
BLOCK_KINDS = (
    "dense",          # full causal attention + MLP
    "swa",            # sliding-window attention + MLP
    "moe",            # full attention + MoE MLP
    "moe_swa",        # sliding-window attention + MoE MLP
    "mla",            # multi-head latent attention + MLP
    "encoder",        # bidirectional attention + MLP (no causal mask)
    "mlstm",          # xLSTM matrix-memory block (self-contained)
    "slstm",          # xLSTM scalar-memory block (self-contained)
    "hybrid",         # hymba: parallel SWA-attention + SSM heads, + MLP
    "hybrid_global",  # hymba: parallel full-attention + SSM heads, + MLP
)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: Segments
    head_dim: Optional[int] = None          # defaults to d_model // n_heads
    # attention
    qk_norm: bool = False
    swa_window: int = 4096
    rope_base: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None
    encoder_only: bool = False
    # mlp
    mlp_kind: str = "swiglu"                # swiglu | geglu | gelu
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: Optional[int] = None
    capacity_factor: float = 1.25
    # MLA
    mla: Optional[MLAConfig] = None
    # SSM
    ssm_state: int = 16
    ssm_expand: int = 2
    conv_width: int = 4
    # misc
    norm_kind: str = "rms"                  # rms | layer
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "layer"                    # none | layer | full
    kv_cache_dtype: str = "model"           # model | int8 (quantized decode KV)

    def __post_init__(self):
        assert sum(c for _, c in self.segments) == self.n_layers, (
            f"{self.name}: segments {self.segments} != n_layers {self.n_layers}")
        for kind, _ in self.segments:
            assert kind in BLOCK_KINDS, kind

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_recurrent(self) -> bool:
        """True if decode carries recurrent state instead of a growing KV."""
        return all(k in ("mlstm", "slstm") for k, _ in self.segments)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no block attends to unbounded context...

        SSM/hybrid/SWA stacks qualify; any 'dense'/'moe'/'mla'/'encoder'
        block makes the arch full-attention (skip long_500k, DESIGN.md §6).
        Hymba's 3 global-attention layers are the documented exception: the
        arch is hybrid by design and the pool assigns it long-context duty.
        """
        kinds = {k for k, _ in self.segments}
        full = {"dense", "moe", "mla", "encoder"}
        if self.name.startswith("hymba"):
            return True
        return not (kinds & full)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        scale: dict = dict(
            n_layers=sum(min(c, 2) for _, c in self.segments),
            segments=tuple((k, min(c, 2)) for k, c in self.segments),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            head_dim=32 if self.head_dim else None,
            swa_window=16,
        )
        if self.n_experts:
            scale.update(n_experts=4, top_k=min(self.top_k, 2),
                         d_expert=64 if self.d_expert else None)
        if self.mla is not None:
            scale.update(mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                                       qk_nope_head_dim=16, qk_rope_head_dim=8,
                                       v_head_dim=16))
        if self.mrope_sections is not None:
            scale.update(mrope_sections=(4, 6, 6))
        scale.update(overrides)
        return dataclasses.replace(self, **scale)
