"""Deterministic mixed-workload subsystem (DESIGN.md §5).

``generator`` turns a named mix (insert-heavy, point-read-heavy,
range-heavy, YCSB-A/B/E-style blends, delete-churn) plus a key
distribution (uniform or zipfian) into a reproducible stream of
``OpBatch``es; ``driver`` streams any such workload through any registered
``StorageEngine`` and records per-op latency/cost histograms with
p50/p99/p100 — the measurement harness of Luo & Carey's LSM evaluations,
transplanted to the paper's five tiers.
"""
from .generator import MIXES, Workload, WorkloadSpec, make_workload

# NOTE: ``driver`` and ``tenants`` are intentionally not re-exported here —
# ``driver`` at package level would shadow ``python -m repro.workloads.driver``
# (runpy's sys.modules warning), and ``tenants`` imports ``repro.ingest``,
# which imports this package back (generator) — a cycle at import time.
# Import ``repro.workloads.driver`` / ``repro.workloads.tenants`` directly.
__all__ = ["MIXES", "Workload", "WorkloadSpec", "make_workload"]
