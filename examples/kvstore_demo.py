"""The paper's own scenario: insertion-intensive store vs LSM vs B+-tree.

Reproduces the headline comparison (Figs 6-9) at demo scale and prints the
worst-case-insert and query-time contrast.

  PYTHONPATH=src python examples/kvstore_demo.py
"""
import numpy as np

from repro.core.btree import BPlusTreeBulk
from repro.core.cost_model import HDD
from repro.core.lsm import LSMTree
from repro.core.refimpl import NBTree

n = 60_000
rng = np.random.default_rng(7)
keys = np.unique(rng.integers(1, 1 << 40, size=int(n * 1.02), dtype=np.uint64))[:n]
keys = rng.permutation(keys)

nb, lsm = NBTree(f=3, sigma=2048, device=HDD), LSMTree(mem_pairs=2048, device=HDD)
nb_t = [nb.insert(k, i) for i, k in enumerate(keys)]
lsm_t = [lsm.insert(k, i) for i, k in enumerate(keys)]
nb.drain()
print(f"avg insert   : NB {nb.cm.time/n*1e6:8.1f} us | LSM {lsm.cm.time/n*1e6:8.1f} us")
print(f"WORST insert : NB {max(nb_t)*1e3:8.3f} ms | LSM {max(lsm_t)*1e3:8.1f} ms  "
      f"(<-- the paper's 1000x, Fig. 7)")

bulk = BPlusTreeBulk(keys, np.arange(n, dtype=np.int64), device=HDD)
q = rng.choice(keys, 300, replace=False)
nbq = np.mean([nb.query(k)[1] for k in q])
lsmq = np.mean([lsm.query(k)[1] for k in q])
btq = np.mean([bulk.query(k)[1] for k in q])
print(f"avg query    : NB {nbq*1e3:6.2f} ms | LSM {lsmq*1e3:6.2f} ms | "
      f"B+bulk {btq*1e3:6.2f} ms   (Fig. 8)")

# range scans (1% selectivity): every index serves the same inclusive API.
span = np.uint64((1 << 40) // 100)
los = rng.integers(1, (1 << 40) - int(span), 30).astype(np.uint64)
res = {}
for name, idx in (("NB", nb), ("LSM", lsm), ("B+bulk", bulk)):
    t, hits = [], 0
    for lo in los:
        rk, _ = idx.range_query(lo, lo + span)
        t.append(idx._last_query_time)
        hits += len(rk)
    res[name] = (np.mean(t), hits)
assert len({h for _, h in res.values()}) == 1, "indexes disagree on range hits"
print("range scan 1%: " + " | ".join(
    f"{k} {v[0]*1e3:6.2f} ms" for k, v in res.items())
    + f"   ({res['NB'][1] // len(los)} hits/query, all indexes agree)")
