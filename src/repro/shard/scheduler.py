"""Cross-shard deamortized maintenance scheduling (DESIGN.md §6).

The paper's worst-case insertion-delay bound comes from spending a bounded
amount of maintenance per serving step (Sec. 5.1).  A sharded ensemble
breaks that bound if the step budget is spent obliviously: Luo & Carey
("On Performance Stability in LSM-based Storage Systems") show that
unscheduled background maintenance across partitions is exactly what
reintroduces write stalls at scale-out.  The fix is the same deamortization
argument applied one level up — each serving step's budget is *allocated*
across shards so the shard closest to a forced synchronous drain is always
served first.

:class:`DebtScheduler` is that allocator, kept as a pure, deterministic
strategy object so it can be unit-tested without engines: given the current
per-shard debt vector and a unit budget it returns how many maintenance
units each shard receives this step.  Policy: one unit at a time to the
heaviest *remaining* (optimistically decremented) debt, ties broken by a
persistent round-robin pointer so equally-indebted shards share the budget
fairly across steps instead of the lowest id starving the rest.

Straggler-aware priority: shards flagged by the caller (a
``StragglerDetector`` over per-unit maintain seconds, see
``ShardedEngine.maintain``) have their remaining debt *weighted* by
``straggler_boost`` when choosing where the next unit goes.  The units a
slow shard owes cost more charged seconds each, so at equal debt counts
it is closer — in time — to a forced synchronous drain; front-loading it
caps the ensemble's worst maintain tail.  Measured on a 4-shard skewed
ingest (one shard on a device with 4x per-unit cost, see
``tests/test_replication.py::test_straggler_boost_drains_slow_shard``)
the boost cuts the slow shard's peak outstanding debt roughly in half
with unchanged total units; with no straggler flagged the allocation is
bit-identical to the unweighted policy, so the hook is kept.
"""
from __future__ import annotations


class DebtScheduler:
    """Debt-weighted, round-robin-tiebroken budget allocator."""

    def __init__(self, straggler_boost: float = 2.0):
        assert straggler_boost >= 1.0
        self._rr = 0  # persistent tiebreak pointer (fairness across calls)
        self.straggler_boost = float(straggler_boost)

    def allocate(self, debts, budget: int, stragglers=()) -> list[int]:
        """Distribute ``budget`` maintenance units over ``debts``.

        Returns a per-shard unit allocation with ``sum(alloc) ==
        min(budget, sum(debts))``.  Each unit goes to the shard with the
        highest remaining debt (debt is optimistically decremented by one
        per granted unit; the engine refreshes true debt from the shard's
        ``maintain`` return value afterwards).  Exact ties go to the shard
        at or after the round-robin pointer, which then advances — so a
        uniformly indebted ensemble is served in rotation, not by id.

        Shards listed in ``stragglers`` compete with ``remaining *
        straggler_boost`` as their effective debt — extra budget for
        persistently slow shards, never units they don't owe (a shard
        with zero remaining debt gets nothing regardless of flags).
        """
        remaining = [int(d) for d in debts]
        alloc = [0] * len(remaining)
        n = len(remaining)
        slow = set(stragglers)
        boost = self.straggler_boost
        for _ in range(max(0, int(budget))):
            best, best_debt = -1, 0.0
            for off in range(n):
                s = (self._rr + off) % n
                eff = remaining[s] * (boost if s in slow else 1.0)
                if eff > best_debt:
                    best, best_debt = s, eff
            if best < 0:
                break
            alloc[best] += 1
            remaining[best] -= 1
            self._rr = (best + 1) % n
        return alloc
