"""Hypothesis property tests: NB-tree == dict semantics + structural invariants.

The model-based oracle: any interleaving of insert/update/delete followed by
drain must make the NB-tree (both tiers) indistinguishable from a python
dict, while every intermediate state keeps the cross-s-node linkage and
fanout properties.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # collection degrades to skip without it
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.refimpl import NBTree

ops_strategy = st.lists(
    st.tuples(
        st.sampled_from(["insert", "delete", "update", "query"]),
        st.integers(min_value=1, max_value=400),
        st.integers(min_value=0, max_value=2**31 - 1),
    ),
    min_size=1, max_size=300,
)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy,
       f=st.integers(min_value=2, max_value=5),
       sigma=st.sampled_from([16, 32, 64]))
def test_matches_dict_model(ops, f, sigma):
    nb = NBTree(f=f, sigma=sigma)
    model = {}
    for op, key, val in ops:
        if op == "insert" or op == "update":
            nb.insert(key, val)
            model[np.uint64(key)] = val
        elif op == "delete":
            nb.delete(key)
            model.pop(np.uint64(key), None)
        else:
            got = nb.get(key)
            want = model.get(np.uint64(key))
            assert (got is None) == (want is None)
            if want is not None:
                assert got == want
    nb.drain()
    nb.check_invariants()
    for k, v in model.items():
        assert nb.get(k) == v, k


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy,
       f=st.integers(min_value=2, max_value=5),
       sigma=st.sampled_from([16, 32, 64]),
       ranges=st.lists(st.tuples(st.integers(0, 450), st.integers(0, 450)),
                       min_size=1, max_size=6))
def test_range_query_matches_dict_model(ops, f, sigma, ranges):
    """Inclusive range scans == the dict model at every interleaving point,
    including empty ranges (lo > hi), lo == hi, and ranges spanning the
    whole key space (hence every node split)."""
    nb = NBTree(f=f, sigma=sigma)
    model = {}
    for op, key, val in ops:
        if op == "insert" or op == "update":
            nb.insert(key, val)
            model[int(key)] = val
        elif op == "delete":
            nb.delete(key)
            model.pop(int(key), None)
    for lo, hi in [*ranges, (0, 500), (17, 17), (400, 10)]:
        rk, rv = nb.range_query(lo, hi)
        want = sorted((k, v) for k, v in model.items() if lo <= k <= hi)
        assert rk.tolist() == [k for k, _ in want], (lo, hi)
        assert rv.tolist() == [v for _, v in want], (lo, hi)
    nb.drain()
    nb.check_invariants()
    rk, rv = nb.range_query(0, 500)
    want = sorted(model.items())
    assert rk.tolist() == [k for k, _ in want]
    assert rv.tolist() == [v for _, v in want]


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n=st.integers(min_value=50, max_value=2000),
       seed=st.integers(min_value=0, max_value=2**16))
def test_invariants_under_bulk_load(n, seed):
    rng = np.random.default_rng(seed)
    keys = rng.choice(np.arange(1, 1 << 40, dtype=np.uint64), n, replace=False)
    nb = NBTree(f=3, sigma=64)
    for i, k in enumerate(keys):
        nb.insert(k, i)
    nb.drain()
    nb.check_invariants()
    assert nb.total_pairs() == n


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_sorted_order_monotone_keys(seed):
    """Adversarial pattern for B-tree splits: monotonically increasing keys."""
    nb = NBTree(f=3, sigma=32)
    for i in range(1500):
        nb.insert(i * 7 + seed % 7, i)
    nb.drain()
    nb.check_invariants()
    assert nb.get(7 * 100 + seed % 7) == 100
