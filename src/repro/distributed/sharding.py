"""Sharding rules: logical axes -> mesh axes, GSPMD constraints, param specs.

Mesh axes (launch/mesh.py):
  pod    — DCN axis across pods: pure data parallel (gradient all-reduce
           over the slow interconnect only once per step).
  data   — FSDP: batch + fully-sharded parameters/optimizer state.
  model  — TP/EP: attention heads, MLP hidden, MoE experts, vocab.

``PARAM_RULES`` maps parameter-name suffixes to PartitionSpecs; anything
unmatched is replicated.  Activations get explicit constraints at block
boundaries via :func:`constrain` (a no-op outside a mesh context so models
run unsharded on a single CPU device in tests).
"""
from __future__ import annotations

import re

import jax
import numpy as np

from ..launch.mesh import current_mesh
from jax.sharding import PartitionSpec as P

#: logical -> physical for activations (tuples = joint axes, e.g. the
#: data-parallel product ("pod", "data") for batch/group dims).
ACT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
}


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool | None = None):
    """``jax.shard_map`` on current jax; ``jax.experimental.shard_map`` with
    the equivalent ``auto``/``check_rep`` spelling on 0.4.x.

    ``axis_names`` is the set of *manual* axes (None = all of them), as in
    the new API; on 0.4.x it is translated to the complement ``auto`` set.
    ``check_vma=None`` keeps each API's own default.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  axis_names=axis_names, **kw)
    from jax.experimental.shard_map import shard_map as sm_old
    auto = frozenset(mesh.axis_names) - set(axis_names or mesh.axis_names)
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  auto=auto, **kw)


def mesh_axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or mesh.empty or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def constrain(x, *logical):
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None or mesh.empty:
        return x
    # Inside a partial-manual shard_map (the compressed-gradient pod loop)
    # activation constraints are dropped entirely: mixing them with manual
    # axes trips an XLA SPMD-partitioner CHECK (spmd_partitioner_util.cc:504,
    # jaxlib 0.8.2); GSPMD still propagates sharding from the in/out specs.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and any(
            t == axis_type.Manual for t in getattr(mesh, "axis_types", ())):
        return x
    manual = set()
    spec = []
    for dim, l in zip(x.shape, logical):
        phys = ACT_RULES.get(l) if l is not None else None
        if phys is None:
            spec.append(None)
            continue
        axes = tuple(a for a in ((phys,) if isinstance(phys, str) else phys)
                     if a in mesh.shape and a not in manual)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if axes and dim % size == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# --------------------------------------------------------------- param rules
# suffix-pattern -> spec builder (rank-aware).  Stacked (scan) params have a
# leading layer dim, handled by _pad_spec.
PARAM_RULES: list[tuple[str, tuple]] = [
    # attention projections: shard the head/feature product dim over model,
    # the d_model dim over data (FSDP).
    (r"\.attn\.wq$", ("data", "model")),
    (r"\.attn\.wk$", ("data", "model")),
    (r"\.attn\.wv$", ("data", "model")),
    (r"\.attn\.wo$", ("model", "data")),
    # MLA
    (r"\.attn\.wq_down$", ("data", "model")),
    (r"\.attn\.wq_up$", (None, "model")),
    (r"\.attn\.wkv_down$", ("data", None)),
    (r"\.attn\.wk_up$", (None, "model")),
    (r"\.attn\.wv_up$", (None, "model")),
    # dense MLP
    (r"\.mlp\.wi$", ("data", "model")),
    (r"\.mlp\.wg$", ("data", "model")),
    (r"\.mlp\.wo$", ("model", "data")),
    # MoE: experts over model (EP); when E doesn't divide the model axis
    # (mixtral: 8 experts on a 16-way axis) fall back to tensor-parallel
    # expert FFNs (hidden dim over model) — candidate list, first valid wins.
    (r"\.moe\.router$", (None, None)),
    (r"\.moe\.wi$", [("model", "data", None), (None, "data", "model")]),
    (r"\.moe\.wg$", [("model", "data", None), (None, "data", "model")]),
    (r"\.moe\.wo$", [("model", None, "data"), (None, "model", "data")]),
    (r"\.moe\.shared\.wi$", ("data", "model")),
    (r"\.moe\.shared\.wg$", ("data", "model")),
    (r"\.moe\.shared\.wo$", ("model", "data")),
    # xLSTM / SSM
    (r"\.cell\.wq$", ("data", "model")),
    (r"\.cell\.wk$", ("data", "model")),
    (r"\.cell\.wv$", ("data", "model")),
    (r"\.cell\.w_in$", ("data", "model")),
    (r"\.cell\.w_bcdt$", ("model", None)),
    (r"\.cell\.w_out$", ("model", "data")),
    (r"\.cell\.wz$", ("data", "model")),
    (r"\.cell\.wi$", ("data", "model")),
    (r"\.cell\.wf$", ("data", "model")),
    (r"\.cell\.wo_gate$", ("data", "model")),
    (r"\.cell\.r$", ("data", "model")),
    (r"\.cell\.wo$", ("model", "data")),
    # embeddings: vocab over model, features over data.
    (r"^embed$", ("model", "data")),
    (r"^unembed$", ("data", "model")),
]


def _candidates_for(path: str, ndim: int, stacked: bool):
    """Ordered candidate specs for a parameter path (first valid wins)."""
    for pat, spec in PARAM_RULES:
        if re.search(pat, path):
            cands = spec if isinstance(spec, list) else [spec]
            out = []
            for c in cands:
                c = tuple(c)
                if stacked:
                    c = (None,) + c  # leading scan-layer dim
                if len(c) < ndim:
                    c = c + (None,) * (ndim - len(c))
                out.append(c[:ndim])
            return out
    return [(None,) * ndim]


def _validate(spec, shape, mesh):
    fixed, full = [], True
    for dim, ax in zip(shape, spec):
        ok = ax is not None and ax in mesh.shape and dim % mesh.shape[ax] == 0
        fixed.append(ax if ok else None)
        if ax is not None and not ok:
            full = False
    return tuple(fixed), full


def param_specs(params, mesh=None):
    """PartitionSpec pytree for a parameter pytree (paths drive the rules).

    Each rule may list fallback candidates; the first whose named axes all
    divide the tensor is used, otherwise non-dividing axes of the best
    candidate are dropped (tiny smoke configs on big meshes).
    """
    mesh = mesh or current_mesh()
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(kp):
        return ".".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)

    specs = {}
    for kp, leaf in flat:
        path = path_str(kp)
        stacked = path.startswith("seg")  # scanned segment params: leading L dim
        cands = _candidates_for(path, leaf.ndim, stacked)
        if mesh is None or mesh.empty:
            specs[path] = P(*cands[0])
            continue
        chosen = None
        for c in cands:
            fixed, full = _validate(c, leaf.shape, mesh)
            if full:
                chosen = fixed
                break
        if chosen is None:
            chosen, _ = _validate(cands[0], leaf.shape, mesh)
        specs[path] = P(*chosen)

    # rebuild tree
    treedef = jax.tree_util.tree_structure(params)
    leaves = [specs[path_str(kp)] for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)
