"""End-to-end training driver.

CPU-scale example (reduced config, real pipeline):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir runs/train_gemma

Production shape (the dry-run validates this path on the 16x16/2x16x16
meshes; on real hardware drop --reduced and pass --mesh single|multi).
"""
from __future__ import annotations

import argparse
import dataclasses

from ..data.pipeline import PackedBatches, StreamingIngest, synthetic_documents
from ..models import registry
from ..optim import adamw
from ..optim.schedules import cosine_with_warmup
from ..train.trainer import Trainer
from .mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit("use a decoder arch for the LM training example")

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    ingest = StreamingIngest()
    for doc in synthetic_documents(512, args.seq + 8, cfg.vocab):
        ingest.ingest(doc)
    print(f"ingested {len(ingest)} docs (NB-tree indexed, {ingest.dups} dups dropped)")
    batches = PackedBatches(ingest, args.batch, args.seq)

    opt_cfg = adamw.AdamWConfig(
        lr=cosine_with_warmup(args.lr, args.steps // 10 + 1, args.steps))
    tr = Trainer(cfg, mesh=mesh, opt_cfg=opt_cfg, ckpt_dir=args.ckpt_dir,
                 num_microbatches=args.microbatches,
                 grad_compression=args.grad_compression)
    hist = tr.run(batches, args.steps, ckpt_every=args.ckpt_every)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(first {hist[0]['loss']:.4f}) over {len(hist)} steps")


if __name__ == "__main__":
    main()
