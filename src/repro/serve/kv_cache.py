"""Paged KV cache with an NB-tree block index (the paper -> serving bridge).

vLLM-style paging: physical KV pages are rows of (L, KVH, P, S, D) device
arrays; the *logical -> physical* page mapping is the NB-tree
(core/jax_nbtree.NBTreeIndex) keyed by pack(seq_id, logical_block):

  * decode inserts one mapping per sequence per S tokens — the
    insertion-intensive workload of the paper, at engine rate;
  * block-table construction is a batched NB-tree query (Bloom-gated
    descent, one fused device call);
  * ``maintain(budget)`` runs per engine step with a bounded unit budget —
    the deamortization guarantee: index upkeep can never stall a serve
    step beyond the budget (paper Sec. 5.1 transplanted).

Keys pack seq_id in the high bits so a sequence's blocks are contiguous in
key space (its block list is one range scan; frees are a contiguous batch).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..core.jax_nbtree import NBTreeIndex

SEQ_BITS = 18
BLOCK_BITS = 32 - SEQ_BITS
MAX_BLOCKS_PER_SEQ = (1 << BLOCK_BITS) - 1


def pack_key(seq_id, block) -> np.ndarray:
    seq_id = np.asarray(seq_id, np.uint32)
    block = np.asarray(block, np.uint32)
    assert (block < MAX_BLOCKS_PER_SEQ).all()
    return (seq_id << np.uint32(BLOCK_BITS)) | block


class PagedKVCache:
    def __init__(self, n_layers: int, n_kv_heads: int, head_dim: int, *,
                 n_pages: int = 256, page_size: int = 16,
                 dtype=jnp.bfloat16, f: int = 4, sigma: int = 2048):
        self.L, self.KVH, self.D = n_layers, n_kv_heads, head_dim
        self.P, self.S = n_pages, page_size
        self.k_pages = jnp.zeros((n_layers, n_kv_heads, n_pages, page_size, head_dim), dtype)
        self.v_pages = jnp.zeros((n_layers, n_kv_heads, n_pages, page_size, head_dim), dtype)
        # page 0 is reserved as the null page (masked-out reads land there).
        self.free = list(range(n_pages - 1, 0, -1))
        self.index = NBTreeIndex(f=f, sigma=sigma)
        self.seq_len: dict[int, int] = {}

    # ------------------------------------------------------------- allocation
    def add_sequence(self, seq_id: int, length: int = 0) -> None:
        assert seq_id not in self.seq_len
        self.seq_len[seq_id] = 0
        if length:
            self.extend(seq_id, length)

    def extend(self, seq_id: int, new_len: int) -> list[int]:
        """Ensure pages exist to hold ``new_len`` tokens; returns new pages."""
        have = -(-self.seq_len[seq_id] // self.S) if self.seq_len[seq_id] else 0
        need = -(-new_len // self.S)
        fresh = []
        for b in range(have, need):
            if not self.free:
                raise RuntimeError("KV cache out of pages (preemption needed)")
            fresh.append((b, self.free.pop()))
        if fresh:
            keys = pack_key(seq_id, np.asarray([b for b, _ in fresh]))
            vals = np.asarray([p for _, p in fresh], np.int32)
            self.index.insert_batch(keys, vals)
        self.seq_len[seq_id] = new_len
        return [p for _, p in fresh]

    def free_sequence(self, seq_id: int) -> None:
        n_blocks = -(-self.seq_len[seq_id] // self.S)
        if n_blocks:
            keys = pack_key(seq_id, np.arange(n_blocks))
            present, pages = self.index.query_batch(keys)
            pages = np.asarray(pages)[np.asarray(present)]
            self.free.extend(int(p) for p in pages)
            self.index.delete_batch(keys)
        del self.seq_len[seq_id]

    def maintain(self, budget: int = 2) -> int:
        """Bounded per-step index upkeep (deamortization)."""
        return self.index.maintain(budget)

    # ------------------------------------------------------------ block table
    def block_tables(self, seq_ids, max_pages: int) -> jnp.ndarray:
        """(B, max_pages) int32 physical page table for paged_attention."""
        seq_ids = np.asarray(seq_ids)
        keys = pack_key(seq_ids[:, None], np.arange(max_pages)[None, :]).reshape(-1)
        present, pages = self.index.query_batch(keys)
        table = jnp.where(present, pages, 0).reshape(len(seq_ids), max_pages)
        return table.astype(jnp.int32)

    def seq_lens(self, seq_ids) -> jnp.ndarray:
        return jnp.asarray([self.seq_len[int(s)] for s in np.asarray(seq_ids)],
                           jnp.int32)

    # ---------------------------------------------------------------- writes
    def write_token(self, layer: int, seq_ids, positions, k, v) -> None:
        """Write per-sequence new-token KV: k/v (B, KVH, D) at ``positions``."""
        seq_ids = np.asarray(seq_ids)
        positions = np.asarray(positions)
        blocks = positions // self.S
        slots = positions % self.S
        keys = pack_key(seq_ids, blocks)
        present, pages = self.index.query_batch(keys)
        assert bool(np.asarray(present).all()), "write to unallocated block"
        pages = np.asarray(pages)
        # batched scatter; advanced indices (pages, slots) broadcast to (B,)
        # and land in front, so the update value is exactly k/v (B, KVH, D).
        self.k_pages = self.k_pages.at[layer, :, pages, slots].set(k)
        self.v_pages = self.v_pages.at[layer, :, pages, slots].set(v)

    def layer_pages(self, layer: int):
        return self.k_pages[layer], self.v_pages[layer]
