"""Observability layer tests (DESIGN.md §11).

Covers the shared log-bucket histogram (property-tested against exact
numpy percentiles: bucket-bounded error on p50/p99, exact count/mean/
p100), windowed-metrics rollover (empty windows under clock jumps,
partial-window flush, fluctuation/stall-free scoring), the span tracer's
Chrome trace_event emission (schema validity, ring-buffer bounds,
round-trip through JSON), stall detection + attribution against a
synthetic injected stall, byte-determinism of obs-instrumented open-loop
reports, the disabled-mode zero-overhead contract (obs off == obs absent,
to the byte), the driver histogram facade, and the measured per-kernel
bandwidth table fed by tracer dispatch stats.
"""
import json

import numpy as np
import pytest

from repro.core.engine_api import make_engine
from repro.ingest import FrontendConfig, PoissonArrivals, make_trace, \
    run_open_loop
from repro.obs import (LogBucketHistogram, ObsConfig, SPAN_CATEGORIES,
                       Tracer, WindowedMetrics, attribute_stalls,
                       detect_stalls, validate_chrome_trace)
from repro.obs.metrics import BUCKET_EDGES_S
from repro.workloads import make_workload
from repro.workloads.driver import LatencyHistogram

# ------------------------------------------------------------- histogram


#: adjacent bucket edges are a factor of 10^(1/4) apart, so a
#: bucket-interpolated quantile can be off by at most one bucket width.
_BUCKET_RATIO = float(BUCKET_EDGES_S[1] / BUCKET_EDGES_S[0])


@pytest.mark.parametrize("dist", ["lognormal", "uniform", "bimodal"])
def test_histogram_quantiles_within_one_bucket_of_exact(dist):
    rng = np.random.default_rng(hash(dist) % (1 << 32))
    if dist == "lognormal":
        xs = rng.lognormal(mean=-7.0, sigma=2.0, size=20_000)
    elif dist == "uniform":
        xs = rng.uniform(1e-6, 1e-2, size=20_000)
    else:
        xs = np.concatenate([rng.normal(1e-4, 1e-5, 10_000),
                             rng.normal(5e-2, 5e-3, 10_000)]).clip(1e-9)
    h = LogBucketHistogram()
    h.add_many(xs)
    assert h.count == len(xs)
    assert h.mean == pytest.approx(xs.mean())
    assert h.max == pytest.approx(xs.max())          # p100 exact
    assert h.min == pytest.approx(xs.min())
    assert int(h.counts.sum()) == len(xs)
    # compare against the order statistic ("lower"): the bucket rank is
    # floor(q*(n-1)), and linear interpolation across an empty gap
    # between modes is not within any bucket's reach by construction.
    for q in (0.50, 0.90, 0.99, 0.999):
        exact = float(np.quantile(xs, q, method="lower"))
        est = h.quantile(q)
        assert est <= exact * _BUCKET_RATIO * 1.0001
        assert est >= exact / _BUCKET_RATIO / 1.0001
    # monotone and clamped to the exact extremes
    qs = [h.quantile(q) for q in (0.0, 0.5, 0.9, 0.99, 1.0)]
    assert qs == sorted(qs)
    assert qs[0] == h.min and qs[-1] == h.max


def test_histogram_scalar_add_matches_vector_add():
    xs = [1e-6, 3e-4, 2e-1, 5.0, 1e-12, 1e9]     # includes out-of-range
    a, b = LogBucketHistogram(), LogBucketHistogram()
    for x in xs:
        a.add(x)
    b.add_many(xs)
    assert np.array_equal(a.counts, b.counts)
    assert a.count == b.count and a.total == b.total
    assert a.min == b.min and a.max == b.max


def test_histogram_merge_and_empty():
    h = LogBucketHistogram()
    assert h.quantile(0.5) == 0.0 and h.mean == 0.0
    assert h.summary()["count"] == 0
    a, b = LogBucketHistogram(), LogBucketHistogram()
    a.add_many([1e-4, 2e-4])
    b.add_many([5e-3])
    a.merge(b)
    assert a.count == 3
    assert a.max == pytest.approx(5e-3)
    s = a.summary()
    assert s["p50_s"] <= s["p99_s"] <= s["p100_s"]
    assert sum(s["bucket_counts"]) == 3


def test_driver_latency_histogram_facade():
    rng = np.random.default_rng(7)
    xs = rng.lognormal(-8.0, 1.5, 5000)
    h = LatencyHistogram()
    h.add(xs)
    assert h.count == 5000
    d = h.to_dict()
    assert d["count"] == 5000
    assert d["p100_s"] == pytest.approx(xs.max())     # exact, not bucketed
    assert d["mean_s"] == pytest.approx(xs.mean())
    assert d["p50_s"] <= d["p99_s"] <= d["p100_s"]
    assert sum(d["bucket_counts"]) == d["count"]
    assert len(d["bucket_counts"]) == len(d["bucket_edges_s"]) - 1
    assert "p999_s" not in d                          # per-kind block shape
    assert h.percentile(100) == pytest.approx(xs.max())


# ------------------------------------------------------- windowed metrics


def test_windowed_metrics_clock_jump_emits_empty_windows():
    wm = WindowedMetrics(1.0)
    wm.record(0.5, 1e-3)
    wm.record(4.2, 2e-3)          # jumps over windows 1..3
    out = wm.finish()
    tl = out["timeline"]
    assert out["n_windows"] == 5
    assert [w["ops"] for w in tl] == [1, 0, 0, 0, 1]
    assert out["n_active_windows"] == 2
    # empty windows report zeroed gauges, not stale state
    assert tl[1]["p99_s"] == 0.0 and tl[2]["queue_peak"] == 0
    # window boundaries tile the timeline exactly
    for i, w in enumerate(tl):
        assert w["t_start_s"] == pytest.approx(float(i))
        assert w["t_end_s"] == pytest.approx(float(i + 1))


def test_windowed_metrics_finish_extends_to_t_end():
    wm = WindowedMetrics(0.5)
    wm.record(0.1, 1e-3)
    out = wm.finish(t_end=2.6)
    assert out["n_windows"] == 5    # [0,.5) + 4 empties through t=2.6
    assert [w["ops"] for w in out["timeline"]] == [1, 0, 0, 0, 0]


def test_windowed_metrics_shed_only_window_is_emitted():
    wm = WindowedMetrics(1.0)
    wm.record_shed(0.2, 3)
    out = wm.finish()
    assert out["n_windows"] == 1
    assert out["timeline"][0]["shed"] == 3
    assert out["timeline"][0]["ops"] == 0


def test_windowed_metrics_rejects_bad_width():
    with pytest.raises(ValueError):
        WindowedMetrics(0.0)


def test_fluctuation_score_flat_vs_sawtooth():
    flat, saw = WindowedMetrics(1.0), WindowedMetrics(1.0)
    for i in range(16):
        for _ in range(100):
            flat.record(i + 0.5, 1e-3)
        for _ in range(25 if i % 2 else 175):
            saw.record(i + 0.5, 1e-3)
    f, s = flat.finish(), saw.finish()
    assert f["fluctuation_score"] == pytest.approx(0.0)
    assert s["fluctuation_score"] > 0.5


# ------------------------------------------------------------- stalls


def _mk_windows(p99s, window_s=1.0):
    return [{"t_start_s": i * window_s, "t_end_s": (i + 1) * window_s,
             "ops": 100, "p99_s": p, "p50_s": p / 2} for i, p in
            enumerate(p99s)]


def test_detect_stalls_flags_spike_not_baseline():
    p99s = [1e-3] * 10 + [10e-3] + [1e-3] * 5      # 10x spike at index 10
    stalls = detect_stalls(_mk_windows(p99s), k=4.0)
    assert [s["index"] for s in stalls] == [10]
    assert stalls[0]["baseline_p99_s"] == pytest.approx(1e-3)


def test_detect_stalls_excludes_stalled_windows_from_baseline():
    # consecutive stalls must all be flagged: the first must not drag the
    # trailing median up and mask the rest.
    p99s = [1e-3] * 8 + [20e-3] * 3 + [1e-3] * 4
    stalls = detect_stalls(_mk_windows(p99s), k=4.0)
    assert [s["index"] for s in stalls] == [8, 9, 10]


def test_detect_stalls_min_history_exempts_warmup():
    p99s = [50e-3, 1e-3, 1e-3, 1e-3, 1e-3]
    assert detect_stalls(_mk_windows(p99s), k=4.0, min_history=4) == []


def test_attribute_stalls_picks_dominant_overlap():
    tr = Tracer()
    # window [10, 11): a long cascade span dominates a short commit span
    tr.complete("cascade", "empty", 10.1, 0.7)
    tr.complete("commit", "group", 10.2, 0.1)
    tr.complete("wal_fsync", "append", 9.0, 0.5)   # outside the window
    stalls = [{"index": 10, "t_start_s": 10.0, "t_end_s": 11.0,
               "p99_s": 1.0, "baseline_p99_s": 0.1}]
    out = attribute_stalls(stalls, tr.events())
    assert out[0]["cause"] == "cascade"
    assert out[0]["cause_overlap_s"]["cascade"] == pytest.approx(0.7)
    assert "wal_fsync" not in out[0]["cause_overlap_s"]


def test_attribute_stalls_unknown_when_no_overlap():
    stalls = [{"index": 0, "t_start_s": 0.0, "t_end_s": 1.0,
               "p99_s": 1.0, "baseline_p99_s": 0.1}]
    out = attribute_stalls(stalls, [])
    assert out[0]["cause"] == "unknown"


# ------------------------------------------------------------- tracer


def test_tracer_chrome_json_roundtrip(tmp_path):
    tr = Tracer()
    tr.complete("commit", "group_commit", 0.001, 0.0005, ops=64)
    tr.complete("wal_fsync", "append_commit", 0.0012, 0.0001, lsn=1)
    tr.instant("shed", "queue_full", 0.002, n=3)
    path = tmp_path / "trace.json"
    tr.save(str(path))
    obj = json.loads(path.read_text())
    assert validate_chrome_trace(obj) == []
    evs = obj["traceEvents"]
    # metadata rows name one process per span category
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} >= {"commit", "wal_fsync",
                                                "shed"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 2
    assert xs[0]["ts"] == pytest.approx(1000.0)       # microseconds
    assert xs[0]["dur"] == pytest.approx(500.0)
    assert xs[0]["args"]["ops"] == 64
    insts = [e for e in evs if e["ph"] == "i"]
    assert len(insts) == 1 and insts[0]["s"] == "g"


def test_tracer_ring_buffer_bounds_memory():
    tr = Tracer(capacity=16)
    for i in range(100):
        tr.complete("commit", "c", i * 1e-3, 1e-4)
    assert len(tr) == 16
    assert tr.dropped_events == 84
    # survivors are the newest events
    ts = [e["ts"] for e in tr.events()]
    assert ts == sorted(ts) and ts[0] == pytest.approx(84_000.0)


def test_tracer_disabled_records_nothing():
    tr = Tracer(enabled=False)
    tr.complete("commit", "c", 0.0, 1e-3)
    tr.instant("shed", "s", 0.0)
    assert len(tr) == 0 and tr.dropped_events == 0


def test_validate_chrome_trace_catches_malformed():
    assert validate_chrome_trace({"traceEvents": "nope"})
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "X", "name": "x", "ts": 0}]})  # no dur
    assert validate_chrome_trace(
        {"traceEvents": [{"ph": "i", "name": "x", "ts": "zero"}]})
    assert validate_chrome_trace({"traceEvents": []}) == []


def test_span_categories_cover_serving_pipeline():
    assert {"commit", "wal_fsync", "flush_unit", "cascade", "shard_split",
            "checkpoint", "recovery", "shed",
            "tenant_throttle"} <= set(SPAN_CATEGORIES)


# ------------------------------------------- end-to-end open-loop contract


def _open_loop_report(obs):
    wl = make_workload("insert-heavy", key_space=1 << 16, n_ops=2048,
                       preload=256, batch_size=128, seed=3)
    trace = make_trace(wl, PoissonArrivals(150_000.0))
    eng = make_engine("nbtree", f=3, sigma=1024)
    cfg = FrontendConfig(max_queue=256, commit_ops=64, linger_s=2e-4)
    return run_open_loop(eng, trace, config=cfg, obs=obs)


def test_open_loop_obs_deterministic_across_runs():
    a = _open_loop_report(ObsConfig(window_s=0.005))
    b = _open_loop_report(ObsConfig(window_s=0.005))
    assert json.dumps(a["open_loop"]["obs"], sort_keys=True) == \
        json.dumps(b["open_loop"]["obs"], sort_keys=True)
    ob = a["open_loop"]["obs"]
    assert ob["n_windows"] >= 2
    assert ob["trace"]["events"] > 0
    assert "commit" in ob["trace"]["categories"]


def test_open_loop_disabled_obs_identical_to_absent():
    base = _open_loop_report(None)
    off = _open_loop_report(ObsConfig(enabled=False))
    assert json.dumps(base, sort_keys=True, default=str) == \
        json.dumps(off, sort_keys=True, default=str)
    assert "obs" not in base["open_loop"]


def test_open_loop_obs_windows_cover_trace_duration():
    rep = _open_loop_report(ObsConfig(window_s=0.002))
    ob = rep["open_loop"]["obs"]
    tl = ob["timeline"]
    done = sum(w["ops"] for w in tl)
    shed = sum(w["shed"] for w in tl)
    assert done == rep["open_loop"]["n_done"]
    assert shed == rep["open_loop"]["n_shed"]
    # windows tile [0, t_last) with no gaps
    for prev, nxt in zip(tl, tl[1:]):
        assert nxt["t_start_s"] == pytest.approx(prev["t_end_s"])


# ------------------------------------------------------------- roofline


def test_measured_kernel_table_from_dispatch_stats():
    from repro.roofline.analysis import measured_kernel_table

    stats = {
        "_flush_impl": {"count": 4, "wall_s": 2.0, "bytes": 8_190_000_000},
        "_insert_impl": {"count": 100, "wall_s": 0.1, "bytes": 1_000_000},
    }
    rows = measured_kernel_table(stats, peak_bw=819e9)
    assert [r["kernel"] for r in rows] == ["_flush_impl", "_insert_impl"]
    assert rows[0]["achieved_gb_s"] == pytest.approx(4.095)
    assert rows[0]["peak_frac"] == pytest.approx(0.005)
    assert rows[1]["count"] == 100
    zero = measured_kernel_table({"k": {"count": 1, "wall_s": 0.0,
                                        "bytes": 10}})
    assert zero[0]["achieved_gb_s"] == 0.0
