"""Durability subsystem tests (DESIGN.md §9).

Covers the WAL (record round trips, segment rotation, garbage-tail
truncation, checkpoint GC, LSN continuity across reopen), the full crash
matrix through the durable ingest frontend (kill at every
:class:`~repro.wal.faults.CrashPoint`, recover, differential-check against
a sorted-dict oracle of exactly the acked prefix), the checkpointer
atomicity protocol (roll-forward vs delete of ``.tmp_step_*``, async-save
reader safety, real exceptions on corrupt restores, bf16 round trip), the
``dump_live`` snapshot primitive across engine tiers, and the
HeartbeatMonitor declare-once/revive fix.

The two invariants every crash-matrix case asserts:

* **zero lost acked writes** — every op whose group-commit fsync returned
  before the kill is present in the recovered engine;
* **zero resurrected unacked writes** — no op whose fsync did *not*
  return is present (torn WAL tails are truncated on open).
"""
import os

import numpy as np
import pytest

from repro.core.engine_api import OpBatch, OpKind, make_engine
from repro.ingest import (DurabilityConfig, FrontendConfig, IngestFrontend,
                          PoissonArrivals, make_trace, run_open_loop)
from repro.wal import (CrashPoint, FaultInjector, SimulatedCrash,
                       WriteAheadLog, recover)
from repro.workloads import make_workload

KEYS = np.uint64
VALS = np.int64


def _commit(i, n=8):
    """Deterministic synthetic commit #i: n inserts with key = i*100 + j."""
    keys = np.arange(i * 100, i * 100 + n, dtype=KEYS)
    kinds = np.full(n, int(OpKind.INSERT), np.int8)
    return kinds, keys, keys.astype(VALS)


# ------------------------------------------------------------------------ wal
def test_wal_roundtrip_rotation_and_reopen(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_bytes=4096)
    for i in range(1, 31):
        lsn, nbytes = wal.append_commit(*_commit(i))
        assert lsn == i and nbytes > 0
    assert wal.last_lsn == 30
    assert wal.n_segments > 1, "4 KiB segments must have rotated"
    recs = list(wal.replay())
    assert [r.lsn for r in recs] == list(range(1, 31))
    k, kk, vv = _commit(7)
    assert np.array_equal(recs[6].keys, kk)
    assert np.array_equal(recs[6].vals, vv)
    assert np.array_equal(recs[6].kinds, k)
    # replay after an LSN yields exactly the strict tail
    assert [r.lsn for r in wal.replay(after_lsn=25)] == [26, 27, 28, 29, 30]
    wal.close()

    # reopen: LSN chain continues where it left off
    wal2 = WriteAheadLog(str(tmp_path), segment_bytes=4096)
    assert wal2.last_lsn == 30
    assert wal2.truncated_tail_bytes == 0
    lsn, _ = wal2.append_commit(*_commit(31))
    assert lsn == 31
    wal2.close()


def test_wal_garbage_tail_truncated_on_open(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_bytes=1 << 16)
    for i in range(1, 6):
        wal.append_commit(*_commit(i))
    wal.close()
    seg = sorted(os.listdir(tmp_path))[-1]
    with open(tmp_path / seg, "ab") as f:     # a torn, never-fsynced commit
        f.write(b"\x57\x41\x4c\x31 torn garbage bytes")
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.last_lsn == 5, "valid prefix must survive"
    assert wal2.truncated_tail_bytes > 0
    assert [r.lsn for r in wal2.replay()] == [1, 2, 3, 4, 5]
    # the file itself was physically truncated, not just skipped
    wal2.close()
    assert WriteAheadLog(str(tmp_path)).truncated_tail_bytes == 0


def test_wal_corrupt_record_drops_suffix(tmp_path):
    """A flipped byte mid-log invalidates that record AND everything after
    (the LSN chain can't be trusted past a corrupt link)."""
    wal = WriteAheadLog(str(tmp_path), segment_bytes=1 << 16)
    offsets = [0]
    for i in range(1, 6):
        _, nbytes = wal.append_commit(*_commit(i))
        offsets.append(offsets[-1] + nbytes)
    wal.close()
    seg = sorted(os.listdir(tmp_path))[0]
    with open(tmp_path / seg, "r+b") as f:    # corrupt record 3's payload
        f.seek(offsets[2] + 20)
        b = f.read(1)
        f.seek(offsets[2] + 20)
        f.write(bytes([b[0] ^ 0xFF]))
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.last_lsn == 2
    assert [r.lsn for r in wal2.replay()] == [1, 2]
    wal2.close()


def test_wal_truncate_upto_keeps_newest_segment(tmp_path):
    wal = WriteAheadLog(str(tmp_path), segment_bytes=4096)
    for i in range(1, 91):
        wal.append_commit(*_commit(i))
    nseg = wal.n_segments
    assert nseg > 2
    removed = wal.truncate_upto(wal.last_lsn)
    assert removed == nseg - 1, "everything but the open segment is covered"
    assert wal.n_segments == 1
    # the kept segment still carries the LSN counter across a reopen
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path), segment_bytes=4096)
    assert wal2.last_lsn == 90
    wal2.close()


def test_wal_torn_append_via_injector(tmp_path):
    inj = FaultInjector(CrashPoint.AFTER_WAL_APPEND, at_occurrence=3)
    wal = WriteAheadLog(str(tmp_path), segment_bytes=1 << 16, injector=inj)
    wal.append_commit(*_commit(1))
    wal.append_commit(*_commit(2))
    with pytest.raises(SimulatedCrash):
        wal.append_commit(*_commit(3))        # written, torn, never fsynced
    assert inj.fired
    wal2 = WriteAheadLog(str(tmp_path))
    assert wal2.last_lsn == 2, "the torn record must not resurrect"
    assert wal2.truncated_tail_bytes > 0
    wal2.close()


# --------------------------------------------------------------- crash matrix
def _durable_trace(n_ops=1200, seed=5):
    wl = make_workload("delete-churn", key_space=1 << 14, n_ops=n_ops,
                       preload=256, batch_size=128, seed=seed)
    return make_trace(wl, PoissonArrivals(50_000.0))


def _durable_frontend(directory, injector=None, ckpt_every=4):
    eng = make_engine("nbtree", f=3, sigma=64)
    fe = IngestFrontend(
        eng, FrontendConfig(max_queue=2048, commit_ops=32, linger_s=5e-4),
        durability=DurabilityConfig(str(directory), segment_bytes=4096,
                                    checkpoint_every_commits=ckpt_every),
        injector=injector)
    return eng, fe


def _oracle(trace, acked):
    """Sorted-dict ground truth: preload then every *acked* commit in LSN
    order (an op is acked iff its commit's fsync returned)."""
    d = {}
    for k, v in zip(trace.preload.keys.tolist(), trace.preload.vals.tolist()):
        d[int(k)] = int(v)
    for _lsn, kinds, keys, vals in acked:
        for kk, k, v in zip(kinds.tolist(), keys.tolist(), vals.tolist()):
            if kk == int(OpKind.INSERT):
                d[int(k)] = int(v)
            else:
                d.pop(int(k), None)
    return sorted(d.items())


def _assert_recovered_equals_oracle(directory, trace, fe):
    rr = recover(str(directory),
                 lambda: make_engine("nbtree", f=3, sigma=64))
    want = _oracle(trace, fe.acked)
    rk, rv = rr.engine.dump_live()
    assert rk.tolist() == [k for k, _ in want], "lost or resurrected keys"
    assert rv.tolist() == [v for _, v in want], "stale values after recovery"
    assert rr.last_lsn == fe.last_acked_lsn
    assert rr.engine.stats().applied_lsn == fe.last_acked_lsn
    return rr


# occurrence picked so the kill lands mid-run: WAL points fire per commit
# (4th commit => 3 acked survivors); checkpoint points fire per snapshot
# (occurrence 1 is the preload snapshot, 2 the first periodic one).
_MATRIX = [
    (CrashPoint.BEFORE_WAL_APPEND, 4),
    (CrashPoint.AFTER_WAL_APPEND, 4),      # torn tail: durable-prefix only
    (CrashPoint.AFTER_WAL_FSYNC, 4),       # acked but never applied
    (CrashPoint.AFTER_APPLY, 4),
    (CrashPoint.MID_CASCADE, 3),           # index mid-restructure
    (CrashPoint.MID_CHECKPOINT, 2),        # leaves written, no manifest
    (CrashPoint.BEFORE_CHECKPOINT_RENAME, 2),
    (CrashPoint.AFTER_CHECKPOINT, 2),      # snapshot done, WAL not truncated
]


@pytest.mark.parametrize("point,occurrence", _MATRIX,
                         ids=[p.value for p, _ in _MATRIX])
def test_crash_matrix_recovers_exact_acked_prefix(tmp_path, point, occurrence):
    trace = _durable_trace()
    inj = FaultInjector(point, at_occurrence=occurrence)
    _, fe = _durable_frontend(tmp_path, injector=inj)
    with pytest.raises(SimulatedCrash) as exc:
        fe.run(trace)
    assert inj.fired, f"{point.value} was never exercised"
    assert exc.value.point is point
    _assert_recovered_equals_oracle(tmp_path, trace, fe)


def test_crash_late_in_run_replays_only_the_tail(tmp_path):
    """A late kill recovers from a periodic snapshot + short WAL tail, not
    from LSN 1 — the checkpoint actually bounds replay."""
    trace = _durable_trace(n_ops=1600)
    inj = FaultInjector(CrashPoint.AFTER_APPLY, at_occurrence=30)
    _, fe = _durable_frontend(tmp_path, injector=inj, ckpt_every=8)
    with pytest.raises(SimulatedCrash):
        fe.run(trace)
    assert inj.fired
    rr = _assert_recovered_equals_oracle(tmp_path, trace, fe)
    assert rr.snapshot_lsn > 0
    assert rr.replayed_commits < len(fe.acked)
    assert rr.snapshot_lsn + rr.replayed_commits == rr.last_lsn


def test_double_crash_recovery_is_stable(tmp_path):
    """recover() is read-only apart from garbage truncation: running it
    twice (crash during recovery, then again) yields the same state."""
    trace = _durable_trace()
    inj = FaultInjector(CrashPoint.AFTER_WAL_APPEND, at_occurrence=6)
    _, fe = _durable_frontend(tmp_path, injector=inj)
    with pytest.raises(SimulatedCrash):
        fe.run(trace)
    r1 = _assert_recovered_equals_oracle(tmp_path, trace, fe)
    r2 = _assert_recovered_equals_oracle(tmp_path, trace, fe)
    assert r2.truncated_tail_bytes == 0, "first open already truncated"
    assert r1.last_lsn == r2.last_lsn


# --------------------------------------------------------- durable, no crash
def test_durable_run_report_and_recovery(tmp_path):
    trace = _durable_trace()
    eng, fe = _durable_frontend(tmp_path, ckpt_every=8)
    rep = fe.run(trace)
    dur = rep["durability"]
    assert dur["acked_commits"] == len(fe.acked) > 0
    assert dur["last_acked_lsn"] == fe.last_acked_lsn == dur["wal"]["last_lsn"]
    assert dur["wal"]["syncs"] == dur["wal"]["appends"] == dur["acked_commits"]
    assert dur["wal"]["service_s_total"] > 0.0
    assert dur["checkpoints"]["taken"] > 0
    # recovery from the surviving directory == the live engine, bit for bit
    rr = _assert_recovered_equals_oracle(tmp_path, trace, fe)
    ek, ev = eng.dump_live()
    rk, rv = rr.engine.dump_live()
    assert np.array_equal(ek, rk) and np.array_equal(ev, rv)


def test_wal_overhead_and_state_parity_with_wal_off(tmp_path):
    """Durability never changes answers, only cost: same trace with WAL
    on/off lands the same live table, and WAL-on charges strictly more
    service time (the fsync is on the clock)."""
    on_eng, fe = _durable_frontend(tmp_path, ckpt_every=0)
    rep_on = fe.run(_durable_trace(seed=9))
    off_eng = make_engine("nbtree", f=3, sigma=64)
    rep_off = IngestFrontend(
        off_eng, FrontendConfig(max_queue=2048, commit_ops=32,
                                linger_s=5e-4)).run(_durable_trace(seed=9))
    assert rep_on["n_shed"] == rep_off["n_shed"] == 0
    ok, ov = on_eng.dump_live()
    fk, fv = off_eng.dump_live()
    assert np.array_equal(ok, fk) and np.array_equal(ov, fv)
    assert rep_on["server"]["service_s"] > rep_off["server"]["service_s"]


def test_durable_report_deterministic(tmp_path):
    """Sim-tier durable runs are pure functions of (trace, config): two
    runs differ only in the directory path they were given."""
    import json

    def one(sub):
        eng = make_engine("nbtree", f=3, sigma=64)
        rep = run_open_loop(
            eng, _durable_trace(seed=3),
            config=FrontendConfig(max_queue=2048, commit_ops=32),
            durability=DurabilityConfig(str(tmp_path / sub),
                                        checkpoint_every_commits=8))
        rep["open_loop"]["durability"]["config"]["directory"] = "<dir>"
        return json.dumps(rep, sort_keys=True)

    assert one("a") == one("b")


def test_wal_only_recovery_without_checkpoints(tmp_path):
    """checkpoint_every_commits=0 still recovers every acked write (preload
    is snapshotted once; the WAL tail does the rest)."""
    trace = _durable_trace()
    inj = FaultInjector(CrashPoint.AFTER_WAL_FSYNC, at_occurrence=12)
    _, fe = _durable_frontend(tmp_path, injector=inj, ckpt_every=0)
    with pytest.raises(SimulatedCrash):
        fe.run(trace)
    rr = _assert_recovered_equals_oracle(tmp_path, trace, fe)
    assert rr.replayed_commits == len(fe.acked), "no periodic snapshot: " \
        "every acked commit must come back via replay"


# ------------------------------------------------------------------ dump_live
@pytest.mark.parametrize("name,kw", [
    ("nbtree", dict(f=3, sigma=128)),
    ("lsm", dict(mem_pairs=128)),
    ("btree", dict()),
    ("sharded:nbtree", dict(shards=2, f=3, sigma=128)),
    ("jax-nbtree", dict(f=4, sigma=64, max_nodes=64)),
])
def test_dump_live_conformance(name, kw):
    """dump_live is the snapshot primitive: key-sorted live table with
    deletes applied, identical across tiers, and cost-free."""
    rng = np.random.default_rng(1)
    keys = rng.choice(np.arange(1, 4096, dtype=KEYS), size=256, replace=False)
    eng = make_engine(name, **kw)
    eng.apply(OpBatch.inserts(keys, keys.astype(VALS)))
    eng.apply(OpBatch.deletes(keys[:64]))
    eng.drain()
    io_before = eng.io_time_s()
    dk, dv = eng.dump_live()
    assert eng.io_time_s() == io_before, "snapshot must not charge sim I/O"
    want = np.sort(keys[64:])
    assert np.array_equal(dk, want)
    assert np.array_equal(dv, want.astype(VALS))
    assert dk.dtype == KEYS and dv.dtype == VALS
    assert len(dk) == eng.count_live()


def test_note_applied_monotone():
    eng = make_engine("nbtree", f=3, sigma=128)
    assert eng.stats().applied_lsn == 0
    eng.note_applied(7)
    eng.note_applied(3)           # stale LSNs never move the watermark back
    assert eng.stats().applied_lsn == 7


# --------------------------------------------------------------- checkpointer
def _tree(seed=0, n=64):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": rng.standard_normal((8, n)).astype(np.float32),
                      "b": rng.standard_normal((n,)).astype(np.float32)}}


def test_checkpointer_crash_before_manifest_is_invisible(tmp_path):
    """MID_CHECKPOINT kill: leaves on disk, manifest not yet written — the
    half-checkpoint must be deleted on reopen, never restored."""
    from repro.checkpoint.checkpointer import Checkpointer

    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))
    inj = FaultInjector(CrashPoint.MID_CHECKPOINT, at_occurrence=1)
    ck2 = Checkpointer(str(tmp_path), injector=inj)
    with pytest.raises(SimulatedCrash):
        ck2.save(2, _tree(2))
    assert os.path.isdir(tmp_path / ".tmp_step_2")
    ck3 = Checkpointer(str(tmp_path))
    assert not os.path.isdir(tmp_path / ".tmp_step_2"), "unprovable tmp kept"
    assert ck3.latest_step() == 1
    got = ck3.restore(1, _tree(1))
    np.testing.assert_array_equal(np.asarray(got["layer"]["w"]),
                                  _tree(1)["layer"]["w"])


def test_checkpointer_crash_after_manifest_rolls_forward(tmp_path):
    """BEFORE_CHECKPOINT_RENAME kill: manifest fsynced, dir still .tmp —
    reopen must finish the rename and the step must restore."""
    from repro.checkpoint.checkpointer import Checkpointer

    inj = FaultInjector(CrashPoint.BEFORE_CHECKPOINT_RENAME, at_occurrence=1)
    ck = Checkpointer(str(tmp_path), injector=inj)
    with pytest.raises(SimulatedCrash):
        ck.save(3, _tree(3))
    assert os.path.isdir(tmp_path / ".tmp_step_3")
    assert not os.path.isdir(tmp_path / "step_3")
    ck2 = Checkpointer(str(tmp_path))
    assert os.path.isdir(tmp_path / "step_3"), "provable tmp must roll forward"
    assert ck2.latest_step() == 3
    got = ck2.restore(3, _tree(3))
    np.testing.assert_array_equal(np.asarray(got["layer"]["b"]),
                                  _tree(3)["layer"]["b"])


def test_checkpointer_async_save_readers_wait(tmp_path):
    """blocking=False: latest_step/restore right after save must see the
    finished checkpoint (readers join the writer thread), and a second
    save must not race the first."""
    from repro.checkpoint.checkpointer import Checkpointer

    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1), blocking=False)
    assert ck.latest_step() == 1            # waits for the daemon writer
    ck.save(2, _tree(2), blocking=False)
    got = ck.restore(2, _tree(2))           # waits again
    np.testing.assert_array_equal(np.asarray(got["layer"]["w"]),
                                  _tree(2)["layer"]["w"])
    # a fresh process sees both steps via the manifest
    ck2 = Checkpointer(str(tmp_path))
    assert ck2.known_steps >= {1, 2}


def test_checkpointer_restore_raises_real_exceptions(tmp_path):
    """Validation failures are CheckpointError even under ``python -O``
    (bare asserts would vanish)."""
    from repro.checkpoint.checkpointer import CheckpointError, Checkpointer

    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1))
    with pytest.raises(CheckpointError, match="manifest missing"):
        ck.restore(99, _tree(1))
    bad_shape = {"layer": {"w": np.zeros((8, 65), np.float32),
                           "b": np.zeros((64,), np.float32)}}
    with pytest.raises(CheckpointError, match="shape mismatch"):
        ck.restore(1, bad_shape)
    os.unlink(tmp_path / "step_1" / "layer.b.npy")
    with pytest.raises(CheckpointError, match="leaf file missing"):
        ck.restore(1, _tree(1))


def test_checkpointer_bf16_round_trip(tmp_path):
    import jax.numpy as jnp

    from repro.checkpoint.checkpointer import Checkpointer

    tree = {"p": jnp.arange(32, dtype=jnp.bfloat16) / 7}
    ck = Checkpointer(str(tmp_path))
    ck.save(4, tree)
    got = ck.restore(4, tree)
    assert got["p"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["p"], np.float32),
                                  np.asarray(tree["p"], np.float32))


def test_engine_checkpointer_snapshot_round_trip(tmp_path):
    from repro.checkpoint.checkpointer import (CheckpointError,
                                               EngineCheckpointer)

    ck = EngineCheckpointer(str(tmp_path))
    assert ck.load_latest_snapshot() is None
    keys = np.arange(10, 50, dtype=KEYS)
    ck.save_snapshot(17, keys, keys.astype(VALS))
    lsn, rk, rv = ck.load_latest_snapshot()
    assert lsn == 17
    assert np.array_equal(rk, keys) and np.array_equal(rv, keys.astype(VALS))
    with pytest.raises(CheckpointError, match="parallel"):
        ck.save_snapshot(18, keys, keys[:-1].astype(VALS))


# ---------------------------------------------------------- heartbeat monitor
def test_heartbeat_declare_once_and_revive():
    from repro.distributed.fault_tolerance import HeartbeatMonitor

    mon = HeartbeatMonitor([0, 1, 2], timeout_steps=3)
    for s in range(1, 4):
        mon.beat(0, s)
        mon.beat(1, s)            # host 2 never beats
    assert mon.advance(4) == [2]
    assert mon.advance(5) == [], "a dead host is declared exactly once"
    assert mon.beat(2, 5) is False, "late beats must not resurrect"
    mon.beat(0, 7)
    mon.beat(1, 7)
    assert mon.advance(8) == [], "ignored beat didn't reset the clock either"
    mon.revive(2)
    assert 2 not in mon.dead
    assert mon.beat(2, 9) is True
    mon.beat(0, 10)
    mon.beat(1, 10)
    assert mon.advance(10) == [], "revived host has a fresh timeout window"
    # a revived host that goes silent again is re-declared (once)
    mon.beat(0, 12)
    mon.beat(1, 12)
    assert mon.advance(13) == [2]
    assert mon.advance(14) == []


# ------------------------------------------------------- restart after crash
def _resume_trace(n_ops=600, seed=9):
    """Second serving window after a restart: no preload (the data is
    already in the recovered engine), same keyspace so the two windows'
    write sets genuinely overlap."""
    wl = make_workload("delete-churn", key_space=1 << 14, n_ops=n_ops,
                       preload=0, batch_size=128, seed=seed)
    return make_trace(wl, PoissonArrivals(50_000.0))


def _resume_frontend(directory, engine, ckpt_every=4):
    """Fresh frontend over an already-recovered engine and the SAME durable
    directory — the restart path."""
    return IngestFrontend(
        engine, FrontendConfig(max_queue=2048, commit_ops=32, linger_s=5e-4),
        durability=DurabilityConfig(str(directory), segment_bytes=4096,
                                    checkpoint_every_commits=ckpt_every))


def test_restart_after_crash_resumes_lsn_chain(tmp_path):
    """Crash mid-run, recover, serve a second trace through a fresh
    frontend on the same directory: the first resumed commit continues the
    LSN chain exactly where the durable watermark left it (no reuse, no
    gap), and a final recovery equals the oracle of BOTH acked prefixes —
    no acked write lost, none applied twice."""
    trace1 = _durable_trace()
    inj = FaultInjector(CrashPoint.AFTER_WAL_FSYNC, at_occurrence=9)
    _, fe1 = _durable_frontend(tmp_path, injector=inj)
    with pytest.raises(SimulatedCrash):
        fe1.run(trace1)
    assert inj.fired and len(fe1.acked) == 9

    rr = _assert_recovered_equals_oracle(tmp_path, trace1, fe1)

    fe2 = _resume_frontend(tmp_path, rr.engine)
    assert fe2.last_acked_lsn == rr.last_lsn, \
        "a reopened frontend must adopt the durable watermark, not claim 0"
    trace2 = _resume_trace()
    rep = fe2.run(trace2)
    assert fe2.acked[0][0] == rr.last_lsn + 1, "LSN continuity across restart"
    lsns = [a[0] for a in fe2.acked]
    assert lsns == list(range(rr.last_lsn + 1, rr.last_lsn + 1 + len(lsns)))
    assert rep["durability"]["last_acked_lsn"] == fe2.last_acked_lsn

    # final recovery sees one continuous history: preload + acked1 + acked2.
    rr2 = recover(str(tmp_path), lambda: make_engine("nbtree", f=3, sigma=64))
    want = _oracle(trace1, list(fe1.acked) + list(fe2.acked))
    rk, rv = rr2.engine.dump_live()
    assert list(zip(rk.tolist(), rv.tolist())) == want, \
        "restart lost or double-applied acked writes"
    assert rr2.last_lsn == fe2.last_acked_lsn


def test_restart_after_clean_shutdown_resumes_lsn_chain(tmp_path):
    """Same resume path without a crash: run to completion, reopen, serve
    more — the clean-shutdown boundary is just a crash with an empty
    replay tail."""
    trace1 = _durable_trace(n_ops=500)
    _, fe1 = _durable_frontend(tmp_path)
    fe1.run(trace1)
    assert fe1.acked, "run must have acked commits"

    rr = recover(str(tmp_path), lambda: make_engine("nbtree", f=3, sigma=64))
    fe2 = _resume_frontend(tmp_path, rr.engine)
    trace2 = _resume_trace(n_ops=400, seed=11)
    fe2.run(trace2)
    assert fe2.acked[0][0] == fe1.last_acked_lsn + 1

    rr2 = recover(str(tmp_path), lambda: make_engine("nbtree", f=3, sigma=64))
    want = _oracle(trace1, list(fe1.acked) + list(fe2.acked))
    rk, rv = rr2.engine.dump_live()
    assert list(zip(rk.tolist(), rv.tolist())) == want
