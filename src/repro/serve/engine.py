"""Continuous-batching serving engine over the NB-tree paged KV cache.

The engine demonstrates the paper's index as the allocator/indexing layer of
an LM server:

  * admission: waiting requests claim decode slots as sequences finish;
  * prefill: full-sequence forward (serve/steps.make_prefill_step) writes
    per-position KV into *pages* through the NB-tree block index;
  * decode: every step builds block tables by batched NB-tree queries and
    attends with the paged_attention Pallas kernel;
  * upkeep: ``cache.maintain(budget)`` runs each step — bounded index work
    per step (deamortization), so no request ever observes an allocator
    stall (the serving analogue of the paper's worst-case insertion bound).

The paged decode path supports attention-backbone archs (dense/swa blocks);
recurrent archs carry O(1) state and use the contiguous decode path — the
index still tracks their state slots.  CPU-scale: reduced configs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..models import transformer as T
from ..models.layers import apply_norm, apply_rope, mlp, rope_angles
from .kv_cache import PagedKVCache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class PagedDecoder:
    """Single-token decode for dense/swa stacks over paged KV."""

    def __init__(self, cfg, params, cache: PagedKVCache):
        assert all(k in ("dense", "swa") for k, _ in cfg.segments), (
            "paged decode path supports attention backbones")
        self.cfg, self.params, self.cache = cfg, params, cache
        # flatten scanned segments into per-layer param list (host-side,
        # engine scale) so each layer can address its own pages.
        self.layer_params = []
        self.layer_kinds = []
        for i, (kind, count) in enumerate(cfg.segments):
            seg = params[f"seg{i}"]
            for j in range(count):
                self.layer_params.append(jax.tree.map(lambda t: t[j], seg))
                self.layer_kinds.append(kind)

    def prefill(self, seq_ids, tokens):
        """tokens (B, S) — runs forward, writes all KV into pages."""
        cfg = self.cfg
        B, S = tokens.shape
        for sid in np.asarray(seq_ids):
            self.cache.extend(int(sid), S)
        logits, _aux, kv_cache = T.forward(self.params, cfg, tokens=tokens,
                                           build_cache_len=S, last_logit_only=True)
        # copy contiguous prefill KV into pages, page-aligned chunks.
        li = 0
        for i, (kind, count) in enumerate(cfg.segments):
            seg_cache = kv_cache[f"seg{i}"]
            for j in range(count):
                k = np.asarray(seg_cache["k"][j], dtype=np.float32)  # (B,S,KVH,D)
                v = np.asarray(seg_cache["v"][j], dtype=np.float32)
                for pos in range(S):
                    self.cache.write_token(
                        li, seq_ids, np.full(B, pos),
                        jnp.asarray(k[:, pos]), jnp.asarray(v[:, pos]))
                li += 1
        self.cache.maintain(4)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

    def decode(self, seq_ids, tokens, position: int):
        """One decode step for all sequences at the same position."""
        cfg = self.cfg
        B = tokens.shape[0]
        for sid in np.asarray(seq_ids):
            self.cache.extend(int(sid), position + 1)
        self.cache.maintain(2)
        max_pages = -(-(position + 1) // self.cache.S)
        tables = self.cache.block_tables(seq_ids, max_pages)
        lens = jnp.full((B,), position + 1, jnp.int32)

        x = self.params["embed"][tokens][:, None, :]
        positions = jnp.full((B, 1), position, jnp.int32)
        hd = cfg.resolved_head_dim
        for li, (p, kind) in enumerate(zip(self.layer_params, self.layer_kinds)):
            h = apply_norm(x, p["norm1"], cfg.norm_kind, cfg.norm_eps)
            q = (h @ p["attn"]["wq"]).reshape(B, 1, cfg.n_heads, hd)
            k = (h @ p["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
            v = (h @ p["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
            if cfg.qk_norm:
                from ..models.layers import rms_norm
                q = rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
                k = rms_norm(k, p["attn"]["k_norm"], cfg.norm_eps)
            cos, sin = rope_angles(positions, hd, cfg.rope_base)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
            self.cache.write_token(li, seq_ids, np.full(B, position),
                                   k[:, 0], v[:, 0])
            kp, vp = self.cache.layer_pages(li)
            g = cfg.n_heads // cfg.n_kv_heads
            qh = q[:, 0].reshape(B, cfg.n_kv_heads, g, hd)
            out = ops.paged_attention(qh, kp, vp, tables, lens)
            a = out.reshape(B, 1, cfg.n_heads * hd) @ p["attn"]["wo"]
            x = x + a
            h2 = apply_norm(x, p["norm2"], cfg.norm_kind, cfg.norm_eps)
            x = x + mlp(h2, p["mlp"], cfg.mlp_kind)
        x = apply_norm(x, self.params["final_norm"], cfg.norm_kind, cfg.norm_eps)
        unembed = (self.params["embed"].T if cfg.tie_embeddings
                   else self.params["unembed"])
        logits = (x[:, 0] @ unembed).astype(jnp.float32)
        return jnp.argmax(logits, -1).astype(jnp.int32)


class Engine:
    """Minimal continuous-batching loop (batched requests, CPU scale)."""

    def __init__(self, cfg, params, *, max_batch: int = 4, n_pages: int = 512,
                 page_size: int = 16):
        self.cfg, self.params = cfg, params
        self.max_batch = max_batch
        self.cache = PagedKVCache(cfg.n_layers, cfg.n_kv_heads,
                                  cfg.resolved_head_dim,
                                  n_pages=n_pages, page_size=page_size,
                                  dtype=jnp.float32)
        self.decoder = PagedDecoder(cfg, params, self.cache)
        self._next_sid = 0

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve a request list to completion (same-length prompts batched)."""
        queue = list(requests)
        while queue:
            batch = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            sids = []
            for r in batch:
                sid = self._next_sid
                self._next_sid += 1
                self.cache.add_sequence(sid)
                sids.append(sid)
            toks = jnp.asarray([r.prompt for r in batch], jnp.int32)
            S = toks.shape[1]
            nxt = self.decoder.prefill(np.asarray(sids), toks)
            for r, t in zip(batch, np.asarray(nxt)):
                r.out.append(int(t))
            steps = max(r.max_new_tokens for r in batch) - 1
            for s in range(steps):
                nxt = self.decoder.decode(np.asarray(sids), nxt, S + s)
                for r, t in zip(batch, np.asarray(nxt)):
                    if len(r.out) < r.max_new_tokens:
                        r.out.append(int(t))
            for r, sid in zip(batch, sids):
                r.done = True
                self.cache.free_sequence(sid)
        return requests
