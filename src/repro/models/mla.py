"""Multi-head Latent Attention (MiniCPM3-4B / DeepSeek-V2 family).

Queries go through a low-rank bottleneck (q_lora_rank); keys/values are
compressed into a small latent c_kv (kv_lora_rank) plus a shared rotary key
slice — the decode cache stores only (c_kv, k_rope), the architecture's
whole point: cache bytes per token = kv_lora_rank + qk_rope_head_dim
instead of 2 * H * head_dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .blockwise_attn import blockwise_sdpa, should_use_blockwise
from .layers import _dense_init, apply_rope, rms_norm, rope_angles


def mla_params(key, cfg, dtype):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wq_down": _dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_up": _dense_init(ks[1], (m.q_lora_rank, H * qk_dim), dtype,
                             fan_in=m.q_lora_rank),
        "wkv_down": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wk_up": _dense_init(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype,
                             fan_in=m.kv_lora_rank),
        "wv_up": _dense_init(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype,
                             fan_in=m.kv_lora_rank),
        "wo": _dense_init(ks[5], (H * m.v_head_dim, d), dtype, fan_in=H * m.v_head_dim),
    }


def mla_attention(x, p, cfg, *, positions, cache=None, cache_index=None):
    """Returns (out, new_cache); cache = dict(c_kv (B,S,R), k_rope (B,S,Dr))."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim

    q = rms_norm(x @ p["wq_down"], p["q_norm"], cfg.norm_eps) @ p["wq_up"]
    q = q.reshape(B, S, H, qk_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]

    kv = x @ p["wkv_down"]                              # (B, S, R + Dr)
    c_kv = rms_norm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope_new = kv[..., m.kv_lora_rank:][:, :, None, :]  # (B, S, 1, Dr)

    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_base)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope_new = apply_rope(k_rope_new, cos, sin)[:, :, 0]  # (B, S, Dr)

    if cache is None:
        # ---- full-sequence (train/prefill): materialize per-layer K/V and
        # run the flash blockwise path when large (PERF It.8) ------------
        k_nope = (c_kv @ p["wk_up"]).reshape(B, S, H, m.qk_nope_head_dim)
        v = (c_kv @ p["wv_up"]).reshape(B, S, H, m.v_head_dim)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_new[:, :, None, :],
                                      (B, S, H, m.qk_rope_head_dim))], -1)
        q_cat = jnp.concatenate([q_nope, q_rope], -1)    # (B,S,H,qk_dim)
        if should_use_blockwise(B, S, S, H):
            out = blockwise_sdpa(q_cat, k_cat, v, qpos=positions,
                                 kpos=positions, kind="causal")
        else:
            sc = jnp.einsum("bshd,bthd->bhst", q_cat.astype(jnp.float32),
                            k_cat.astype(jnp.float32)) / np.sqrt(qk_dim)
            mask = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
            sc = jnp.where(mask[None, None], sc, -1e30)
            w = jax.nn.softmax(sc, axis=-1)
            out = jnp.einsum("bhst,bthd->bshd", w, v.astype(jnp.float32))
        out = out.reshape(B, S, H * m.v_head_dim).astype(x.dtype) @ p["wo"]
        # raw per-position latents for prefill caching.
        return out, {"c_kv": c_kv, "k_rope": k_rope_new}

    # ---- decode: *absorbed* attention (PERF It.8) ------------------------
    # score = q_nope . (c_kv W_uk)^T == (q_nope W_uk^T) . c_kv, so the step
    # reads only the latent cache (R + Dr floats per token) — the
    # architecture's whole point; never materializes (B,T,H,D) keys.
    ck = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, cache_index, 0))
    kr = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, cache_index, 0))
    T = ck.shape[1]
    wk = p["wk_up"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))            # (B,1,H,R)
    sc = (jnp.einsum("bshr,btr->bhst", q_abs, ck.astype(jnp.float32))
          + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                       kr.astype(jnp.float32))) / np.sqrt(qk_dim)
    mask = (jnp.arange(T) <= cache_index)[None, None, None, :]
    sc = jnp.where(mask, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", w, ck.astype(jnp.float32))  # (B,1,H,R)
    wv = p["wv_up"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", ctx, wv.astype(jnp.float32))
    out = out.reshape(B, S, H * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return out, {"c_kv": ck, "k_rope": kr}
