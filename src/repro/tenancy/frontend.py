"""Multi-tenant serving front door (DESIGN.md §10).

:class:`MultiTenantFrontend` serves several tenants' open-loop arrival
traces against ONE shared storage engine on the same deterministic clock
as the single-stream :class:`~repro.ingest.frontend.IngestFrontend` it
extends.  What changes is everything between arrival and group commit:

* each tenant's keys are rewritten into its :class:`NamespaceMap`
  interval, so one engine (any tier, sharded included) holds every
  namespace with zero cross-tenant key collisions and per-tenant RANGE
  stays a contiguous scan;
* admission runs through a :class:`WeightedFairQueue` — per-tenant
  bounded queues, per-tenant shed accounting, deficit-round-robin pick —
  so an aggressor overflows *its own* queue instead of starving
  co-tenants (``fair=False`` swaps back the single shared FIFO, the
  noisy-neighbor baseline the tenancy benchmark measures against);
* one :class:`~repro.ingest.slo.SLOTracker` runs per tenant plus one
  aggregate, all at the run's ``stall_factor``, and each tenant's report
  carries its own p99.9 and an SLO verdict against its target;
* group commits mix tenants, and the WAL path is inherited unchanged —
  encoded keys carry tenant identity into the shared log, so
  ``repro.wal.recovery.recover`` restores every namespace at once and
  ``key_range=namespace.tenant_interval(tid)`` restores exactly one;
* :meth:`pin_snapshot` freezes a cross-shard-consistent read view at the
  current commit watermark (``repro.tenancy.snapshots``) that stays
  valid while ingest and emptying cascades proceed underneath.

Determinism carries over: on sim tiers the whole multi-tenant run is a
pure function of (traces, tenant configs, engine config) — byte-identical
reports across runs.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.core.engine_api import OpBatch, OpKind, StorageEngine
from repro.ingest.arrivals import ArrivalTrace, multiplex
from repro.ingest.frontend import (DurabilityConfig, FrontendConfig,
                                   IngestFrontend)
from repro.ingest.slo import SLOTracker
from repro.wal.faults import CrashPoint, FaultInjector, reach as _reach

from .fair_queue import WeightedFairQueue
from .namespace import NamespaceMap
from .snapshots import SnapshotManager

_KIND_NAMES = {int(k): k.name.lower() for k in OpKind}


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's identity, fair-share weight, bound and SLO target."""

    tenant_id: int
    name: str = ""
    weight: float = 1.0            # DRR share relative to peers
    max_queue: int = 4096          # this tenant's own admission bound (ops)
    #: insert end-to-end p99.9 target in seconds (None = no target); the
    #: per-tenant report carries ``slo.met`` against it.
    slo_p999_s: float | None = None

    def __post_init__(self):
        assert self.tenant_id >= 0 and self.weight > 0 and self.max_queue >= 1
        assert self.slo_p999_s is None or self.slo_p999_s > 0

    @property
    def label(self) -> str:
        return self.name or f"tenant{self.tenant_id}"


class _SharedFifo:
    """The unfair baseline: one global FIFO, one global bound.

    Same offer/take/heads/backlog/stats surface as
    :class:`WeightedFairQueue` so the frontend is agnostic; shed is
    charged to whichever tenant's op hit the full shared queue — exactly
    the cross-tenant interference fairness removes.
    """

    def __init__(self, max_queue: int):
        self.max_queue = int(max_queue)
        self._q: collections.deque = collections.deque()
        self._counters: dict[int, dict] = {}
        self._depth: dict[int, int] = {}

    def add_tenant(self, tenant_id: int, *, weight: float = 1.0,
                   max_queue: int = 0) -> None:
        self._counters[int(tenant_id)] = {
            "weight": float(weight), "offered": 0, "shed": 0, "served": 0}
        self._depth[int(tenant_id)] = 0

    def offer(self, tenant_id: int, item) -> bool:
        c = self._counters[int(tenant_id)]
        c["offered"] += 1
        if len(self._q) >= self.max_queue:
            c["shed"] += 1
            return False
        self._q.append((int(tenant_id), item))
        self._depth[int(tenant_id)] += 1
        return True

    def take(self, max_ops: int) -> list:
        out = []
        while self._q and len(out) < max_ops:
            tid, item = self._q.popleft()
            self._counters[tid]["served"] += 1
            self._depth[tid] -= 1
            out.append((tid, item))
        return out

    def heads(self) -> list:
        return [self._q[0]] if self._q else []

    def backlog(self, tenant_id: int | None = None) -> int:
        if tenant_id is None:
            return len(self._q)
        return self._depth[int(tenant_id)]

    def stats(self) -> dict:
        return {str(tid): dict(c, max_queue=self.max_queue,
                               backlog=self.backlog(tid), depth_max=None)
                for tid, c in self._counters.items()}


class MultiTenantFrontend(IngestFrontend):
    """Serve several tenants' traces on one engine; see module docstring.

    The durability plumbing (WAL group commit, periodic checkpoints,
    crash points, ``acked`` oracle) is inherited verbatim — a multi-tenant
    commit is just a group commit whose keys happen to span namespaces.
    """

    def __init__(self, engine: StorageEngine, tenants: list,
                 config: FrontendConfig | None = None,
                 durability: DurabilityConfig | None = None,
                 injector: FaultInjector | None = None, *,
                 namespace: NamespaceMap | None = None, fair: bool = True,
                 obs=None):
        super().__init__(engine, config, durability, injector, obs=obs)
        assert tenants, "at least one tenant required"
        self.tenants = {int(t.tenant_id): t for t in tenants}
        assert len(self.tenants) == len(tenants), "duplicate tenant ids"
        self.namespace = namespace or NamespaceMap()
        for t in tenants:
            self.namespace._check_tenant(t.tenant_id)
        self.fair = bool(fair)
        if self.fair:
            # quantum = commit size: one round's credit for a weight-1
            # tenant is one full commit — the finest granularity at which
            # the server can reorder service anyway.
            self.queue = WeightedFairQueue(quantum=self.config.commit_ops)
        else:
            self.queue = _SharedFifo(self.config.max_queue)
        for t in tenants:
            self.queue.add_tenant(t.tenant_id, weight=t.weight,
                                  max_queue=t.max_queue)
        self.snapshots = SnapshotManager(engine)
        self._n_commits = 0

    # ------------------------------------------------------------- snapshots
    def pin_snapshot(self, tenant_id: int | None = None,
                     now_s: float = 0.0):
        """Freeze a consistent read view at the current commit watermark.

        Call on a group-commit boundary (e.g. from ``run``'s ``on_commit``
        callback, or before/after ``run``).  ``tenant_id`` scopes the view
        to that namespace's interval; None pins the whole keyspace.  The
        watermark is the durable commit LSN when a WAL is attached, else
        the commit ordinal — either way the applied prefix the view equals.
        """
        wm = self.last_acked_lsn if self._wal is not None else self._n_commits
        kr = None if tenant_id is None \
            else self.namespace.tenant_interval(tenant_id)
        return self.snapshots.pin(wm, now_s, key_range=kr)

    # ----------------------------------------------------------------- running
    def run(self, traces: dict, *, drain: bool = True,
            on_commit=None) -> dict:
        """Serve every tenant's :class:`ArrivalTrace`; JSON-ready report.

        ``traces`` maps tenant id -> trace in that tenant's *local*
        keyspace (encoding is this frontend's job).  ``on_commit``, if
        given, is called as ``on_commit(frontend, t_commit)`` after every
        group commit fully lands — a commit boundary, i.e. a legal instant
        to :meth:`pin_snapshot` (how the differential snapshot tests drive
        pins mid-run, cascades still pending).
        """
        cfg = self.config
        eng = self.engine
        ns = self.namespace
        q = self.queue
        assert set(traces) == set(self.tenants), \
            "traces and tenant configs must cover the same tenant ids"

        agg = SLOTracker(stall_factor=cfg.stall_factor)
        trackers = {tid: SLOTracker(stall_factor=cfg.stall_factor)
                    for tid in self.tenants}
        obs, tracer = self.obs, self.tracer
        wm = None
        if obs is not None:
            from repro.obs.metrics import WindowedMetrics
            wm = WindowedMetrics(obs.window_s, stall_k=obs.stall_k,
                                 stall_trailing=obs.stall_trailing)

        # encode every tenant's ops/preload into its namespace up front —
        # one vectorized pass per tenant, and the per-commit gather below
        # stays index arithmetic.
        enc = {tid: ns.encode_batch(tid, traces[tid].ops)
               for tid in self.tenants}
        tr_t = {tid: np.asarray(traces[tid].t_arrive, np.float64)
                for tid in self.tenants}

        # load phase: closed-loop, before the clock starts.
        pre = [ns.encode_batch(tid, traces[tid].preload)
               for tid in sorted(self.tenants) if len(traces[tid].preload)]
        if pre:
            eng.apply(OpBatch.concat(pre))
            eng.drain()
            if self._ckpt is not None:
                self._checkpoint()
                self._ckpt_service_s = 0.0

        mt, msid, mloc = multiplex(traces)
        n = len(mt)
        self._i = 0
        t_free = 0.0

        def admit_until(t: float) -> None:
            i = self._i
            # coalesced per poll (one instant per tenant+kind with a count),
            # matching the single-tenant frontend: per-op instants under a
            # sustained overload would evict every span from the trace ring
            shed_t0: dict[tuple[int, str], float] = {}
            shed_n: dict[tuple[int, str], int] = {}
            while i < n and mt[i] <= t:
                tid, loc = int(msid[i]), int(mloc[i])
                kname = _KIND_NAMES[int(enc[tid].kinds[loc])]
                if q.offer(tid, loc):
                    trackers[tid].record_queue_depth(q.backlog(tid))
                    agg.record_queue_depth(q.backlog())
                else:
                    trackers[tid].record_shed(kname)
                    agg.record_shed(kname)
                    if obs is not None:
                        shed_t0.setdefault((tid, kname), mt[i])
                        shed_n[(tid, kname)] = shed_n.get((tid, kname), 0) + 1
                        wm.record_shed(mt[i])
                i += 1
            for (tid, kname), t0 in shed_t0.items():
                tracer.instant("shed", kname, t0, tenant=tid,
                               count=shed_n[(tid, kname)])
            self._i = i

        while q.backlog() or self._i < n:
            admit_until(t_free)
            if not q.backlog():
                admit_until(mt[self._i])
            t0 = max(t_free, min(tr_t[tid][loc] for tid, loc in q.heads()))

            # ---- group commit: size or deadline, whichever first ----------
            if q.backlog() >= cfg.commit_ops or self._i >= n:
                t_commit = t0
            else:
                deadline = t0 + cfg.linger_s
                need = cfg.commit_ops - q.backlog()
                j, got = self._i, 0
                while j < n and mt[j] <= deadline and got < need:
                    j, got = j + 1, got + 1
                t_commit = max(t0, mt[j - 1]) if got == need else deadline
            admit_until(t_commit)

            take = q.take(cfg.commit_ops)
            if obs is not None and self.fair:
                # a tenant with backlog that got ZERO slots this commit was
                # deferred by the DRR scheduler — the throttle event.
                served_tids = {p[0] for p in take}
                for tid in self.tenants:
                    if tid not in served_tids and q.backlog(tid) > 0:
                        tracer.instant("tenant_throttle", "drr_defer",
                                       t_commit, tenant=int(tid),
                                       backlog=int(q.backlog(tid)))
            sel_t = np.asarray([p[0] for p in take], np.int64)
            sel_i = np.asarray([p[1] for p in take], np.int64)
            m = len(take)
            bkinds = np.empty(m, np.int8)
            bkeys = np.empty(m, np.uint64)
            bvals = np.empty(m, np.int64)
            bhis = np.empty(m, np.uint64)
            arr = np.empty(m, np.float64)
            for tid in np.unique(sel_t):
                w = sel_t == tid
                e, ii = enc[int(tid)], sel_i[w]
                bkinds[w] = e.kinds[ii]
                bkeys[w] = e.keys[ii]
                bvals[w] = e.vals[ii]
                bhis[w] = e.his[ii]
                arr[w] = tr_t[int(tid)][ii]
            batch = OpBatch(bkinds, bkeys, bvals, bhis)

            # ---- durability: WAL append + fsync BEFORE apply --------------
            wal_s = 0.0
            if self._wal is not None:
                wal_s = self._wal_commit(batch)

            # ---- service (engine clock -> simulated clock) ----------------
            res = eng.apply(batch)
            if self._wal is not None:
                eng.note_applied(self.last_acked_lsn)
                _reach(self._injector, CrashPoint.AFTER_APPLY)
            if self.sim_clock:
                op_service = np.asarray(res.latency_s, np.float64)
            else:
                op_service = np.full(m, cfg.virtual_op_service_s)
            service_s = wal_s + float(op_service.sum())

            # ---- interleaved maintenance + debt snapshot ------------------
            io1 = eng.io_time_s()
            debt = self._maintain(cfg.maintain_budget)
            io2 = eng.io_time_s()
            if self.sim_clock:
                maintain_s = io2 - io1
            else:
                maintain_s = cfg.virtual_op_service_s * cfg.maintain_budget

            self._n_commits += 1
            ckpt_s = 0.0
            if (self._ckpt is not None
                    and self.durability.checkpoint_every_commits
                    and self._n_commits
                    % self.durability.checkpoint_every_commits == 0
                    and self._wal.last_lsn > self._ckpt_lsn):
                ckpt_s = self._checkpoint()
                maintain_s += ckpt_s

            done = t_commit + wal_s + np.cumsum(op_service)
            if obs is not None:
                if wal_s > 0.0:
                    tracer.complete("wal_fsync", "fsync", t_commit, wal_s,
                                    lsn=int(self.last_acked_lsn))
                tracer.complete("commit", "group_commit", t_commit,
                                service_s, ops=m, qdepth=q.backlog())
                cascade_s = maintain_s - ckpt_s
                if cascade_s > 0.0:
                    tracer.complete("cascade", "maintain",
                                    t_commit + service_s, cascade_s,
                                    budget=cfg.maintain_budget,
                                    debt=int(debt))
                if ckpt_s > 0.0:
                    tracer.complete("checkpoint", "snapshot",
                                    t_commit + service_s + cascade_s,
                                    ckpt_s, lsn=int(self._ckpt_lsn),
                                    pairs=int(self._last_snapshot_pairs))
                wm.record(t_commit, done - arr, ops=m,
                          queue_depth=q.backlog(), debt=int(debt))
            knames = [_KIND_NAMES[int(k)] for k in bkinds]
            agg.record_commit(
                t_commit=t_commit, kinds=knames, e2e_s=done - arr,
                queue_delay_s=t_commit - arr, qdepth_after=q.backlog(),
                service_s=service_s, maintain_s=maintain_s, debt=int(debt))
            for tid in np.unique(sel_t):
                w = sel_t == tid
                trackers[int(tid)].record_commit(
                    t_commit=t_commit,
                    kinds=[kn for kn, hit in zip(knames, w) if hit],
                    e2e_s=done[w] - arr[w], queue_delay_s=t_commit - arr[w],
                    qdepth_after=q.backlog(int(tid)),
                    service_s=service_s, maintain_s=maintain_s,
                    debt=int(debt))
            t_free = t_commit + service_s + maintain_s
            if on_commit is not None:
                on_commit(self, t_commit)

        t_end = t_free
        debt_final = eng.maintain(0)
        if drain:
            eng.drain()

        # ---- report ------------------------------------------------------
        def offered_of(kind_arr) -> dict:
            k = np.asarray(kind_arr)
            return {name: int((k == kk).sum())
                    for kk, name in _KIND_NAMES.items()}

        all_kinds = np.concatenate(
            [np.asarray(traces[tid].ops.kinds) for tid in sorted(self.tenants)]
        ) if self.tenants else np.zeros(0, np.int8)
        report = agg.report(offered=offered_of(all_kinds), t_end=t_end)
        report["service_model"] = "charged" if self.sim_clock else "virtual"
        report["pending_debt_at_end"] = int(debt_final)
        report["config"] = dataclasses.asdict(cfg)
        report["fair"] = self.fair
        report["namespace"] = ns.describe()
        report["admission"] = q.stats()
        report["snapshots"] = self.snapshots.stats()
        if obs is not None:
            report["obs"] = self._finish_obs(wm, t_end)

        tenants_out = {}
        for tid in sorted(self.tenants):
            tc = self.tenants[tid]
            sub = trackers[tid].report(
                offered=offered_of(traces[tid].ops.kinds), t_end=t_end)
            lo, hi = ns.tenant_interval(tid)
            ins = sub["per_kind_e2e"].get("insert", {})
            p999 = float(ins.get("p999_s", 0.0))
            slo = {"p999_target_s": tc.slo_p999_s,
                   "observed_insert_p999_s": p999,
                   "met": (None if tc.slo_p999_s is None
                           else bool(p999 <= tc.slo_p999_s))}
            tenants_out[str(tid)] = {
                "name": tc.label, "weight": tc.weight,
                "interval": [int(lo), int(hi)],
                "live_pairs": int(eng.count_live_range(lo, hi)),
                "slo": slo, "open_loop": sub,
            }
        report["tenants"] = tenants_out

        if self._wal is not None:
            self._wal.close()
            report["durability"] = {
                "config": dataclasses.asdict(self.durability),
                "wal": self._wal.stats()
                | {"service_s_total": self._wal_service_s},
                "checkpoints": {
                    "taken": self._ckpts_taken,
                    "last_lsn": self._ckpt_lsn,
                    "last_snapshot_pairs": self._last_snapshot_pairs,
                    "service_s_total": self._ckpt_service_s,
                },
                "acked_commits": len(self.acked),
                "last_acked_lsn": self.last_acked_lsn,
            }
        return report


def run_multi_tenant(engine: StorageEngine, tenants: list, traces: dict, *,
                     config: FrontendConfig | None = None,
                     durability: DurabilityConfig | None = None,
                     namespace: NamespaceMap | None = None,
                     fair: bool = True, obs=None) -> dict:
    """One-call harness: serve every tenant's trace, full JSON report."""
    fe = MultiTenantFrontend(engine, tenants, config, durability,
                             namespace=namespace, fair=fair, obs=obs)
    ol = fe.run(traces)
    stats = engine.stats()
    return {
        "engine": engine.name,
        "tenants": {str(t.tenant_id):
                    {"name": t.label, "weight": t.weight,
                     "arrival": dict(traces[t.tenant_id].arrival),
                     "n_ops": len(traces[t.tenant_id])}
                    for t in tenants},
        "open_loop": ol,
        "stats": dataclasses.asdict(stats),
    }
