"""Open-loop ingest frontend: bounded queue, group commit, simulated clock.

This is the serving layer between workload generation and the storage
engines (DESIGN.md §7).  A closed-loop driver asks "how long does an op
take once the engine starts it?"; an open-loop frontend asks the question
the paper's worst-case-delay claim is actually about: *what latency does a
request experience when it arrives on its own schedule* — queueing behind
a compaction stall included.

:class:`IngestFrontend` simulates a single-server ingest node on a
deterministic clock:

* **Arrivals** come from an :class:`~repro.ingest.arrivals.ArrivalTrace`
  (timestamped ops).  An op is *admitted* if the bounded ingest queue has
  room at its arrival instant, else it is **shed** (admission control —
  the knob that trades availability for bounded memory and bounded tail).
* **Group commit**: the server coalesces queued ops into an
  :class:`~repro.core.engine_api.OpBatch` of up to ``commit_ops``,
  lingering at most ``linger_s`` past the moment it could first serve
  (classic group commit: size *or* deadline, whichever first).  Arrival
  order is preserved, so the protocol's sequential batch semantics match
  the trace's logical order.
* **Service** is charged from the engine's own accounting: on cost-model
  tiers (``clock == "sim"``) a batch's service time is the sum of its
  per-op simulated latencies and maintenance time is the engine's charged
  I/O delta — so the whole run is a pure function of (trace, engine
  config) and two runs produce byte-identical reports.  On the wall-clock
  device tier, real measurements are nondeterministic by nature, so the
  clock instead uses a fixed *virtual* per-op service time
  (``virtual_op_service_s``); device rows exercise the full protocol and
  queueing math deterministically, while their absolute latencies are the
  surrogate model's, flagged ``service_model: "virtual"`` in reports.
* **Maintenance** is interleaved once per commit — ``maintain(budget)``
  on the simulated clock, exactly like the closed-loop driver — and the
  engine's pending-debt snapshot is recorded at every commit, which is
  what lets :mod:`repro.ingest.slo` attribute tail latency to stalls and
  verify the deamortized debt bound under load.

End-to-end latency of op *i* = (commit time + its share of batch service)
- arrival time = queueing + service; the SLO tracker reports exact
p50/p99/p99.9/p100 per kind plus queue/shed/stall accounting.

* **Durability** (optional; DESIGN.md §9): with a :class:`DurabilityConfig`
  the frontend write-ahead-logs every group commit's INSERT/DELETE rows
  (``repro.wal``) and **acks only after the record's fsync returns** — the
  ack instant *is* durability.  The fsync-per-commit cost is charged on the
  same clock as everything else: simulated seek + sequential-write seconds
  on sim tiers (through a :class:`~repro.core.cost_model.CostModel` on the
  engine's own device constants), measured wall seconds on the device tier.
  Every ``checkpoint_every_commits`` commits the engine's live table is
  snapshotted (``EngineCheckpointer``) at the current commit LSN and the
  WAL is truncated past it, bounding recovery replay.  A crash at any
  point (``repro.wal.faults``) recovers via ``repro.wal.recovery.recover``
  to exactly the acked prefix.
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core.cost_model import PAIR_BYTES, SSD
from repro.core.engine_api import OpBatch, OpKind, StorageEngine
from repro.obs.metrics import ObsConfig, WindowedMetrics
from repro.obs.stall import attribute_stalls, detect_stalls
from repro.obs.trace import Tracer
from repro.wal.faults import (ChaosEvent, ChaosKind, CrashPoint,
                              FaultInjector, FaultSchedule, SimulatedCrash,
                              flip_wal_byte, reach as _reach, tear_wal_tail)

from .arrivals import ArrivalTrace
from .slo import STALL_FACTOR, SLOTracker

_KIND_NAMES = {int(k): k.name.lower() for k in OpKind}
_WRITE_KINDS = (int(OpKind.INSERT), int(OpKind.DELETE))


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Serving-node knobs (defaults sized for benchmark-scale traces)."""

    max_queue: int = 4096          # admission-control bound (ops)
    commit_ops: int = 64           # group-commit size cap
    linger_s: float = 1e-3         # group-commit deadline past first-servable
    maintain_budget: int = 1       # maintenance units interleaved per commit
    #: deterministic surrogate service time per op for wall-clock engines
    #: (see module docstring); ignored on sim tiers.
    virtual_op_service_s: float = 5e-6
    #: stall-attribution threshold (see ``repro.ingest.slo``): a commit is
    #: a stall when its service time exceeds this multiple of the run's
    #: typical commit service.  Recorded in ``report["stalls"]`` so sweeps
    #: with different thresholds are self-describing.
    stall_factor: float = STALL_FACTOR

    def __post_init__(self):
        assert self.max_queue >= 1 and self.commit_ops >= 1
        assert self.commit_ops <= self.max_queue, \
            "a commit cannot exceed the queue bound"
        assert self.linger_s >= 0.0 and self.maintain_budget >= 0
        assert self.virtual_op_service_s > 0.0
        assert self.stall_factor > 1.0


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Knobs of the WAL + checkpoint layer (DESIGN.md §9).

    ``directory`` holds ``wal/`` (redo segments) and ``checkpoints/``
    (LSN-keyed engine snapshots).  ``checkpoint_every_commits = 0``
    disables periodic snapshots (the WAL alone still makes every acked
    write recoverable; a nonempty preload is always snapshotted once
    before the clock starts, since preload is never logged).
    """

    directory: str
    segment_bytes: int = 1 << 20
    checkpoint_every_commits: int = 0

    def __post_init__(self):
        assert self.segment_bytes >= 4096
        assert self.checkpoint_every_commits >= 0


class IngestFrontend:
    """Single-server open-loop serving simulation over one engine."""

    def __init__(self, engine: StorageEngine, config: FrontendConfig | None = None,
                 durability: DurabilityConfig | None = None,
                 injector: FaultInjector | None = None,
                 obs: ObsConfig | None = None,
                 chaos: FaultSchedule | None = None):
        self.engine = engine
        self.config = config or FrontendConfig()
        self.durability = durability
        self._injector = injector
        # chaos harness (DESIGN.md §12): the single-engine frontend owns the
        # schedule's default target, ``"wal"``.  When ``chaos`` is None every
        # hook below is one attribute check — the serving loop is unchanged.
        self.chaos = chaos
        self._chaos_stall_s = 0.0       # one-shot: next commit's fsync pays it
        self._chaos_spike = 1.0         # service multiplier while spike active
        self._chaos_spike_until = 0.0
        if chaos is not None:
            chaos.register("wal", self._on_chaos)
        # observability is strictly opt-in: when ``obs`` is None (or
        # disabled) every hook below is a single attribute check, so the
        # serving loop's timings are identical to the pre-obs frontend.
        self.obs = obs if (obs is not None and obs.enabled) else None
        self.tracer: Tracer | None = None
        if self.obs is not None:
            self.tracer = Tracer(capacity=self.obs.trace_capacity)
            engine.attach_tracer(self.tracer)
        # the engine self-reports its clock domain via stats(); adapters set
        # a class attribute, so probing one snapshot is cheap and universal.
        self.sim_clock = engine.stats().clock == "sim"
        self._wal = None
        self._ckpt = None
        #: every acked group commit as ``(lsn, kinds, keys, vals)`` — the
        #: ground truth the crash-matrix tests build their oracle from (an
        #: op is in here iff its fsync returned, i.e. iff it was acked).
        self.acked: list = []
        self.last_acked_lsn = 0
        if durability is not None:
            from repro.checkpoint.checkpointer import EngineCheckpointer
            from repro.wal import (CHECKPOINT_SUBDIR, WAL_SUBDIR,
                                   WriteAheadLog)
            self._wal_dir = os.path.join(durability.directory, WAL_SUBDIR)
            self._wal = WriteAheadLog(
                self._wal_dir,
                segment_bytes=durability.segment_bytes, injector=injector)
            self._ckpt = EngineCheckpointer(
                os.path.join(durability.directory, CHECKPOINT_SUBDIR),
                injector=injector)
            # restart-after-crash: opening an existing directory resumes the
            # durable LSN chain where the previous frontend left it, so a
            # resumed run's first commit is ``last_lsn + 1`` (LSN
            # continuity) and its report never claims a stale watermark.
            self.last_acked_lsn = self._wal.last_lsn
            # fsync cost is charged on the engine's own device constants
            # when it has any (sim tiers); the device tier measures wall
            # time instead, so its device constant is never read.
            cm = getattr(engine, "cm", None)
            self._wal_device = cm.device if cm is not None else SSD
            self._wal_service_s = 0.0
            self._ckpt_service_s = 0.0
            self._ckpt_lsn = 0
            self._ckpts_taken = 0
            self._last_snapshot_pairs = 0

    # ------------------------------------------------------------------ chaos
    def _on_chaos(self, ev: ChaosEvent) -> None:
        """Apply one due chaos event to this frontend (target ``"wal"``).

        Performance faults mutate charging state consumed at the next
        commit; ``CRASH`` propagates like an injector kill (the crash-
        recovery tests' ``except SimulatedCrash`` path); the corruption
        kinds physically damage the newest WAL segment so the *next
        recovery* — not this run — sees a torn/corrupt tail.
        """
        if ev.kind is ChaosKind.FSYNC_STALL:
            self._chaos_stall_s += ev.arg
        elif ev.kind is ChaosKind.LATENCY_SPIKE:
            self._chaos_spike = max(float(ev.arg), 1.0)
            self._chaos_spike_until = ev.t + ev.dur_s
        elif ev.kind is ChaosKind.CRASH:
            # fires at a commit boundary, before the next WAL append: none
            # of the still-queued ops were acked, exactly BEFORE_WAL_APPEND.
            raise SimulatedCrash(CrashPoint.BEFORE_WAL_APPEND, 1)
        elif ev.kind is ChaosKind.TORN_SEGMENT and self._wal is not None:
            tear_wal_tail(self._wal_dir)
        elif ev.kind is ChaosKind.BIT_FLIP and self._wal is not None:
            flip_wal_byte(self._wal_dir)

    # ------------------------------------------------------------- durability
    def _wal_commit(self, batch: OpBatch) -> float:
        """Durably log the commit's writes; returns charged seconds.

        The ack instant for every write in the batch is the fsync return
        inside ``append_commit`` — a crash before it means the ops were
        never acked (and a torn record is truncated on recovery); a crash
        after it means recovery must replay them.
        """
        wmask = np.isin(np.asarray(batch.kinds), _WRITE_KINDS)
        if not wmask.any():
            return 0.0              # read-only commit: nothing to make durable
        t0 = time.perf_counter()
        lsn, nbytes = self._wal.append_commit(
            batch.kinds[wmask], batch.keys[wmask], batch.vals[wmask])
        wall = time.perf_counter() - t0
        if self.sim_clock:
            dev = self._wal_device
            sec = dev.seek_s + nbytes / dev.write_bw
        else:
            sec = wall
        self._wal_service_s += sec
        self.acked.append((lsn, batch.kinds[wmask].copy(),
                           batch.keys[wmask].copy(), batch.vals[wmask].copy()))
        self.last_acked_lsn = lsn
        # the fsync returned, so the ops above ARE acked — this crash point
        # therefore means "durable + acked, not yet applied": replay owes it.
        _reach(self._injector, CrashPoint.AFTER_WAL_FSYNC)
        return sec

    def _checkpoint(self) -> float:
        """Snapshot the engine's live table at the current commit LSN and
        truncate the WAL past it; returns charged seconds."""
        lsn = self._wal.last_lsn
        t0 = time.perf_counter()
        keys, vals = self.engine.dump_live()
        self._ckpt.save_snapshot(lsn, keys, vals)
        _reach(self._injector, CrashPoint.AFTER_CHECKPOINT)
        self._wal.truncate_upto(lsn)
        wall = time.perf_counter() - t0
        self._ckpt_lsn = lsn
        self._ckpts_taken += 1
        self._last_snapshot_pairs = len(keys)
        if self.sim_clock:
            dev = self._wal_device
            sec = dev.seek_s + len(keys) * PAIR_BYTES / dev.write_bw
        else:
            sec = wall
        self._ckpt_service_s += sec
        return sec

    def _maintain(self, budget: int) -> int:
        """``engine.maintain`` with the mid-cascade crash point threaded in
        (unit-at-a-time only when an injector is armed — the production
        path stays one call)."""
        if self._injector is None or budget <= 0:
            return self.engine.maintain(budget)
        debt = self.engine.maintain(0)
        for _ in range(int(budget)):
            if not debt:
                break
            debt = self.engine.maintain(1)
            _reach(self._injector, CrashPoint.MID_CASCADE)
        return debt

    # ------------------------------------------------------------ observability
    def _finish_obs(self, wm: WindowedMetrics, t_end: float) -> dict:
        """Close the windowed timeline, attribute stalls against the span
        buffer, optionally save the Chrome trace, and return the report
        block.  Shared by the single- and multi-tenant serving loops."""
        obs, tracer = self.obs, self.tracer
        block = wm.finish(t_end)
        stalls = detect_stalls(wm.windows, k=obs.stall_k,
                               trailing=obs.stall_trailing)
        block["stalls"] = attribute_stalls(stalls, tracer.events())
        block["trace"] = {
            "events": len(tracer),
            "dropped_events": tracer.dropped_events,
            "categories": sorted(tracer.categories()),
        }
        if obs.trace_path:
            tracer.save(obs.trace_path)
            block["trace"]["path"] = obs.trace_path
        return block

    # ----------------------------------------------------------------- running
    def run(self, trace: ArrivalTrace, *, drain: bool = True) -> dict:
        """Serve ``trace``; returns the JSON-ready open-loop report."""
        cfg = self.config
        eng = self.engine
        tracker = SLOTracker(stall_factor=cfg.stall_factor)
        obs, tracer = self.obs, self.tracer
        wm = None
        if obs is not None:
            wm = WindowedMetrics(obs.window_s, stall_k=obs.stall_k,
                                 stall_trailing=obs.stall_trailing)

        # load phase: closed-loop, before the clock starts (not offered load).
        if len(trace.preload):
            eng.apply(trace.preload)
            eng.drain()
            if self._ckpt is not None:
                # preload is setup, not offered load — it is never WAL-logged,
                # so durability requires snapshotting it before the clock
                # starts (uncharged, like the load phase itself).
                self._checkpoint()
                self._ckpt_service_s = 0.0

        kinds = np.asarray(trace.ops.kinds)
        t_arr = np.asarray(trace.t_arrive, np.float64)
        n = len(kinds)
        queue: list[int] = []       # FIFO of admitted op indices
        self._i = 0                 # next arrival not yet admitted/shed
        self._n_commits = 0         # group commits served (checkpoint cadence)
        t_free = 0.0                # server becomes available at this time

        def admit_until(t: float) -> None:
            """Admit (or shed) every arrival with t_arrive <= t, in order.

            Occupancy only grows between commits, so evaluating arrivals in
            timestamp order against the live queue length gives each op the
            admission decision it would see at its own arrival instant.
            """
            i = self._i
            # shed instants are coalesced per poll (one event per kind with
            # a count) so a long overload burst cannot flood the trace ring
            # and evict the cascade/checkpoint spans attribution needs
            shed_t0: dict[str, float] = {}
            shed_n: dict[str, int] = {}
            while i < n and t_arr[i] <= t:
                if len(queue) < cfg.max_queue:
                    queue.append(i)
                    tracker.record_queue_depth(len(queue))
                else:
                    kname = _KIND_NAMES[int(kinds[i])]
                    tracker.record_shed(kname)
                    if obs is not None:
                        shed_t0.setdefault(kname, t_arr[i])
                        shed_n[kname] = shed_n.get(kname, 0) + 1
                        wm.record_shed(t_arr[i])
                i += 1
            for kname, t0 in shed_t0.items():
                tracer.instant("shed", kname, t0, count=shed_n[kname])
            self._i = i

        while queue or self._i < n:
            admit_until(t_free)
            if not queue:
                # idle: jump the clock to the next arrival (plus any ties).
                admit_until(t_arr[self._i])
            t0 = max(t_free, t_arr[queue[0]])

            # ---- group commit: size or deadline, whichever first ----------
            if len(queue) >= cfg.commit_ops or self._i >= n:
                t_commit = t0
            else:
                deadline = t0 + cfg.linger_s
                need = cfg.commit_ops - len(queue)
                j, got = self._i, 0
                while j < n and t_arr[j] <= deadline and got < need:
                    j, got = j + 1, got + 1
                t_commit = max(t0, t_arr[j - 1]) if got == need else deadline
            admit_until(t_commit)

            take = queue[: cfg.commit_ops]
            del queue[: len(take)]
            idx = np.asarray(take, np.int64)
            batch = OpBatch(kinds[idx], trace.ops.keys[idx],
                            trace.ops.vals[idx], trace.ops.his[idx])

            # ---- chaos: due events fire at the commit boundary ------------
            if self.chaos is not None:
                for ev in self.chaos.fire_due(t_commit):
                    if obs is not None:
                        tracer.instant("chaos", ev.kind.value, t_commit,
                                       target=ev.target, arg=ev.arg)

            # ---- durability: WAL append + fsync BEFORE apply --------------
            # (write-ahead rule; the fsync return is the ack instant, and
            # its cost is part of the commit's service time on this clock.)
            wal_s = 0.0
            if self._wal is not None:
                wal_s = self._wal_commit(batch)
            if self._chaos_stall_s > 0.0:
                # a pending FSYNC_STALL charges the next commit exactly once
                wal_s += self._chaos_stall_s
                if self._wal is not None:
                    self._wal_service_s += self._chaos_stall_s
                self._chaos_stall_s = 0.0

            # ---- service (engine clock -> simulated clock) ----------------
            # apply cost is charged through per-op latencies (the engine's
            # foreground share); maintenance through the charged-I/O delta.
            res = eng.apply(batch)
            if self._wal is not None:
                eng.note_applied(self.last_acked_lsn)
                _reach(self._injector, CrashPoint.AFTER_APPLY)
            if self.sim_clock:
                op_service = np.asarray(res.latency_s, np.float64)
            else:
                op_service = np.full(len(idx), cfg.virtual_op_service_s)
            if self._chaos_spike > 1.0 and t_commit < self._chaos_spike_until:
                # LATENCY_SPIKE window: every charged second costs ``arg``×
                op_service = op_service * self._chaos_spike
                wal_s *= self._chaos_spike
            service_s = wal_s + float(op_service.sum())

            # ---- interleaved maintenance + debt snapshot ------------------
            io1 = eng.io_time_s()
            debt = self._maintain(cfg.maintain_budget)
            io2 = eng.io_time_s()
            if self.sim_clock:
                maintain_s = io2 - io1
            else:
                maintain_s = cfg.virtual_op_service_s * cfg.maintain_budget

            # ---- periodic checkpoint: snapshot @ LSN, truncate WAL --------
            self._n_commits += 1
            ckpt_s = 0.0
            if (self._ckpt is not None
                    and self.durability.checkpoint_every_commits
                    and self._n_commits
                    % self.durability.checkpoint_every_commits == 0
                    and self._wal.last_lsn > self._ckpt_lsn):
                ckpt_s = self._checkpoint()
                maintain_s += ckpt_s

            done = t_commit + wal_s + np.cumsum(op_service)
            tracker.record_commit(
                t_commit=t_commit,
                kinds=[_KIND_NAMES[int(k)] for k in kinds[idx]],
                e2e_s=done - t_arr[idx],
                queue_delay_s=t_commit - t_arr[idx],
                qdepth_after=len(queue),
                service_s=service_s, maintain_s=maintain_s, debt=int(debt))
            if obs is not None:
                # spans carry *charged* durations on the same clock as the
                # latency math, so the saved trace is byte-deterministic on
                # sim tiers (and virtual-clock-consistent on the device tier)
                if wal_s > 0.0:
                    tracer.complete("wal_fsync", "fsync", t_commit, wal_s,
                                    lsn=int(self.last_acked_lsn))
                tracer.complete("commit", "group_commit", t_commit,
                                service_s, ops=len(idx),
                                qdepth=len(queue))
                cascade_s = maintain_s - ckpt_s
                if cascade_s > 0.0:
                    tracer.complete("cascade", "maintain",
                                    t_commit + service_s, cascade_s,
                                    budget=cfg.maintain_budget,
                                    debt=int(debt))
                if ckpt_s > 0.0:
                    tracer.complete("checkpoint", "snapshot",
                                    t_commit + service_s + cascade_s,
                                    ckpt_s, lsn=int(self._ckpt_lsn),
                                    pairs=int(self._last_snapshot_pairs))
                wm.record(t_commit, done - t_arr[idx], ops=len(idx),
                          queue_depth=len(queue), debt=int(debt))
            t_free = t_commit + service_s + maintain_s

        t_end = t_free
        debt_final = eng.maintain(0)
        if drain:
            eng.drain()

        offered = {name: int((kinds == k).sum())
                   for k, name in _KIND_NAMES.items()}
        report = tracker.report(offered=offered, t_end=t_end)
        report["service_model"] = "charged" if self.sim_clock else "virtual"
        report["pending_debt_at_end"] = int(debt_final)
        report["config"] = dataclasses.asdict(self.config)
        if obs is not None:
            report["obs"] = self._finish_obs(wm, t_end)
        if self._wal is not None:
            self._wal.close()
            report["durability"] = {
                "config": dataclasses.asdict(self.durability),
                "wal": self._wal.stats()
                | {"service_s_total": self._wal_service_s},
                "checkpoints": {
                    "taken": self._ckpts_taken,
                    "last_lsn": self._ckpt_lsn,
                    "last_snapshot_pairs": self._last_snapshot_pairs,
                    "service_s_total": self._ckpt_service_s,
                },
                "acked_commits": len(self.acked),
                "last_acked_lsn": self.last_acked_lsn,
            }
        if self.chaos is not None:
            report["chaos"] = self.chaos.describe()
        return report


def run_open_loop(engine: StorageEngine, trace: ArrivalTrace, *,
                  config: FrontendConfig | None = None,
                  durability: DurabilityConfig | None = None,
                  obs: ObsConfig | None = None,
                  chaos: FaultSchedule | None = None) -> dict:
    """One-call harness: serve ``trace`` on ``engine``, full JSON report.

    The returned dict mirrors the closed-loop driver report shape (engine
    name, arrival description, final ``stats()`` snapshot) with the
    open-loop SLO section under ``"open_loop"``.
    """
    fe = IngestFrontend(engine, config, durability=durability, obs=obs,
                        chaos=chaos)
    ol = fe.run(trace)
    stats = engine.stats()
    return {
        "engine": engine.name,
        "arrival": dict(trace.arrival),
        "trace": {"n_ops": len(trace), "duration_s": trace.duration_s,
                  "seed": trace.seed, "preload_pairs": len(trace.preload)},
        "open_loop": ol,
        "stats": dataclasses.asdict(stats),
    }
