"""Failover scenario: kill a primary mid-ingest; measure RTO and blast radius.

The replication layer (DESIGN.md §12) claims three measurable properties
for an insertion-intensive deployment that loses a primary at full offered
load, all exercised here on the charged sim clock:

* **Zero lost acked writes at R=2.**  Every run is differentially checked
  against a sorted-dict oracle fed only by *acked* group commits: after
  the kill + promotion + rebuild, the surviving ensemble state equals the
  oracle exactly — no acked row missing, no unacked row resurrected.
* **Bounded, measured RTO.**  The failover event records the crash,
  detection (heartbeat timeout), promotion (WAL-tail replay), and the
  write-availability restore; the affected range's windowed p99.9
  timeline collapses during the outage and returns to its pre-crash tail
  after the backlog drains.  Unaffected ranges keep serving — their
  windowed tails are statistically unchanged vs a no-chaos control run of
  the same seed.
* **R=1 is the counterfactual.**  The same kill with no replica loses the
  range permanently: acked rows on the dead primary are gone and every
  subsequent op routed there is shed at its retry deadline.  That
  measured loss is the price the ``primary``/unreplicated configurations
  pay for their lower commit latency.

Standalone CLI (CI chaos-smoke; ``BENCH_failover.json`` at the repo root
is the seed trajectory record)::

    PYTHONPATH=src python -m benchmarks.fig_failover --quick \
        --out runs/fig_failover.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

from repro.core.cost_model import SSD
from repro.core.engine_api import OpKind, make_engine
from repro.ingest import FrontendConfig, PoissonArrivals, make_trace
from repro.replication import ReplicatedFrontend, ReplicationConfig
from repro.wal import FaultSchedule
from repro.workloads import make_workload
from repro.workloads.driver import SCHEMA_VERSION

KEY_SPACE = 1 << 20
GROUPS = 4
KILL_GID = 1
ENGINE_KW = dict(f=3, sigma=512, device=SSD)
FRONTEND = FrontendConfig(max_queue=4096, commit_ops=64, linger_s=2e-4)
WINDOW_S = 0.01            # availability-timeline resolution

#: offered load and stream size for the full sweep.
OPS = 8_000
RATE = 40_000.0
KILL_T = 0.03              # primary of group KILL_GID dies here (sim s)

#: one source of truth for the smoke-sized sweep (--quick here and in
#: benchmarks/run.py must produce comparable artifacts).
QUICK_KWARGS = dict(ops=3_000, kill_t=0.02)


def _engine():
    return make_engine("nbtree", **ENGINE_KW)


def _scenario(replicas: int, chaos_spec: str | None, *, ops: int,
              rate: float, seed: int):
    """One full serving run; returns (report, differential, per-group tails).

    The differential check needs the live engines, so it runs inside the
    frontend's lifetime: oracle = preload + every acked commit in ack
    order; state = union of the surviving primaries' live dumps.  Keys
    routed to permanently failed groups are tallied as ``lost_range`` —
    the R=1 counterfactual's measured loss — and excluded from the
    survivor comparison.
    """
    wl = make_workload("insert-heavy", key_space=KEY_SPACE, n_ops=ops,
                       preload=2048, batch_size=256, seed=seed)
    trace = make_trace(wl, PoissonArrivals(rate))
    rep = ReplicationConfig(replicas=replicas, heartbeat_timeout_s=0.005)
    chaos = FaultSchedule.parse(chaos_spec) if chaos_spec else None
    with tempfile.TemporaryDirectory(prefix="fig_failover_") as d:
        fe = ReplicatedFrontend(_engine, d, groups=GROUPS, replication=rep,
                                config=FRONTEND, chaos=chaos,
                                window_s=WINDOW_S, key_hi=KEY_SPACE)
        report = fe.run(trace)

        oracle: dict[int, int] = {}
        for k, v in zip(trace.preload.keys.tolist(),
                        trace.preload.vals.tolist()):
            oracle[int(k)] = int(v)
        for _gid, _lsn, kinds, keys, vals in fe.acked:
            for kk, k, v in zip(kinds.tolist(), keys.tolist(), vals.tolist()):
                if kk == int(OpKind.INSERT):
                    oracle[int(k)] = int(v)
                elif kk == int(OpKind.DELETE):
                    oracle.pop(int(k), None)

        failed = {g.gid for g in fe.groups if g.failed}
        live: dict[int, int] = {}
        for g in fe.groups:
            if g.gid in failed:
                continue
            lk, lv = g.primary.engine.dump_live()
            for k, v in zip(lk.tolist(), lv.tolist()):
                live[int(k)] = int(v)
        okeys = np.fromiter(oracle.keys(), np.uint64, len(oracle))
        gids = (fe.partitioner.shard_of(okeys) if len(okeys)
                else np.zeros(0, np.int64))
        lost_range = sum(int(g) in failed for g in gids)
        surviving = {int(k) for k, g in zip(okeys.tolist(), gids)
                     if int(g) not in failed}
        lost_acked = sum(1 for k in surviving if k not in live
                         or live[k] != oracle[k])
        resurrected = sum(1 for k in live if k not in oracle)
        diff = dict(lost_acked=lost_acked, resurrected=resurrected,
                    lost_range=lost_range)
    return report, diff


def _tails(report) -> dict[int, dict]:
    """Per-group tail summary from the availability timelines."""
    out = {}
    for a in report["replication"]["availability"]:
        act = [w for w in a["timeline"]["timeline"] if w["ops"] > 0]
        p999 = sorted(w["p999_s"] for w in act)
        out[a["gid"]] = {
            "active_windows": len(act),
            "median_p999_s": p999[len(p999) // 2] if p999 else 0.0,
            "last_p999_s": act[-1]["p999_s"] if act else 0.0,
            "last_t_s": act[-1]["t_end_s"] if act else 0.0,
            "downtime_s": a["downtime_s"],
            "shed": sum(w["shed"] for w in a["timeline"]["timeline"]),
        }
    return out


def _row(**kw):
    base = dict(fig="failover", kind="", index="", replicas=0, gid=-1,
                rate=0.0, n_done=0, n_shed=0, acked_commits=0,
                failovers=0, rto_ms=0.0, detect_ms=0.0, promote_ms=0.0,
                replayed_ops=0, downtime_ms=0.0, lost_acked=0,
                resurrected=0, lost_range=0, failed_groups="",
                active_windows=0, median_p999_ms=0.0, last_p999_ms=0.0,
                shed=0)
    base.update(kw)
    return base


def run(ops: int = OPS, rate: float = RATE, kill_t: float = KILL_T,
        seed: int = 0):
    rows = []
    kill = f"crash@{kill_t}:g{KILL_GID}/primary"
    runs = {
        "control-r2": (2, None),
        "kill-r2": (2, kill),
        "kill-r1": (1, kill),
    }
    for name, (replicas, spec) in runs.items():
        report, diff = _scenario(replicas, spec, ops=ops, rate=rate,
                                 seed=seed)
        rep = report["replication"]
        fo = rep["failovers"]
        ev = fo[0] if fo else {}
        rto = ev.get("rto_s") or 0.0
        rows.append(_row(
            kind="scenario", index=name, replicas=replicas, rate=rate,
            n_done=report["n_done"], n_shed=report["n_shed"],
            acked_commits=rep["acked_commits"], failovers=len(fo),
            rto_ms=rto * 1e3,
            detect_ms=(ev.get("t_detected", 0.0)
                       - ev.get("t_crash", 0.0)) * 1e3 if ev else 0.0,
            promote_ms=ev.get("promote_s", 0.0) * 1e3,
            replayed_ops=ev.get("replayed_ops", 0),
            lost_acked=diff["lost_acked"], resurrected=diff["resurrected"],
            lost_range=diff["lost_range"],
            failed_groups="/".join(str(g) for g in rep["failed_groups"])))
        for gid, t in sorted(_tails(report).items()):
            rows.append(_row(
                kind="group", index=f"{name}/g{gid}", replicas=replicas,
                gid=gid, rate=rate, downtime_ms=t["downtime_s"] * 1e3,
                active_windows=t["active_windows"],
                median_p999_ms=t["median_p999_s"] * 1e3,
                last_p999_ms=t["last_p999_s"] * 1e3, shed=t["shed"]))
    return rows


def check(rows) -> list[str]:
    out = []
    sc = {r["index"]: r for r in rows if r["kind"] == "scenario"}
    grp = {r["index"]: r for r in rows if r["kind"] == "group"}
    k2, k1, ctl = sc["kill-r2"], sc["kill-r1"], sc["control-r2"]

    # the replication contract: a primary kill at R=2 loses nothing acked.
    ok = (k2["failovers"] >= 1 and k2["lost_acked"] == 0
          and k2["resurrected"] == 0 and k2["lost_range"] == 0
          and not k2["failed_groups"])
    tag = "matches paper" if ok else "MISMATCH"
    out.append(f"failover: R=2 primary kill -> promotion, zero lost acked "
               f"writes, zero resurrected unacked writes "
               f"({k2['failovers']} failover, {k2['replayed_ops']} WAL-tail "
               f"ops replayed)  [{tag}]")

    # measured RTO, and the affected range's tail actually comes back: its
    # final active window's p99.9 is back within 3x its control-run median
    # (the outage backlog has drained), strictly after the restore.
    aff_k = grp[f"kill-r2/g{KILL_GID}"]
    aff_c = grp[f"control-r2/g{KILL_GID}"]
    band = 3.0 * max(aff_c["median_p999_ms"], 1e-3)
    ok = (0.0 < k2["rto_ms"] < 500.0
          and aff_k["downtime_ms"] > 0.0
          and aff_k["last_p999_ms"] <= band)
    tag = "matches paper" if ok else "MISMATCH"
    out.append(f"failover: RTO {k2['rto_ms']:.1f}ms (detect "
               f"{k2['detect_ms']:.1f}ms + promote {k2['promote_ms']:.2f}ms "
               f"+ quorum rebuild); affected range's windowed p99.9 "
               f"recovers to {aff_k['last_p999_ms']:.3f}ms (<= 3x control "
               f"median {aff_c['median_p999_ms']:.3f}ms)  [{tag}]")

    # blast radius: unaffected ranges' windowed tails statistically
    # unchanged vs the no-chaos control of the same seed (within 3x each
    # way), with zero downtime and zero shed.
    others = [g for g in range(GROUPS) if g != KILL_GID]
    ratios = []
    ok = True
    for g in others:
        a, b = grp[f"kill-r2/g{g}"], grp[f"control-r2/g{g}"]
        r = (a["median_p999_ms"] + 1e-6) / (b["median_p999_ms"] + 1e-6)
        ratios.append(round(r, 2))
        ok &= (1 / 3 <= r <= 3.0 and a["downtime_ms"] == 0.0
               and a["shed"] == 0)
    tag = "matches paper" if ok else "MISMATCH"
    out.append(f"failover: unaffected ranges statistically unchanged "
               f"(median-p99.9 ratios vs control {ratios}, zero downtime, "
               f"zero shed)  [{tag}]")

    # the counterfactual: R=1 loses the killed range for good.
    ok = (k1["failed_groups"] == str(KILL_GID) and k1["lost_range"] > 0
          and k1["n_shed"] > 0 and k1["lost_acked"] == 0)
    tag = "matches paper" if ok else "MISMATCH"
    out.append(f"failover: R=1 kill loses the range permanently "
               f"({k1['lost_range']} acked rows gone, {k1['n_shed']} ops "
               f"shed at deadline) while survivors stay exact  [{tag}]")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller sweep (CI chaos-smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="runs/fig_failover.json")
    args = ap.parse_args(argv)
    kwargs = dict(QUICK_KWARGS) if args.quick else {}
    rows = run(seed=args.seed, **kwargs)
    checks = check(rows)
    for r in rows:
        print(r)
    for c in checks:
        print(" ->", c)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "seed": args.seed,
                   "quick": bool(args.quick), "rows": rows,
                   "checks": checks}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
