"""Pallas TPU kernel: streaming merge of two sorted runs (the flush hot loop).

TPU adaptation of the paper's merge-sort flush (Sec. 4.1).  A sequential
two-pointer merge is hostile to a vector machine, so we use the *merge-path*
formulation, reorganized to be **gather-only** (TPU VMEM has fast dynamic
gathers, no fast scatters): every output element k independently binary-
searches the diagonal partition i(k) = |{a-elements among the first k merged
elements}| over the two runs held entirely in VMEM, then gathers its key /
value from ``a[i]`` or ``b[k-i]``.  log2(N) vectorized steps, no data-
dependent control flow, MXU-free (pure VPU), fully pipelined across output
tiles by the Pallas grid.

Tie-break: equal keys take the ``a`` element first — ``a`` is the newer
stream, so leftmost-match queries see the freshest record (delta-record
resolution, paper Sec. 3.2.2).

Two entry points share the kernel body: ``merge_sorted`` (one pair of runs,
1-d output-tile grid) and ``merge_sorted_batch`` (R independent pairs on a
2-d ``(run, out-tile)`` grid — the one-dispatch fan-out the fused NB-tree
emptying cascade uses to merge all children of a node at once).

VMEM budget: both runs (keys+values, uint32/int32) fully resident:
4 arrays x 64 Ki x 4 B = 1 MiB at sigma = 64 Ki pairs — comfortably inside
the ~128 MiB/core VMEM of v5e, leaving room for double-buffered output tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import KEY_MAX32

LANES = 128
SUBLANES = 8
TILE = SUBLANES * LANES  # output elements per grid step


def _take(arr, idx):
    """Clamped dynamic gather (Mosaic lowers to tpu.DynamicGather)."""
    return jnp.take(arr, idx, mode="clip")


def _merge_kernel(a_keys_ref, a_vals_ref, b_keys_ref, b_vals_ref,
                  ok_ref, ov_ref, *, n: int, m: int, steps: int,
                  batched: bool = False):
    a = a_keys_ref[...].reshape(-1)
    b = b_keys_ref[...].reshape(-1)
    av = a_vals_ref[...].reshape(-1)
    bv = b_vals_ref[...].reshape(-1)

    # batched entry runs a (run, out-tile) grid; the run axis is resolved by
    # the BlockSpecs, so the kernel body only needs its output-tile index.
    tile = pl.program_id(1 if batched else 0)
    row = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, LANES), 1)
    k = tile * TILE + row * LANES + col  # global output index, (8, 128)

    # --- merge-path binary search for i(k) --------------------------------
    lo = jnp.maximum(0, k - m)
    hi = jnp.minimum(k, n)
    for _ in range(steps):
        i = (lo + hi) >> 1
        j = k - i
        a_i = _take(a, jnp.clip(i, 0, n - 1))
        b_jm1 = _take(b, jnp.clip(j - 1, 0, m - 1))
        go_right = (lo < hi) & (a_i <= b_jm1)
        lo = jnp.where(go_right, i + 1, lo)
        hi = jnp.where(go_right, hi, i)

    i = lo
    j = k - i
    a_i = _take(a, jnp.clip(i, 0, n - 1))
    b_j = _take(b, jnp.clip(j, 0, m - 1))
    take_a = (j >= m) | ((i < n) & (a_i <= b_j))
    ok_ref[...] = jnp.where(take_a, a_i, b_j).reshape(ok_ref.shape)
    ov_ref[...] = jnp.where(
        take_a,
        _take(av, jnp.clip(i, 0, n - 1)),
        _take(bv, jnp.clip(j, 0, m - 1)),
    ).reshape(ov_ref.shape)


def _pad_run(keys, vals, pad_to):
    n = keys.shape[0]
    if n == pad_to:
        return keys, vals
    return (
        jnp.pad(keys, (0, pad_to - n), constant_values=KEY_MAX32),
        jnp.pad(vals, (0, pad_to - n), constant_values=0),
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_sorted(a_keys, a_vals, b_keys, b_vals, *, interpret: bool = True):
    """Merged (keys, vals) of length n+m (padded to a TILE multiple).

    Inputs are sorted uint32 runs (KEY_MAX padding allowed); outputs keep
    KEY_MAX padding at the tail.  ``interpret=True`` runs the kernel body on
    CPU; pass False on real TPU.
    """
    n_raw, m_raw = a_keys.shape[0], b_keys.shape[0]
    n = max(TILE, -(-n_raw // TILE) * TILE)
    m = max(TILE, -(-m_raw // TILE) * TILE)
    a_keys, a_vals = _pad_run(a_keys, a_vals, n)
    b_keys, b_vals = _pad_run(b_keys, b_vals, m)

    total = n + m
    steps = math.ceil(math.log2(max(n, m) + 1)) + 1
    kernel = functools.partial(_merge_kernel, n=n, m=m, steps=steps)

    a2 = a_keys.reshape(n // LANES, LANES)
    b2 = b_keys.reshape(m // LANES, LANES)
    av2 = a_vals.reshape(n // LANES, LANES)
    bv2 = b_vals.reshape(m // LANES, LANES)

    full = lambda rows: pl.BlockSpec((rows, LANES), lambda t: (0, 0))
    out_spec = pl.BlockSpec((SUBLANES, LANES), lambda t: (t, 0))
    ok, ov = pl.pallas_call(
        kernel,
        grid=(total // TILE,),
        in_specs=[full(n // LANES), full(n // LANES), full(m // LANES), full(m // LANES)],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((total // LANES, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((total // LANES, LANES), a_vals.dtype),
        ],
        interpret=interpret,
    )(a2, av2, b2, bv2)
    return ok.reshape(-1), ov.reshape(-1)


def _pad_runs_2d(keys, vals, pad_to):
    n = keys.shape[1]
    if n == pad_to:
        return keys, vals
    pad = ((0, 0), (0, pad_to - n))
    return (jnp.pad(keys, pad, constant_values=KEY_MAX32),
            jnp.pad(vals, pad, constant_values=0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_sorted_batch(a_keys, a_vals, b_keys, b_vals, *, interpret: bool = True):
    """Merge R independent pairs of sorted runs in ONE kernel launch.

    ``a_keys``/``a_vals`` are ``(R, n)``, ``b_keys``/``b_vals`` ``(R, m)``;
    returns ``(R, n+m)`` merged runs (both dims padded to TILE multiples,
    KEY_MAX tails).  Row r is exactly ``merge_sorted(a[r], b[r])`` — same
    merge-path formulation, same a-first tie-break — on a 2-d
    ``(run, out-tile)`` grid, which is what lets the NB-tree emptying
    cascade merge all <= f children of a node in a single device dispatch
    instead of one launch per child.
    """
    R, n_raw = a_keys.shape
    m_raw = b_keys.shape[1]
    assert b_keys.shape[0] == R
    n = max(TILE, -(-n_raw // TILE) * TILE)
    m = max(TILE, -(-m_raw // TILE) * TILE)
    a_keys, a_vals = _pad_runs_2d(a_keys, a_vals, n)
    b_keys, b_vals = _pad_runs_2d(b_keys, b_vals, m)

    total = n + m
    steps = math.ceil(math.log2(max(n, m) + 1)) + 1
    kernel = functools.partial(_merge_kernel, n=n, m=m, steps=steps,
                               batched=True)

    a2 = a_keys.reshape(R, n // LANES, LANES)
    b2 = b_keys.reshape(R, m // LANES, LANES)
    av2 = a_vals.reshape(R, n // LANES, LANES)
    bv2 = b_vals.reshape(R, m // LANES, LANES)

    full = lambda rows: pl.BlockSpec((1, rows, LANES), lambda r, t: (r, 0, 0))
    out_spec = pl.BlockSpec((1, SUBLANES, LANES), lambda r, t: (r, t, 0))
    ok, ov = pl.pallas_call(
        kernel,
        grid=(R, total // TILE),
        in_specs=[full(n // LANES), full(n // LANES),
                  full(m // LANES), full(m // LANES)],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((R, total // LANES, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((R, total // LANES, LANES), a_vals.dtype),
        ],
        interpret=interpret,
    )(a2, av2, b2, bv2)
    return ok.reshape(R, total), ov.reshape(R, total)
