"""Fig. 5 (a)/(b): average query / insertion time vs d-tree size sigma.

Paper finding: larger sigma improves insertion (fewer seeks per pair) but
worsens query time (bigger runs to search), with query recovering at very
large sigma (in-memory component absorbs queries).
"""
from __future__ import annotations

from repro.core.cost_model import HDD
from repro.core.engine_api import make_engine

from .common import insert_all, query_sample, scaled_device, workload


def run(n: int = 120_000):
    keys = workload(n)
    rows = []
    for sigma in (512, 1024, 2048, 4096, 8192, 16384):
        # NB: the device is *fixed* across the sigma sweep (the paper varies
        # sigma on one physical disk); scaled to the sweep's midpoint.
        nb = make_engine("nbtree", f=3, sigma=sigma,
                         device=scaled_device(HDD, 4096))
        avg_ins, _ = insert_all(nb, keys)
        nb.drain()
        avg_q, _ = query_sample(nb, keys)
        rows.append(dict(fig="5", sigma=sigma,
                         avg_insert_us=avg_ins * 1e6,
                         avg_query_ms=avg_q * 1e3,
                         height=nb.height()))
    return rows


def check(rows) -> list[str]:
    out = []
    first, last = rows[0], rows[-1]
    if last["avg_insert_us"] < first["avg_insert_us"]:
        out.append("fig5b: larger sigma improves insertion  [matches paper]")
    else:
        out.append("fig5b: larger sigma did not improve insertion  [MISMATCH]")
    if last["height"] < first["height"]:
        out.append("fig5: larger sigma shortens the tree  [matches paper]")
    return out
