"""Figs. 8 & 9: average / maximum query time vs data size.

Paper claims reproduced: NB-tree average query ~B+-tree(bulk), >=1.5x
faster than the LSM family; maximum query bounded by the s-tree height
(asymptotically optimal) while LSM worst case scales with level count.
"""
from __future__ import annotations

from .common import (DEVICES, bulk_btree_engine, insert_all,
                     make_bench_engine, query_sample, workload)

INDICES = ("nbtree", "nbtree-nobloom", "lsm", "blsm")


def run(sizes=(40_000, 160_000)):
    rows = []
    for dev_name, dev in DEVICES.items():
        for n in sizes:
            keys = workload(n)
            sigma = max(1024, n // 64)
            for name in INDICES:
                eng = make_bench_engine(name, dev, sigma)
                insert_all(eng, keys)
                eng.drain()
                avg_q, max_q = query_sample(eng, keys, n_q=600)
                rows.append(dict(fig="8/9", device=dev_name, n=n, index=name,
                                 avg_query_ms=avg_q * 1e3, max_query_ms=max_q * 1e3))
            bt = bulk_btree_engine(keys, dev, sigma)
            avg_q, max_q = query_sample(bt, keys, n_q=600)
            rows.append(dict(fig="8/9", device=dev_name, n=n, index="btree-bulk",
                             avg_query_ms=avg_q * 1e3, max_query_ms=max_q * 1e3))
    return rows


def check(rows) -> list[str]:
    out = []
    big = max(r["n"] for r in rows)
    for dev in DEVICES:
        sel = {r["index"]: r for r in rows if r["n"] == big and r["device"] == dev}
        nb, bulk, lsm = sel["nbtree"], sel["btree-bulk"], sel["lsm"]
        if nb["avg_query_ms"] < 2.0 * bulk["avg_query_ms"]:
            out.append(f"fig8 {dev}: NB avg query ~ bulk B+-tree "
                       f"({nb['avg_query_ms']:.2f} vs {bulk['avg_query_ms']:.2f} ms)"
                       "  [matches paper]")
        else:
            out.append(f"fig8 {dev}: NB query {nb['avg_query_ms']:.2f}ms vs bulk "
                       f"{bulk['avg_query_ms']:.2f}ms  [MISMATCH]")
        if nb["avg_query_ms"] <= lsm["avg_query_ms"]:
            out.append(f"fig8 {dev}: NB query <= LSM  [matches paper]")
        nobloom = sel["nbtree-nobloom"]
        if nb["avg_query_ms"] < nobloom["avg_query_ms"]:
            out.append(f"fig8 {dev}: Bloom filters cut NB query time  [matches paper]")
    return out
