"""Hymba 1.5B [arXiv:2411.13676; hf].

32L, d_model 1600, 25 heads GQA kv 5, d_ff 5504, parallel attention+SSM
heads per block (ssm_state 16); full (global) attention at layers
{0, 15, 31}, SWA elsewhere — expressed exactly by the segment list.
Hybrid -> long_500k runs (ring KV for SWA + O(1) SSM state).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    segments=(("hybrid_global", 1), ("hybrid", 14),
              ("hybrid_global", 1), ("hybrid", 15),
              ("hybrid_global", 1)),
    swa_window=1024, ssm_state=16, ssm_expand=2,
    mlp_kind="swiglu",
)
