"""Engine-conformance suite for the unified StorageEngine API (DESIGN.md §5).

One deterministic mixed op-stream (insert / delete / query / range with
maintain interleavings) is generated once by the workload subsystem and
replayed through every registered tier; each engine's visible results must
match the sorted-dict oracle op for op — which makes all five tiers
pairwise identical by transitivity.  The same pass asserts the stats()
contract: charged I/O cost never decreases across apply/maintain/drain,
and after drain() the logical live-pair count equals the oracle's.
"""
import numpy as np
import pytest

from repro.core.engine_api import (FIVE_TIERS, EngineStats, OpBatch, OpKind,
                                   UnsupportedOp, available_engines,
                                   make_engine)
from repro.workloads import MIXES, make_workload
from repro.workloads.driver import run_workload

#: small-footprint configs so the device tier stays CI-sized.
CONFIGS = {
    "nbtree": dict(f=3, sigma=256),
    "lsm": dict(mem_pairs=256),
    "btree": {},
    "bepsilon": dict(node_bytes=1 << 14, cached_levels=1),
    "jax-nbtree": dict(f=4, sigma=256, max_nodes=256),
}


def _workload(**overrides):
    kw = dict(key_space=4096, n_ops=512, batch_size=128, preload=256,
              range_selectivity=0.01, seed=3)
    kw.update(overrides)
    return make_workload("delete-churn", **kw)


@pytest.fixture(scope="module")
def stream():
    """(preload, batches, per-op oracle expectations, final live count)."""
    wl = _workload()
    pre = wl.preload_batch()
    batches = list(wl.batches())
    model = dict(zip(pre.keys.tolist(), pre.vals.tolist()))
    expected = []
    for b in batches:
        exp = []
        for i in range(len(b)):
            kind = OpKind(int(b.kinds[i]))
            k = int(b.keys[i])
            if kind is OpKind.INSERT:
                model[k] = int(b.vals[i])
                exp.append(None)
            elif kind is OpKind.DELETE:
                model.pop(k, None)
                exp.append(None)
            elif kind is OpKind.QUERY:
                exp.append(model.get(k))
            else:
                hi = int(b.his[i])
                ks = sorted(x for x in model if k <= x <= hi)
                exp.append((ks, [model[x] for x in ks]))
        expected.append(exp)
    return pre, batches, expected, len(model)


@pytest.mark.parametrize("name", FIVE_TIERS)
def test_engine_conformance(name, stream):
    pre, batches, expected, n_live = stream
    eng = make_engine(name, **CONFIGS[name])
    eng.apply(pre)
    eng.drain()
    last_io = eng.io_time_s()

    for bi, (b, exp) in enumerate(zip(batches, expected)):
        res = eng.apply(b)
        assert not res.range_truncated.any(), (name, bi)
        for i in range(len(b)):
            kind = OpKind(int(b.kinds[i]))
            if kind is OpKind.QUERY:
                want = exp[i]
                assert bool(res.found[i]) == (want is not None), (name, bi, i)
                if want is not None:
                    assert int(res.values[i]) == want, (name, bi, i)
            elif kind is OpKind.RANGE:
                rk, rv = res.range_hits[i]
                assert rk.tolist() == exp[i][0], (name, bi, i)
                assert rv.tolist() == exp[i][1], (name, bi, i)
        eng.maintain(2)
        io = eng.io_time_s()            # charged cost must never decrease
        assert io >= last_io, (name, bi)
        last_io = io

    eng.drain()
    s = eng.stats()
    assert s.io_time_s >= last_io, name
    assert s.total_pairs == n_live, (name, s.total_pairs, n_live)
    assert s.pending_debt == 0, name
    assert s.physical_pairs >= s.total_pairs, name
    assert s.n_inserts + s.n_deletes + s.n_queries + s.n_ranges \
        == len(pre) + sum(len(b) for b in batches), name


def test_stats_snapshot_shape():
    eng = make_engine("lsm", mem_pairs=64)
    eng.apply(OpBatch.inserts(np.arange(1, 33, dtype=np.uint64),
                              np.arange(32, dtype=np.int64)))
    s = eng.stats()
    assert isinstance(s, EngineStats)
    assert s.engine == "lsm" and s.clock == "sim"
    assert s.n_inserts == 32 and s.total_pairs == 32


def test_maintain_budget_bounds_debt():
    """refimpl cascade: bounded maintain() leaves debt, drain() clears it."""
    eng = make_engine("nbtree", f=3, sigma=64)
    keys = np.random.default_rng(0).permutation(
        np.arange(1, 200, dtype=np.uint64))
    eng.apply(OpBatch.inserts(keys, np.arange(len(keys), dtype=np.int64)))
    # one page quantum at a time: debt must stay visible until exhausted.
    seen_debt = eng.stats().pending_debt
    for _ in range(10_000):
        if eng.maintain(1) == 0:
            break
    assert eng.maintain(1) == 0
    assert eng.stats().pending_debt == 0
    assert seen_debt in (0, 1)
    eng.drain()   # idempotent


def test_registry_and_unsupported_ops():
    assert set(FIVE_TIERS) <= set(available_engines())
    with pytest.raises(KeyError):
        make_engine("no-such-engine")
    from repro.core.engine_api import BulkBTreeEngine
    bulk = BulkBTreeEngine(np.arange(1, 9, dtype=np.uint64),
                           np.arange(8, dtype=np.int64))
    with pytest.raises(UnsupportedOp):
        bulk.apply(OpBatch.inserts([1], [1]))
    res = bulk.apply(OpBatch.queries([1, 100]))
    assert res.found.tolist() == [True, False]


def test_opbatch_validation_and_concat():
    with pytest.raises(AssertionError):
        OpBatch(np.zeros(2, np.int8), np.zeros(3, np.uint64),
                np.zeros(2, np.int64), np.zeros(2, np.uint64))
    b = OpBatch.concat([OpBatch.inserts([1, 2], [10, 20]),
                        OpBatch.ranges([0], [5])])
    assert len(b) == 3
    assert b.kinds.tolist() == [OpKind.INSERT, OpKind.INSERT, OpKind.RANGE]
    assert int(b.his[2]) == 5


def test_opbatch_concat_empty_inputs():
    e = OpBatch.concat([])                      # empty list: empty batch
    assert len(e) == 0 and e.keys.dtype == np.uint64
    # zero-length members are dropped, order of the rest preserved
    b = OpBatch.concat([OpBatch.empty(), OpBatch.deletes([7]),
                        OpBatch.inserts([], []), OpBatch.queries([8])])
    assert b.kinds.tolist() == [OpKind.DELETE, OpKind.QUERY]
    assert b.keys.tolist() == [7, 8]
    # an engine accepts the empty batch and returns an empty result
    res = make_engine("lsm", mem_pairs=64).apply(OpBatch.concat([]))
    assert len(res.kinds) == 0 and len(res.latency_s) == 0


def test_opbatch_concat_mixed_kinds_equals_sequential_apply():
    """Property: concat-then-apply == sequential apply (refimpl tier)."""
    for seed in range(4):
        rng = np.random.default_rng(seed)
        pieces = []
        for _ in range(rng.integers(0, 6)):
            kind = rng.integers(0, 4)
            n = int(rng.integers(0, 8))
            ks = rng.integers(1, 512, n, dtype=np.uint64)
            if kind == 0:
                pieces.append(OpBatch.inserts(ks, rng.integers(0, 99, n)))
            elif kind == 1:
                pieces.append(OpBatch.deletes(ks))
            elif kind == 2:
                pieces.append(OpBatch.queries(ks))
            else:
                pieces.append(OpBatch.ranges(ks, ks + np.uint64(40)))
        a = make_engine("nbtree", f=3, sigma=64)
        b = make_engine("nbtree", f=3, sigma=64)
        res = a.apply(OpBatch.concat(pieces))
        parts = [b.apply(p) for p in pieces]
        found = np.concatenate([p.found for p in parts]) \
            if parts else np.zeros(0, bool)
        values = np.concatenate([p.values for p in parts]) \
            if parts else np.zeros(0)
        hits = [h for p in parts for h in p.range_hits]
        assert res.found.tolist() == found.tolist(), seed
        assert res.values.tolist() == values.tolist(), seed
        for h1, h2 in zip(res.range_hits, hits):
            assert (h1 is None) == (h2 is None)
            if h1 is not None:
                assert h1[0].tolist() == h2[0].tolist()
                assert h1[1].tolist() == h2[1].tolist()
        a.drain()
        b.drain()
        assert a.count_live() == b.count_live(), seed


def test_workload_generator_deterministic():
    a = [b for b in _workload().batches()]
    c = [b for b in _workload().batches()]
    for x, y in zip(a, c):
        assert np.array_equal(x.kinds, y.kinds)
        assert np.array_equal(x.keys, y.keys)
        assert np.array_equal(x.vals, y.vals)
        assert np.array_equal(x.his, y.his)
    d = [b for b in _workload(seed=4).batches()]
    assert any(not np.array_equal(x.keys, y.keys) for x, y in zip(a, d))


def test_workload_zipfian_is_skewed():
    wl = make_workload("ycsb-b", key_space=1 << 16, n_ops=4096,
                       batch_size=512, theta=0.9)
    assert wl.spec.dist == "zipfian"
    keys = np.concatenate([b.keys for b in wl.batches()])
    _, counts = np.unique(keys, return_counts=True)
    top = np.sort(counts)[::-1]
    # hot keys dominate: the top 1% of distinct keys draw >10% of accesses
    # (a uniform draw gives ~1%).
    frac = top[: max(1, len(top) // 100)].sum() / counts.sum()
    assert frac > 0.10, frac


def test_hotspot_shift_deterministic_and_moving():
    kw = dict(key_space=1 << 16, n_ops=2048, batch_size=256, preload=64,
              seed=9)
    wl = make_workload("hotspot-shift", **kw)
    assert wl.spec.dist == "hotspot"
    a, b = list(wl.batches()), list(make_workload("hotspot-shift",
                                                  **kw).batches())
    for x, y in zip(a, b):            # same seed -> identical op stream
        assert np.array_equal(x.kinds, y.kinds)
        assert np.array_equal(x.keys, y.keys)
        assert np.array_equal(x.vals, y.vals)
        assert np.array_equal(x.his, y.his)
    c = list(make_workload("hotspot-shift", **{**kw, "seed": 10}).batches())
    assert any(not np.array_equal(x.keys, y.keys) for x, y in zip(a, c))
    # the hot mass moves: median insert key of the first batch sits near
    # the bottom of the key space, the last batch's near the top.
    def med(batch):
        ins = batch.keys[batch.kinds == int(OpKind.INSERT)]
        return float(np.median(ins.astype(np.float64)))
    span = wl.spec.key_space
    assert med(a[0]) < 0.25 * span
    assert med(a[-1]) > 0.5 * span


def test_all_mixes_generate():
    for mix in MIXES:
        wl = make_workload(mix, key_space=1 << 12, n_ops=64, batch_size=32,
                           preload=16)
        batches = list(wl.batches())
        assert sum(len(b) for b in batches) == 64, mix
        kinds = {OpKind(int(k)) for b in batches for k in b.kinds}
        assert kinds <= set(wl.spec.mix), mix


def test_driver_report_structure():
    wl = make_workload("delete-churn", key_space=1 << 12, n_ops=256,
                       batch_size=64, preload=64)
    rep = run_workload(make_engine("lsm", mem_pairs=128), wl,
                       maintain_budget=2)
    assert rep["engine"] == "lsm"
    assert rep["stats"]["pending_debt"] == 0
    counts = {k: v["count"] for k, v in rep["per_kind"].items()}
    assert sum(counts.values()) == 256
    for h in rep["per_kind"].values():
        assert h["p50_s"] <= h["p99_s"] <= h["p100_s"]
        assert sum(h["bucket_counts"]) == h["count"]  # clamped, none dropped
        assert len(h["bucket_counts"]) == len(h["bucket_edges_s"]) - 1
