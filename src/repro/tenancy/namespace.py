"""Per-tenant key namespaces over one shared engine (DESIGN.md §10).

A tenant's keys live in their own *namespace*: tenant id packed into the
high bits of the engine key, tenant-local key in the low bits::

    encoded = (tenant_id << key_bits) | local_key
    key_bits = 31 - tenant_bits          # the whole envelope stays < 2^31

The packing is collision-free by construction — distinct
``(tenant, local_key)`` pairs map to distinct encoded keys, and
``decode`` inverts ``encode`` exactly — and it preserves *order within a
namespace*: a tenant's keys occupy one contiguous interval
``[tid << key_bits | 1, tid << key_bits | max_local_key]`` of the shared
keyspace.  Contiguity is what makes everything downstream keep working
unchanged:

* a tenant RANGE ``[lo, hi]`` encodes to a contiguous scan that can never
  leak a co-tenant's rows;
* the sharded layer's :class:`~repro.shard.partition.RangePartitioner`
  routes and *hot-shard-splits* encoded keys like any others — a bursty
  tenant's namespace simply splits into more shards;
* per-namespace snapshots/stats are ``dump_live_range`` over the interval;
* WAL records carry encoded keys, so tenant identity is threaded through
  the shared log for free and recovery can rebuild one namespace by
  key-interval replay (``repro.wal``).

The 31-bit ceiling keeps the paper-tier portability envelope (uint32
device keys, see ``repro.core.engine_api``): with the default 4 tenant
bits every tenant still owns a 2^27-key space — far above benchmark scale.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.engine_api import OpBatch, OpKind
from repro.core.sorted_run import KEY_DTYPE

#: encoded keys must stay below 2^31 (uint32 device tier; engine_api).
_ENVELOPE_BITS = 31


@dataclasses.dataclass(frozen=True)
class NamespaceMap:
    """Collision-free (tenant, local key) <-> engine key packing."""

    tenant_bits: int = 4

    def __post_init__(self):
        assert 1 <= self.tenant_bits <= 12, \
            "tenant_bits outside [1, 12] leaves no usable per-tenant keyspace"

    # ------------------------------------------------------------- geometry
    @property
    def key_bits(self) -> int:
        return _ENVELOPE_BITS - self.tenant_bits

    @property
    def max_tenants(self) -> int:
        return 1 << self.tenant_bits

    @property
    def max_local_key(self) -> int:
        """Largest encodable tenant-local key (local keys are >= 1)."""
        return (1 << self.key_bits) - 1

    def describe(self) -> dict:
        return {"tenant_bits": self.tenant_bits, "key_bits": self.key_bits,
                "max_tenants": self.max_tenants,
                "max_local_key": self.max_local_key}

    # ------------------------------------------------------------ transform
    def _check_tenant(self, tenant_id: int) -> int:
        tid = int(tenant_id)
        assert 0 <= tid < self.max_tenants, \
            f"tenant id {tid} outside [0, {self.max_tenants})"
        return tid

    def encode(self, tenant_id: int, keys) -> np.ndarray:
        """Tenant-local keys -> engine keys (vectorized, checked)."""
        tid = self._check_tenant(tenant_id)
        keys = np.asarray(keys, KEY_DTYPE)
        if len(keys):
            assert int(keys.min()) >= 1 and \
                int(keys.max()) <= self.max_local_key, \
                f"tenant-local keys must lie in [1, {self.max_local_key}]"
        return (np.uint64(tid << self.key_bits) | keys).astype(KEY_DTYPE)

    def decode(self, keys) -> tuple:
        """Engine keys -> ``(tenant_ids, local_keys)`` (exact inverse)."""
        keys = np.asarray(keys, KEY_DTYPE)
        mask = np.uint64(self.max_local_key)
        return ((keys >> np.uint64(self.key_bits)).astype(np.int64),
                (keys & mask).astype(KEY_DTYPE))

    def tenant_interval(self, tenant_id: int) -> tuple:
        """The namespace's contiguous engine-key interval (inclusive)."""
        tid = self._check_tenant(tenant_id)
        base = tid << self.key_bits
        return base + 1, base + self.max_local_key

    def encode_batch(self, tenant_id: int, batch: OpBatch) -> OpBatch:
        """Rewrite a tenant-local :class:`OpBatch` into engine keyspace.

        ``keys`` encode on every row; ``his`` (the RANGE inclusive upper
        bound) encodes on RANGE rows only — other rows keep their zero
        placeholder, exactly as the protocol ignores them.
        """
        keys = self.encode(tenant_id, batch.keys)
        his = batch.his.copy()
        rmask = np.asarray(batch.kinds) == int(OpKind.RANGE)
        if rmask.any():
            his[rmask] = self.encode(tenant_id, batch.his[rmask])
        return OpBatch(batch.kinds, keys, batch.vals, his)
