"""Fused emptying-cascade pipeline (DESIGN.md §8): parity, budgets, Blooms.

Three contracts of the one-dispatch maintenance path:

* **Physical parity** — the fused flush/split/insert/clear impls produce
  *bit-identical* device tables (runs, counts, filters, structure mirrors)
  to the pre-fusion eager path on random insert/delete/maintain/drain
  interleavings, and both agree with a sorted-dict oracle on every visible
  query/range result.
* **Dispatch budget** — a flush unit is exactly ONE device dispatch and a
  split unit a small constant, asserted through the ``_device_call``
  counting funnel (the regression guard for the >= 5x dispatch reduction
  recorded in BENCH_device_ingest.json).
* **Incremental-Bloom invariant** — ORing only an insert batch's bits into
  the root filter is bit-identical to a from-scratch rebuild over the grown
  run, at every step and for every node row after drain.
"""
import numpy as np

import repro.core.jax_nbtree as jnb
from repro.core.jax_nbtree import NBTreeIndex, _build_bloom


def _pool(seed, n):
    rng = np.random.default_rng(seed)
    return rng.choice(np.arange(1, 2**31, dtype=np.uint32), n, replace=False)


def _assert_same_tables(a: NBTreeIndex, b: NBTreeIndex, tag: str) -> None:
    assert a.max_nodes == b.max_nodes, tag
    for name in ("run_keys", "run_vals", "run_count", "bloom",
                 "pivots", "children", "nchild"):
        assert np.array_equal(np.asarray(getattr(a, name)),
                              np.asarray(getattr(b, name))), f"{tag}: {name}"
    assert a._next_id == b._next_id, tag
    assert [n.nid for n in a._pending] == [n.nid for n in b._pending], tag

    def shape(node):
        return (node.nid, node.count, tuple(node.skeys),
                tuple(shape(c) for c in node.children))

    assert shape(a.root) == shape(b.root), tag


def _apply_round(idx: NBTreeIndex, oracle: dict, rng, pool, cursor: int) -> int:
    """One randomized round of inserts/deletes/maintain; returns new cursor."""
    n = int(rng.integers(32, 193))
    ks = pool[cursor: cursor + n]
    vs = (np.arange(len(ks)) + cursor).astype(np.int32)
    idx.insert_batch(ks, vs)
    for k, v in zip(ks.tolist(), vs.tolist()):
        oracle[k] = v
    if rng.random() < 0.4 and cursor:
        dn = int(rng.integers(1, 64))
        dk = pool[max(0, cursor - dn): cursor]
        idx.delete_batch(dk)
        for k in dk.tolist():
            oracle[k] = None
    idx.maintain(int(rng.integers(0, 3)))
    return cursor + n


def test_fused_matches_eager_and_oracle():
    """Random interleavings: bit-identical tables + oracle-exact results.

    ``max_nodes=8`` forces the fused one-dispatch table growth on both
    paths mid-run, so ``_grow_impl`` parity is covered too.
    """
    rng_a, rng_b, rng_q = (np.random.default_rng(s) for s in (21, 21, 99))
    pool = _pool(20, 6000)
    fused = NBTreeIndex(f=3, sigma=256, max_nodes=8, fused=True)
    eager = NBTreeIndex(f=3, sigma=256, max_nodes=8, fused=False)
    oracle: dict = {}
    shadow: dict = {}
    ca = cb = 0
    for r in range(18):
        ca = _apply_round(fused, oracle, rng_a, pool, ca)
        cb = _apply_round(eager, shadow, rng_b, pool, cb)
        assert ca == cb and oracle == shadow   # identical op streams
        if r % 6 == 5:
            fused.drain()
            eager.drain()
        if r % 3 == 2:
            _assert_same_tables(fused, eager, f"round {r}")
    fused.drain()
    eager.drain()
    _assert_same_tables(fused, eager, "final")
    fused.check_invariants()
    eager.check_invariants()
    assert fused.max_nodes > 8          # growth actually happened

    # visible semantics vs the sorted-dict oracle, on both paths
    seen = pool[:ca]
    q = rng_q.choice(seen, 800, replace=False)
    for idx in (fused, eager):
        p, v = idx.query_batch(q)
        p, v = np.asarray(p), np.asarray(v)
        for j, k in enumerate(q.tolist()):
            want = oracle.get(k)
            assert p[j] == (want is not None), k
            if want is not None:
                assert v[j] == want, k
    live = sorted(k for k, v in oracle.items() if v is not None)
    lo, hi = live[len(live) // 4], live[3 * len(live) // 4]
    want_r = [(k, oracle[k]) for k in live if lo <= k <= hi]
    for idx in (fused, eager):
        rk, rv, cnt, trunc = idx.range_query_batch(
            np.asarray([lo]), np.asarray([hi]), max_results=len(want_r) + 8)
        assert not bool(np.asarray(trunc)[0])
        c = int(np.asarray(cnt)[0])
        got = list(zip(np.asarray(rk)[0, :c].tolist(),
                       np.asarray(rv)[0, :c].tolist()))
        assert got == want_r


def test_flush_unit_is_one_dispatch(monkeypatch):
    """Dispatch-budget regression: flush == 1 call, split a small constant."""
    calls: list = []
    real = jnb._device_call

    def counting(fn, *args, **kwargs):
        calls.append(getattr(fn, "__name__", repr(fn)))
        return real(fn, *args, **kwargs)

    monkeypatch.setattr(jnb, "_device_call", counting)
    idx = NBTreeIndex(f=4, sigma=256, max_nodes=64)
    pool = _pool(7, 8192)
    cursor = 0
    flush_units = split_units = 0
    while cursor < len(pool):
        idx.insert_batch(pool[cursor:cursor + 128],
                         np.arange(128, dtype=np.int32))
        cursor += 128
        while idx._pending:
            unit_node = next((n for n in idx._pending
                              if n.count > idx.sigma), None)
            # classify before running: a root-leaf split grows children
            # onto the *same* node object.
            was_leaf = unit_node.is_leaf if unit_node is not None else None
            calls.clear()
            idx.maintain(1)
            if unit_node is None:
                assert not calls       # stale entries retire for free
                continue
            if was_leaf:
                # split unit: split + clear + <= 4 structure syncs per
                # level of upward cascade (+ possibly one table grow)
                split_units += 1
                assert len(calls) <= 16, calls
            else:
                flush_units += 1
                assert calls == ["_flush_impl"], calls
    assert flush_units > 10 and split_units > 2   # both paths exercised


def test_incremental_bloom_equals_from_scratch():
    """bloom[0] after incremental ORs == rebuild over the grown run, always;
    every node row's filter == rebuild over its row after drain."""
    rng = np.random.default_rng(13)
    pool = _pool(12, 4096)
    idx = NBTreeIndex(f=3, sigma=256, max_nodes=32)
    cursor = 0
    for r in range(10):
        n = int(rng.integers(16, 160))
        ks = pool[cursor: cursor + n]
        cursor += n
        if r % 3 == 2:
            idx.delete_batch(ks[: n // 2])      # tombstones hash like keys
        idx.insert_batch(ks, np.arange(len(ks), dtype=np.int32))
        scratch = _build_bloom(idx.run_keys[0], idx.nbits, idx.h)
        assert np.array_equal(np.asarray(idx.bloom[0]), np.asarray(scratch)), r
        idx.maintain(int(rng.integers(0, 2)))
        scratch = _build_bloom(idx.run_keys[0], idx.nbits, idx.h)
        assert np.array_equal(np.asarray(idx.bloom[0]), np.asarray(scratch)), r
    idx.drain()
    blooms = np.asarray(idx.bloom)
    keys = np.asarray(idx.run_keys)
    for nid in range(idx._next_id):
        scratch = np.asarray(_build_bloom(keys[nid], idx.nbits, idx.h))
        assert np.array_equal(blooms[nid], scratch), nid


def test_pending_queue_bookkeeping():
    """Deque + membership counter stay consistent under churn."""
    idx = NBTreeIndex(f=3, sigma=64, max_nodes=32)
    pool = _pool(5, 2048)
    for i in range(0, 2048, 64):
        idx.insert_batch(pool[i:i + 64], np.arange(64, dtype=np.int32))
        assert sum(idx._pending_n.values()) == len(idx._pending)
        assert ({n.nid for n in idx._pending}
                == set(idx._pending_n)), "membership set out of sync"
        idx.maintain(1)
    idx.drain()
    assert not idx._pending and not idx._pending_n
    idx.check_invariants()


def test_maintain_budget_still_bounded_fused():
    """maintain(k) on the fused path keeps the deamortization contract."""
    rng = np.random.default_rng(6)
    idx = NBTreeIndex(f=4, sigma=512, max_nodes=128)
    keys = _pool(66, 8000)
    max_drop = 0
    for i in range(0, len(keys), 256):
        idx.insert_batch(keys[i:i + 256], np.arange(256, dtype=np.int32))
        before = len(idx._pending)
        idx.maintain(1)
        max_drop = max(max_drop, before - len(idx._pending))
    assert max_drop <= 1
    idx.drain()
    idx.check_invariants()
