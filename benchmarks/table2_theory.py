"""Table 2: asymptotic behaviour — empirical scaling exponents.

Fits log-log slopes of measured worst-case insertion time vs n:
NB-tree should scale ~log n (slope ~0 on log-log of time vs n), LSM-tree
linearly (slope ~1) — the theory gap the paper's title refers to.
"""
from __future__ import annotations

import numpy as np

from .common import insert_all, make_bench_engine, workload
from repro.core.cost_model import HDD


def run(sizes=(20_000, 60_000, 180_000)):
    rows = []
    for name in ("nbtree", "lsm"):
        maxes = []
        for n in sizes:
            keys = workload(n)
            eng = make_bench_engine(name, HDD, max(1024, n // 64))
            _, mx = insert_all(eng, keys)
            maxes.append(mx)
        slope = np.polyfit(np.log(sizes), np.log(np.maximum(maxes, 1e-9)), 1)[0]
        rows.append(dict(fig="table2", index=name, slope=float(slope),
                         max_insert_ms=[m * 1e3 for m in maxes]))
    return rows


def check(rows) -> list[str]:
    out = []
    sel = {r["index"]: r for r in rows}
    if sel["lsm"]["slope"] > 0.6:
        out.append(f"table2: LSM worst-case insert ~linear (slope "
                   f"{sel['lsm']['slope']:.2f})  [matches paper]")
    if sel["nbtree"]["slope"] < 0.4:
        out.append(f"table2: NB worst-case insert ~log (slope "
                   f"{sel['nbtree']['slope']:.2f})  [matches paper]")
    else:
        out.append(f"table2: NB slope {sel['nbtree']['slope']:.2f}  [MISMATCH]")
    return out
