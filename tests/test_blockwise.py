"""Blockwise (flash) attention: parity with naive SDPA, fwd + grad."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.blockwise_attn import blockwise_sdpa, tile_schedule
from repro.models.layers import _sdpa, causal_mask


@pytest.mark.parametrize("B,S,T,H,KVH,D,kind,window", [
    (2, 64, 64, 8, 2, 32, "causal", None),
    (1, 100, 100, 4, 4, 16, "causal", 24),
    (2, 50, 50, 4, 2, 32, "bidir", None),
    (1, 1, 200, 8, 2, 32, "causal", None),     # decode: 1 query vs cache
    (1, 130, 130, 4, 1, 64, "causal", None),   # MQA, ragged chunks
])
def test_forward_parity(rng, B, S, T, H, KVH, D, kind, window):
    q = jnp.array(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.array(rng.normal(size=(B, T, KVH, D)), jnp.float32)
    v = jnp.array(rng.normal(size=(B, T, KVH, D)), jnp.float32)
    if S == 1:
        pos = 150
        qpos = jnp.full((B, S), pos)
        kpos = jnp.where(jnp.arange(T) <= pos, jnp.arange(T), -1)[None].repeat(B, 0)
        m = ((kpos <= pos) & (kpos >= 0))[:, None, :]
    else:
        qpos = jnp.broadcast_to(jnp.arange(S), (B, S))
        kpos = jnp.broadcast_to(jnp.arange(T), (B, T))
        mask = (causal_mask(S, T, window=window) if kind == "causal"
                else jnp.ones((S, T), bool))
        m = jnp.broadcast_to(mask, (B, S, T))
    ref = _sdpa(q, k, v, m)
    out = blockwise_sdpa(q, k, v, qpos=qpos, kpos=kpos, kind=kind,
                         window=window, q_chunk=32, kv_chunk=64)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=3e-5)


def test_gradient_parity(rng):
    B, S, H, KVH, D = 1, 48, 4, 2, 16
    q = jnp.array(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.array(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    v = jnp.array(rng.normal(size=(B, S, KVH, D)), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    m = jnp.broadcast_to(causal_mask(S), (B, S, S))

    g1 = jax.grad(lambda a, b, c: jnp.sum(_sdpa(a, b, c, m) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda a, b, c: jnp.sum(blockwise_sdpa(
        a, b, c, qpos=qpos, kpos=qpos, q_chunk=32, kv_chunk=32) ** 2),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=1e-4)


def test_tile_schedule_decode_no_query_padding():
    nq, nc, qc, kc = tile_schedule(1, 32768)
    assert qc == 8 and nq == 1, "decode must not pad queries to q_chunk"
    nq, nc, qc, kc = tile_schedule(4096, 4096)
    assert qc == 512 and nq == 8
