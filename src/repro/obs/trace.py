"""Structured event tracer emitting Chrome ``trace_event`` JSON.

Spans use the Trace Event Format's "X" (complete) and "i" (instant)
phases with microsecond timestamps, wrapped in ``{"traceEvents": [...]}``
— the object form Perfetto and ``chrome://tracing`` both load directly.
Memory is a bounded ring buffer (``collections.deque(maxlen=...)``): a
multi-hour run keeps the most recent ``capacity`` events instead of
growing without bound, and ``dropped_events`` records how many fell off
the head so a truncated trace is never mistaken for a complete one.

The tracer is clock-agnostic: callers pass explicit timestamps in
*seconds* on whichever clock owns the component (sim seconds in the
ingest frontends, wall seconds in the device engine), so sim-tier traces
are byte-deterministic.  There is no global "now" — determinism would die
the moment a span implicitly read ``time.time()``.

Span categories are a closed vocabulary (:data:`SPAN_CATEGORIES`) so the
stall attributor and trace consumers can rely on the set.
"""
from __future__ import annotations

import collections
import json

#: Closed span-category vocabulary.  ``pid`` in the emitted JSON is the
#: category's index here, giving each category its own named process row
#: in Perfetto's timeline without per-event metadata lookups.
SPAN_CATEGORIES = (
    "commit",          # group-commit service (admission -> applied)
    "wal_fsync",       # WAL append + fsync barrier
    "flush_unit",      # one device-side maintenance unit (flush/split)
    "cascade",         # emptying-cascade maintenance budget within a step
    "shard_split",     # ensemble shard split (instant)
    "checkpoint",      # LSN-keyed snapshot write
    "recovery",        # WAL replay at startup
    "shed",            # admission-queue overflow drop (instant)
    "tenant_throttle", # DRR deferral of a backlogged tenant (instant)
    "dispatch",        # one host->device kernel dispatch (device tier)
    "chaos",           # injected fault event (instant; repro.wal.faults)
    "failover",        # primary death -> writes restored (replication)
    "catchup",         # replica rebuild: snapshot ship + WAL tail replay
)

_CAT_INDEX = {c: i for i, c in enumerate(SPAN_CATEGORIES)}


class Tracer:
    """Bounded ring-buffer span recorder.

    All times are seconds; the emitted JSON converts to the format's
    microseconds.  ``enabled=False`` turns every method into an immediate
    no-op so a disabled tracer can be threaded unconditionally.
    """

    def __init__(self, capacity: int = 1 << 16, *, enabled: bool = True):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._events: collections.deque = collections.deque(
            maxlen=self.capacity)
        self._seen = 0  # total events ever recorded (>= len(_events))

    # -- recording ---------------------------------------------------------
    def complete(self, cat: str, name: str, t0_s: float, dur_s: float,
                 **args) -> None:
        """Record a completed span [t0_s, t0_s + dur_s)."""
        if not self.enabled:
            return
        ev = {"ph": "X", "cat": cat, "name": name,
              "pid": _CAT_INDEX.get(cat, len(SPAN_CATEGORIES)), "tid": 0,
              "ts": round(t0_s * 1e6, 3), "dur": round(dur_s * 1e6, 3)}
        if args:
            ev["args"] = args
        self._events.append(ev)
        self._seen += 1

    def instant(self, cat: str, name: str, t_s: float, **args) -> None:
        """Record a zero-duration event at ``t_s``."""
        if not self.enabled:
            return
        ev = {"ph": "i", "cat": cat, "name": name,
              "pid": _CAT_INDEX.get(cat, len(SPAN_CATEGORIES)), "tid": 0,
              "ts": round(t_s * 1e6, 3), "s": "g"}
        if args:
            ev["args"] = args
        self._events.append(ev)
        self._seen += 1

    # -- reading -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped_events(self) -> int:
        return self._seen - len(self._events)

    def events(self) -> list[dict]:
        """Snapshot of buffered events, oldest first."""
        return list(self._events)

    def spans(self, cat: str | None = None) -> list[dict]:
        """Complete ("X") spans, optionally filtered by category."""
        return [e for e in self._events
                if e["ph"] == "X" and (cat is None or e["cat"] == cat)]

    def categories(self) -> set[str]:
        return {e["cat"] for e in self._events}

    def to_chrome(self) -> dict:
        """Chrome trace_event JSON object (Perfetto-loadable)."""
        meta = [
            {"ph": "M", "name": "process_name", "pid": i, "tid": 0,
             "args": {"name": cat}}
            for i, cat in enumerate(SPAN_CATEGORIES)
        ]
        return {
            "traceEvents": meta + list(self._events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped_events,
                          "capacity": self.capacity},
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=None,
                      separators=(",", ":"))

    def clear(self) -> None:
        self._events.clear()
        self._seen = 0


def validate_chrome_trace(obj: dict) -> list[str]:
    """Return a list of schema violations (empty = valid).

    Checks the subset of the Trace Event Format that Perfetto's JSON
    importer requires: a ``traceEvents`` array whose entries carry a
    ``ph`` and, for X/i phases, numeric ``ts`` (and ``dur`` for X).
    """
    errs = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing traceEvents array"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    for i, e in enumerate(evs):
        if not isinstance(e, dict) or "ph" not in e:
            errs.append(f"event {i}: missing ph")
            continue
        ph = e["ph"]
        if ph in ("X", "i"):
            if not isinstance(e.get("ts"), (int, float)):
                errs.append(f"event {i}: non-numeric ts")
            if not isinstance(e.get("name"), str):
                errs.append(f"event {i}: missing name")
        if ph == "X" and not isinstance(e.get("dur"), (int, float)):
            errs.append(f"event {i}: X span without numeric dur")
        if ph == "i" and e.get("s") not in ("g", "p", "t", None):
            errs.append(f"event {i}: bad instant scope {e.get('s')!r}")
    return errs
